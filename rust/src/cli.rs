//! Tiny CLI argument parser (the offline registry ships no clap).
//!
//! Supports `--flag`, `--key value` and `--key=value`; positionals are
//! kept in order. Typed getters parse on access and surface readable
//! errors; `usage()` output comes from the declared option table so the
//! binaries' `--help` never drifts from what they actually accept.

use std::collections::BTreeMap;

/// Declared option (for help text + unknown-flag detection).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<OptSpec>,
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    /// Declare the accepted options, then parse `std::env::args()`.
    pub fn parse(about: &'static str, specs: &[OptSpec]) -> Result<Args, String> {
        Self::parse_from(about, specs, std::env::args().collect())
    }

    pub fn parse_from(
        about: &'static str,
        specs: &[OptSpec],
        argv: Vec<String>,
    ) -> Result<Args, String> {
        let mut out = Args {
            program: argv.first().cloned().unwrap_or_default(),
            about,
            specs: specs.to_vec(),
            ..Default::default()
        };
        let known = |name: &str| specs.iter().find(|s| s.name == name);
        let mut it = argv.into_iter().skip(1).peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if name == "help" {
                    return Err(out.usage());
                }
                let spec = known(&name).ok_or_else(|| {
                    format!("unknown option --{name}\n\n{}", out.usage())
                })?;
                let value = match (spec.value, inline_val) {
                    (None, None) => "true".to_string(),
                    (None, Some(v)) => {
                        return Err(format!("--{name} takes no value (got '{v}')"))
                    }
                    (Some(_), Some(v)) => v,
                    (Some(placeholder), None) => it
                        .next()
                        .ok_or_else(|| format!("--{name} expects <{placeholder}>"))?,
                };
                out.flags.insert(name, value);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nUsage: {} [options]\n\nOptions:\n", self.about, self.program);
        for spec in &self.specs {
            let left = match spec.value {
                Some(v) => format!("--{} <{}>", spec.name, v),
                None => format!("--{}", spec.name),
            };
            s.push_str(&format!("  {left:<28} {}\n", spec.help));
        }
        s
    }

    pub fn present(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: '{v}' is not a number")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: '{v}' is not an integer")),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => {
                v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
        }
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "dataset", value: Some("name"), help: "dataset to use" },
            OptSpec { name: "nfe", value: Some("n"), help: "evaluation budget" },
            OptSpec { name: "verbose", value: None, help: "chatty output" },
            OptSpec { name: "solvers", value: Some("a,b"), help: "solver list" },
        ]
    }

    fn argv(s: &str) -> Vec<String> {
        std::iter::once("prog".to_string()).chain(s.split_whitespace().map(String::from)).collect()
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse_from("t", &specs(), argv("--dataset gmm8 --nfe=20 --verbose pos1"))
            .unwrap();
        assert_eq!(a.str_or("dataset", "x"), "gmm8");
        assert_eq!(a.usize_or("nfe", 5).unwrap(), 20);
        assert!(a.present("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from("t", &specs(), argv("")).unwrap();
        assert_eq!(a.str_or("dataset", "gmm8"), "gmm8");
        assert_eq!(a.usize_or("nfe", 10).unwrap(), 10);
        assert_eq!(a.f64_or("lambda", 5.0).unwrap(), 5.0);
        assert!(!a.present("verbose"));
    }

    #[test]
    fn rejects_unknown_and_bad_values() {
        assert!(Args::parse_from("t", &specs(), argv("--wat 3")).is_err());
        let a = Args::parse_from("t", &specs(), argv("--nfe banana")).unwrap();
        assert!(a.usize_or("nfe", 1).is_err());
        assert!(Args::parse_from("t", &specs(), argv("--verbose=yes")).is_err());
        assert!(Args::parse_from("t", &specs(), argv("--dataset")).is_err());
    }

    #[test]
    fn help_lists_options() {
        let err = Args::parse_from("my tool", &specs(), argv("--help")).unwrap_err();
        assert!(err.contains("my tool"));
        assert!(err.contains("--dataset <name>"));
        assert!(err.contains("--verbose"));
    }

    #[test]
    fn list_option() {
        let a = Args::parse_from("t", &specs(), argv("--solvers era,ddim")).unwrap();
        assert_eq!(a.list_or("solvers", &[]), vec!["era", "ddim"]);
        let b = Args::parse_from("t", &specs(), argv("")).unwrap();
        assert_eq!(b.list_or("solvers", &["era"]), vec!["era"]);
    }
}
