//! Minimal dense 2-D f32 tensor used throughout the sampling hot path.
//!
//! The solver state is a batch of samples `(rows = batch, cols = data dim)`
//! stored row-major. The offline registry ships no ndarray, and the ops the
//! solvers need are few: affine combinations, norms, and buffer stacking —
//! all written as straight loops the compiler auto-vectorises.

use std::fmt;

/// Dense row-major `rows x cols` f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor[{}x{}]", self.rows, self.cols)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from a flat row-major buffer. Panics on length mismatch.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "tensor shape/data mismatch");
        Tensor { data, rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// The raw element bytes, little-endian, without copying. Only
    /// available on little-endian targets, where the in-memory f32
    /// layout *is* the wire layout — the binary delivery path sends
    /// these straight from the engine-owned buffer to the socket.
    #[cfg(target_endian = "little")]
    pub fn as_le_bytes(&self) -> &[u8] {
        // SAFETY: f32 has no padding or invalid bit patterns when viewed
        // as bytes, the slice covers exactly `len * 4` initialised bytes,
        // and u8 has alignment 1.
        unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        }
    }

    /// Owned little-endian element bytes (works on any endianness; the
    /// big-endian fallback for encode paths that cannot reinterpret).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Rebuild a tensor from a counted little-endian f32 payload, as
    /// received on the wire. Validates the byte count against the
    /// announced shape.
    pub fn from_le_bytes(bytes: &[u8], rows: usize, cols: usize) -> Result<Tensor, String> {
        if bytes.len() % 4 != 0 {
            return Err(format!("payload length {} is not a multiple of 4", bytes.len()));
        }
        if bytes.len() != rows * cols * 4 {
            return Err(format!(
                "payload holds {} f32s but shape is {}x{}",
                bytes.len() / 4,
                rows,
                cols
            ));
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor { data, rows, cols })
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Borrowed view of rows `[start, start + n)` — the zero-copy
    /// sibling of [`Tensor::slice_rows`] (rows are contiguous in the
    /// row-major layout). The batcher's gather kernel reads these.
    pub fn row_span(&self, start: usize, n: usize) -> &[f32] {
        assert!(start + n <= self.rows, "row_span out of range");
        &self.data[start * self.cols..(start + n) * self.cols]
    }

    /// Mutable view of rows `[start, start + n)` (scatter target).
    pub fn row_span_mut(&mut self, start: usize, n: usize) -> &mut [f32] {
        assert!(start + n <= self.rows, "row_span out of range");
        let c = self.cols;
        &mut self.data[start * c..(start + n) * c]
    }

    /// `self = a * self + b * other`, elementwise (the DDIM transition).
    pub fn affine_inplace(&mut self, a: f32, b: f32, other: &Tensor) {
        debug_assert_eq!(self.data.len(), other.data.len());
        crate::kernels::fused::affine_inplace(&mut self.data, a, b, &other.data);
    }

    /// `out = a * self + b * other` (allocating variant).
    pub fn affine(&self, a: f32, b: f32, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.affine_inplace(a, b, other);
        out
    }

    /// `self += s * other`.
    pub fn axpy(&mut self, s: f32, other: &Tensor) {
        debug_assert_eq!(self.data.len(), other.data.len());
        crate::kernels::fused::axpy(&mut self.data, s, &other.data);
    }

    /// `self *= s`.
    pub fn scale(&mut self, s: f32) {
        crate::kernels::fused::scale(&mut self.data, s);
    }

    /// Weighted sum `sum_k w[k] * ts[k]` of equally-shaped tensors.
    ///
    /// This is the Rust-native mirror of the `solver_combine` Pallas
    /// kernel's inner reduction; `kernel_weighted_sum` below is the
    /// cache-friendlier fused form the hot path uses.
    pub fn weighted_sum(ts: &[&Tensor], w: &[f64]) -> Tensor {
        assert_eq!(ts.len(), w.len(), "weights/tensors length mismatch");
        assert!(!ts.is_empty(), "weighted_sum of nothing");
        let mut out = Tensor::zeros(ts[0].rows, ts[0].cols);
        for (t, &wi) in ts.iter().zip(w.iter()) {
            out.axpy(wi as f32, t);
        }
        out
    }

    /// Fused `a * x + b * (sum_k w[k] * eps[k])` with a single pass over
    /// the output — the in-process twin of the `solver_combine` artifact.
    /// Weights are `f64` (the plan's native dtype, matching
    /// [`Tensor::weighted_sum`]) and narrowed to f32 here.
    pub fn kernel_weighted_sum(x: &Tensor, a: f32, b: f32, eps: &[&Tensor], w: &[f64]) -> Tensor {
        assert_eq!(eps.len(), w.len());
        // Iterator zips, not indexed loops: bounds checks defeat
        // auto-vectorisation here (measured 4x in bench_micro before the
        // §Perf pass — see EXPERIMENTS.md).
        let mut out: Vec<f32> = match eps.len() {
            0 => x.data.iter().map(|&xv| a * xv).collect(),
            _ => {
                let bw0 = b * (w[0] as f32);
                x.data
                    .iter()
                    .zip(eps[0].data.iter())
                    .map(|(&xv, &ev)| a * xv + bw0 * ev)
                    .collect()
            }
        };
        for (ek, &wk) in eps.iter().zip(w.iter()).skip(1) {
            let bwk = b * (wk as f32);
            debug_assert_eq!(ek.data.len(), out.len());
            for (o, &ev) in out.iter_mut().zip(ek.data.iter()) {
                *o += bwk * ev;
            }
        }
        Tensor::from_vec(out, x.rows, x.cols)
    }

    /// Mean per-row L2 norm: `mean_r ||self[r]||_2` (Eq. 15's batch form).
    pub fn mean_row_norm(&self) -> f32 {
        if self.rows == 0 {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for r in 0..self.rows {
            let s: f64 = self.row(r).iter().map(|&v| (v as f64) * (v as f64)).sum();
            acc += s.sqrt();
        }
        (acc / self.rows as f64) as f32
    }

    /// Mean per-row L2 distance to `other`.
    pub fn mean_row_dist(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.data.len(), other.data.len());
        if self.rows == 0 {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for r in 0..self.rows {
            let s: f64 = self
                .row(r)
                .iter()
                .zip(other.row(r))
                .map(|(&a, &b)| {
                    let d = (a - b) as f64;
                    d * d
                })
                .sum();
            acc += s.sqrt();
        }
        (acc / self.rows as f64) as f32
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        let s: f64 = self.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
        s.sqrt() as f32
    }

    /// Column means (length `cols`), in f64 for metric stability.
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0f64; self.cols];
        for r in 0..self.rows {
            for (mc, &v) in m.iter_mut().zip(self.row(r)) {
                *mc += v as f64;
            }
        }
        let n = self.rows.max(1) as f64;
        m.iter_mut().for_each(|v| *v /= n);
        m
    }

    /// Sample covariance (cols x cols, row-major, f64, denominator n-1).
    pub fn covariance(&self) -> Vec<f64> {
        let d = self.cols;
        let mu = self.col_means();
        let mut cov = vec![0.0f64; d * d];
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..d {
                let di = row[i] as f64 - mu[i];
                for j in i..d {
                    cov[i * d + j] += di * (row[j] as f64 - mu[j]);
                }
            }
        }
        let n = (self.rows.max(2) - 1) as f64;
        for i in 0..d {
            for j in i..d {
                cov[i * d + j] /= n;
                cov[j * d + i] = cov[i * d + j];
            }
        }
        cov
    }

    /// Vertically stack rows of `parts` into one tensor.
    pub fn vstack(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        let rows: usize = parts.iter().map(|p| p.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for p in parts {
            assert_eq!(p.cols, cols, "vstack column mismatch");
            data.extend_from_slice(&p.data);
        }
        Tensor::from_vec(data, rows, cols)
    }

    /// Copy of rows `[start, start+n)`.
    pub fn slice_rows(&self, start: usize, n: usize) -> Tensor {
        assert!(start + n <= self.rows, "slice_rows out of range");
        let data = self.data[start * self.cols..(start + n) * self.cols].to_vec();
        Tensor::from_vec(data, n, self.cols)
    }

    /// Shrink to the first `n` rows in place. `Vec::truncate` keeps the
    /// allocation, so this is free of heap traffic — the guided workload
    /// collapses a paired 2N-row model output to its N guided rows this
    /// way without breaking the zero-alloc steady state.
    pub fn truncate_rows(&mut self, n: usize) {
        assert!(n <= self.rows, "truncate_rows beyond current rows");
        self.data.truncate(n * self.cols);
        self.rows = n;
    }

    /// Remove rows `[start, start + n)` in place, shifting later rows
    /// up (one contiguous `copy_within`; the allocation is kept). This
    /// is the lane-compaction primitive: retiring one lane member must
    /// not move any surviving member's bytes relative to each other,
    /// only their row offsets.
    pub fn remove_rows(&mut self, start: usize, n: usize) {
        assert!(start + n <= self.rows, "remove_rows out of range");
        let c = self.cols;
        self.data.copy_within((start + n) * c.., start * c);
        self.data.truncate((self.rows - n) * c);
        self.rows -= n;
    }

    /// Append rows from a flat row-major buffer (length must be a
    /// multiple of `cols`). Lane admission stacks a joining request's
    /// start iterate under the existing members this way.
    pub fn extend_rows(&mut self, src: &[f32]) {
        assert!(self.cols > 0 && src.len() % self.cols == 0, "extend_rows shape mismatch");
        self.data.extend_from_slice(src);
        self.rows += src.len() / self.cols;
    }

    /// True if every element is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32], r: usize, c: usize) -> Tensor {
        Tensor::from_vec(v.to_vec(), r, c)
    }

    #[test]
    fn zeros_shape() {
        let z = Tensor::zeros(3, 2);
        assert_eq!((z.rows(), z.cols(), z.len()), (3, 2, 6));
        assert!(z.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn from_vec_checks_len() {
        let _ = Tensor::from_vec(vec![1.0; 5], 2, 3);
    }

    #[test]
    fn affine_matches_manual() {
        let mut x = t(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        let e = t(&[1.0, 1.0, 1.0, 1.0], 2, 2);
        x.affine_inplace(2.0, -1.0, &e);
        assert_eq!(x.as_slice(), &[1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn weighted_sum_two() {
        let a = t(&[1.0, 0.0], 1, 2);
        let b = t(&[0.0, 2.0], 1, 2);
        let s = Tensor::weighted_sum(&[&a, &b], &[3.0, 0.5]);
        assert_eq!(s.as_slice(), &[3.0, 1.0]);
    }

    #[test]
    fn kernel_weighted_sum_matches_unfused() {
        let x = t(&[1.0, -2.0, 0.5, 4.0], 2, 2);
        let e1 = t(&[0.1, 0.2, 0.3, 0.4], 2, 2);
        let e2 = t(&[-1.0, 1.0, -1.0, 1.0], 2, 2);
        let fused = Tensor::kernel_weighted_sum(&x, 0.9, 0.3, &[&e1, &e2], &[2.0, -0.5]);
        let mut want = Tensor::weighted_sum(&[&e1, &e2], &[2.0, -0.5]);
        want.scale(0.3);
        want.axpy(0.9, &x);
        for (a, b) in fused.as_slice().iter().zip(want.as_slice()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn kernel_weighted_sum_empty_buffers() {
        let x = t(&[2.0, 4.0], 1, 2);
        let out = Tensor::kernel_weighted_sum(&x, 0.5, 1.0, &[], &[]);
        assert_eq!(out.as_slice(), &[1.0, 2.0]);
    }

    #[test]
    fn mean_row_norm_known() {
        let x = t(&[3.0, 4.0, 0.0, 0.0], 2, 2);
        assert!((x.mean_row_norm() - 2.5).abs() < 1e-6);
    }

    #[test]
    fn mean_row_dist_zero_for_self() {
        let x = t(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(x.mean_row_dist(&x), 0.0);
    }

    #[test]
    fn col_means_and_cov() {
        // Two points (0,0) and (2,2): mean (1,1), cov [[2,2],[2,2]].
        let x = t(&[0.0, 0.0, 2.0, 2.0], 2, 2);
        assert_eq!(x.col_means(), vec![1.0, 1.0]);
        let cov = x.covariance();
        assert_eq!(cov, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn row_span_views_match_slice_rows() {
        let x = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2);
        assert_eq!(x.row_span(1, 2), x.slice_rows(1, 2).as_slice());
        assert_eq!(x.row_span(0, 3), x.as_slice());
        let mut y = x.clone();
        y.row_span_mut(2, 1).copy_from_slice(&[9.0, 9.0]);
        assert_eq!(y.row(2), &[9.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn row_span_checks_bounds() {
        let x = t(&[1.0, 2.0], 1, 2);
        let _ = x.row_span(1, 1);
    }

    #[test]
    fn vstack_and_slice_roundtrip() {
        let a = t(&[1.0, 2.0], 1, 2);
        let b = t(&[3.0, 4.0, 5.0, 6.0], 2, 2);
        let s = Tensor::vstack(&[&a, &b]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.slice_rows(1, 2).as_slice(), b.as_slice());
    }

    #[test]
    fn truncate_rows_keeps_prefix() {
        let mut x = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2);
        x.truncate_rows(2);
        assert_eq!((x.rows(), x.cols()), (2, 2));
        assert_eq!(x.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        x.truncate_rows(2); // idempotent at the boundary
        assert_eq!(x.rows(), 2);
    }

    #[test]
    #[should_panic(expected = "beyond current rows")]
    fn truncate_rows_checks_bounds() {
        let mut x = Tensor::zeros(2, 2);
        x.truncate_rows(3);
    }

    #[test]
    fn remove_rows_shifts_tail_and_extend_rows_appends() {
        let mut x = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 4, 2);
        x.remove_rows(1, 2);
        assert_eq!((x.rows(), x.cols()), (2, 2));
        assert_eq!(x.as_slice(), &[1.0, 2.0, 7.0, 8.0]);
        x.extend_rows(&[9.0, 10.0]);
        assert_eq!(x.rows(), 3);
        assert_eq!(x.row(2), &[9.0, 10.0]);
        // Removing a zero-row span is a no-op; removing at the end works.
        x.remove_rows(1, 0);
        assert_eq!(x.rows(), 3);
        x.remove_rows(2, 1);
        assert_eq!(x.as_slice(), &[1.0, 2.0, 7.0, 8.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn remove_rows_checks_bounds() {
        let mut x = Tensor::zeros(2, 2);
        x.remove_rows(1, 2);
    }

    #[test]
    fn all_finite_detects_nan() {
        let mut x = Tensor::zeros(1, 2);
        assert!(x.all_finite());
        x.as_mut_slice()[1] = f32::NAN;
        assert!(!x.all_finite());
    }
}
