//! # era-solver
//!
//! Production-grade reproduction of **ERA-Solver: Error-Robust Adams Solver
//! for Fast Sampling of Diffusion Probabilistic Models** (Li et al., 2023)
//! as a three-layer Rust + JAX + Pallas serving stack.
//!
//! Layering (see DESIGN.md):
//! * **L1/L2 (build time)** — `python/compile/` trains small denoisers and
//!   AOT-lowers them (Pallas kernels included) to HLO text artifacts.
//! * **L3 (this crate)** — loads the artifacts through PJRT
//!   ([`runtime`]), drives them with the paper's solver and every baseline
//!   ([`solvers`]), and serves batched sampling requests through a
//!   continuous-batching coordinator ([`coordinator`]) — per shard, an
//!   event-driven scheduler stepping batch-major **solver lanes**
//!   ([`solvers::lanes`]: struct-of-arrays state advancing every
//!   co-resident request with single fused passes, ERA selections
//!   splitting divergent members into sibling lanes, compaction
//!   retiring members without perturbing batch-mates' bits) and
//!   feeding a pool of engine executors (`executors_per_shard` threads
//!   over a [`coordinator::BankSet`] of replicas, up to
//!   `pipeline_depth` dispatch rounds in flight, with
//!   sequence-numbered slab completions so out-of-order delivery
//!   reassembles bit-identically) — scaled out across N coordinator
//!   shards by the worker pool ([`pool`]: routing policies, global
//!   admission control, per-request deadlines and cancellation, merged
//!   telemetry incl. executor utilisation, pipeline-depth and
//!   lane-occupancy histograms) behind a TCP JSON-lines front end
//!   ([`server`], which also surfaces each ERA request's final
//!   `delta_eps` on the wire). Two front ends serve the same protocol
//!   off shared codec/session layers (DESIGN.md §13): the portable
//!   blocking thread-per-connection server, and a readiness-based
//!   **epoll gateway** (Linux, raw syscalls — no async runtime) whose
//!   fixed pool of event-loop threads multiplexes thousands of
//!   connections with bounded write queues that park read interest
//!   for backpressure and admission-aware accept throttling. Sample
//!   delivery negotiates its wire encoding per request: the default
//!   JSON rows, or `"encoding":"bin"` — a JSON header line plus a
//!   counted raw little-endian f32 payload written zero-copy from the
//!   engine-owned result tensor through pooled encode buffers and
//!   vectored (`writev`) socket flushes; binary `init` uploads ride
//!   the same counted-payload framing (DESIGN.md §6).
//!
//! The stack is observable end to end ([`obs`], DESIGN.md
//! § Observability): each shard keeps a fixed-capacity **flight
//! recorder** of typed request-lifecycle span events (admission, queue
//! wait, lane attach/split/compact, slab dispatch/completion with
//! executor ids, per-step ERA `delta_eps` + selected Lagrange bases,
//! finalize/cancel) that records allocation-free; the `metrics` wire op
//! (and `era-serve --metrics`) renders every counter, gauge and
//! per-stage latency histogram in Prometheus text exposition, `trace
//! <tag>` dumps one request's span events as JSON, and the bench suite
//! emits durable `BENCH_*.json` perf artifacts gated in CI against the
//! committed baselines in `benchmarks/`.
//!
//! The sampling hot path runs on the zero-copy kernel layer
//! ([`kernels`]): in-place fused slice ops, per-solver scratch arenas
//! and ring-buffer history, and a shared [`kernels::TrajectoryPlan`]
//! cache that precomputes schedule samples and solver coefficients once
//! per `(solver, NFE, grid, schedule)` across requests and shards.
//!
//! Requests carry a [`solvers::TaskSpec`] selecting the workload
//! (DESIGN.md §8): classifier-free guidance (paired cond/uncond eval
//! rows fused into the same slabs, combined in place by
//! `kernels::fused::guided_combine`), img2img partial trajectories
//! (suffix [`kernels::PlanView`]s into the one shared plan per
//! configuration), and stochastic ERA (per-request churn noise streams,
//! stable under batching and sharding). The defaults reproduce the
//! plain unconditional trajectory bit for bit.
//!
//! Substrate modules ([`tensor`], [`rng`], [`linalg`], [`json`],
//! [`metrics`], [`data`], [`benchkit`], [`cli`]) are hand-rolled: the
//! offline registry closure carries no serde / rand / ndarray / criterion.
//!
//! Quickstart (in-process, no server):
//!
//! ```no_run
//! use era_solver::solvers::{sample_with, SolverKind, GridKind, VpSchedule, make_grid};
//! use era_solver::solvers::eps_model::AnalyticGmm;
//! use era_solver::rng::Rng;
//!
//! let sched = VpSchedule::default();
//! let kind = SolverKind::parse("era").unwrap();
//! let grid = make_grid(&sched, GridKind::Uniform, 10, 1.0, 1e-3);
//! let mut rng = Rng::new(0);
//! let mut solver = kind.build(sched, grid, rng.normal_tensor(64, 2), 0, 10);
//! let samples = sample_with(&mut *solver, &AnalyticGmm::gmm8(sched));
//! assert_eq!(samples.rows(), 64);
//! ```

pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod json;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod solvers;
pub mod tensor;

pub use solvers::{Solver, SolverKind, TaskSpec};
pub use tensor::Tensor;
