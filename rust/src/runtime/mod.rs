//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the serving hot path.
//!
//! * [`manifest`] — registry of everything `make artifacts` built
//!   (datasets, batch buckets, artifact paths + hashes, schedule probe,
//!   reference moments), parsed from `artifacts/manifest.json`.
//! * [`engine`] — the PJRT CPU client wrapper: compile-on-first-use
//!   executable cache keyed by (dataset, artifact kind, batch bucket),
//!   batch padding/unpadding, and the [`engine::PjRtEps`] adapter that
//!   plugs compiled denoisers into the [`crate::solvers::EpsModel`]
//!   abstraction the solvers and the coordinator consume.
//!
//! Python never runs here: after `make artifacts` the `.hlo.txt` files
//! are the only interface between the layers.

pub mod engine;
pub mod manifest;
pub mod resident;
pub(crate) mod xla_stub;

pub use engine::{CombineExec, PjRtEngine, PjRtEps};
pub use manifest::{DatasetEntry, Manifest, TrainReport};
pub use resident::{
    ResidentAdvance, ResidentOp, ResidentOutcome, ResidentSnapshot, ResidentState, ResidentStep,
    ResidentTable,
};
