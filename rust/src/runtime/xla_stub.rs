//! Build-time stand-in for the `xla` crate (PJRT C-API bindings).
//!
//! The offline registry closure does not carry the real `xla` crate, so
//! this module mirrors exactly the API surface [`crate::runtime::engine`]
//! uses and fails *at runtime* when a PJRT client is requested. Every
//! caller already treats engine construction as fallible and gates the
//! PJRT paths on `artifacts/manifest.json` existing, so the serving
//! stack, tests and benches all degrade to the in-process
//! [`crate::coordinator::service::MockBank`] path cleanly.
//!
//! Swapping the real bindings back in is a one-line change in
//! `engine.rs` (`use xla;` instead of `use crate::runtime::xla_stub as
//! xla;`) — the signatures here are kept in lock-step with the
//! `xla-rs`-style API the engine was written against.

#![allow(dead_code)]

/// Opaque error mirroring `xla::Error`; engine code only `{:?}`-formats it.
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: built with the xla stub (no `xla` crate in this \
         environment); use the MockBank serving path or rebuild with real \
         PJRT bindings"
            .to_string(),
    )
}

/// Stub of `xla::PjRtClient`. `cpu()` always fails, so no other method
/// is ever reachable; they exist to typecheck the engine.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::Literal`.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1(_xs: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}
