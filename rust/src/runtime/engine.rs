//! PJRT CPU engine: compile-on-first-use executable cache + the
//! [`PjRtEps`] adapter that makes a compiled denoiser artifact look like
//! any other [`EpsModel`].
//!
//! Threading: the `xla` crate's handles wrap raw PJRT C-API pointers and
//! are `!Send`. The engine serialises *all* PJRT access behind one
//! `Mutex` and is then declared `Send + Sync`: the PJRT CPU client has no
//! thread affinity (any thread may drive it, one at a time), which is the
//! same discipline a single dedicated engine thread would impose, without
//! forcing every caller through a channel hop.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::runtime::manifest::{DatasetEntry, Manifest};
use crate::runtime::resident::{
    ResidentOp, ResidentOutcome, ResidentSnapshot, ResidentState, ResidentTable,
};
// The registry closure ships no `xla` crate; the stub mirrors its API
// and fails at PjRtClient construction (see xla_stub.rs).
use crate::runtime::xla_stub as xla;
use crate::solvers::EpsModel;
use crate::tensor::Tensor;

/// Which artifact family an executable came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum Kind {
    Eps,
    Combine,
}

/// Interior (mutex-guarded) state: the client plus compiled executables.
struct Inner {
    client: xla::PjRtClient,
    /// (dataset, kind, bucket) -> compiled executable.
    cache: HashMap<(String, Kind, usize), xla::PjRtLoadedExecutable>,
}

/// PJRT CPU engine over one artifact tree.
pub struct PjRtEngine {
    manifest: Manifest,
    inner: Mutex<Inner>,
    /// Device-resident lane store (see [`crate::runtime::resident`]).
    /// Buffers live here, outside the PJRT mutex: resident kernel math
    /// never touches `inner`, only the eval inside an op does.
    resident: ResidentTable,
    evals: AtomicUsize,
    rows: AtomicUsize,
    compiles: AtomicUsize,
}

// SAFETY: every use of the !Send PJRT handles is serialised by
// `inner: Mutex<_>`; the PJRT CPU client is not thread-affine.
unsafe impl Send for PjRtEngine {}
unsafe impl Sync for PjRtEngine {}

impl PjRtEngine {
    /// Create an engine over `artifacts/` (validates the manifest and the
    /// schedule probe, but compiles nothing yet).
    pub fn new(artifacts_root: impl AsRef<Path>) -> Result<Self, String> {
        let manifest = Manifest::load(artifacts_root)?;
        let probe_err = manifest.schedule_probe_error();
        if probe_err > 1e-5 {
            return Err(format!(
                "schedule mirror deviates from python probe by {probe_err:e}"
            ));
        }
        let client =
            xla::PjRtClient::cpu().map_err(|e| format!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjRtEngine {
            manifest,
            inner: Mutex::new(Inner { client, cache: HashMap::new() }),
            resident: ResidentTable::new(),
            evals: AtomicUsize::new(0),
            rows: AtomicUsize::new(0),
            compiles: AtomicUsize::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Total artifact executions so far.
    pub fn eval_count(&self) -> usize {
        self.evals.load(Ordering::Relaxed)
    }

    /// Total (padded) rows pushed through artifacts.
    pub fn rows_executed(&self) -> usize {
        self.rows.load(Ordering::Relaxed)
    }

    /// Distinct executables compiled so far.
    pub fn compile_count(&self) -> usize {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Pre-compile the given buckets of a dataset's denoiser (serving
    /// startup does this so no request pays first-compile latency).
    pub fn warmup(&self, dataset: &str, buckets: &[usize]) -> Result<(), String> {
        for &b in buckets {
            self.with_exe(dataset, Kind::Eps, b, |_| Ok(()))?;
        }
        Ok(())
    }

    fn artifact_path(&self, dataset: &str, kind: Kind, bucket: usize) -> Result<String, String> {
        let d = self.manifest.dataset(dataset)?;
        let map = match kind {
            Kind::Eps => &d.eps,
            Kind::Combine => &d.combine,
        };
        let art = map.get(&bucket).ok_or_else(|| {
            format!("{dataset}: no {kind:?} artifact for bucket {bucket}")
        })?;
        Ok(self.manifest.resolve(art).display().to_string())
    }

    /// Run `f` with the compiled executable for (dataset, kind, bucket),
    /// compiling and caching it on first use.
    fn with_exe<R>(
        &self,
        dataset: &str,
        kind: Kind,
        bucket: usize,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<R, String>,
    ) -> Result<R, String> {
        let path = self.artifact_path(dataset, kind, bucket)?;
        let mut inner = self.inner.lock().unwrap();
        let key = (dataset.to_string(), kind, bucket);
        if !inner.cache.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| format!("load {path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                inner.client.compile(&comp).map_err(|e| format!("compile {path}: {e:?}"))?;
            self.compiles.fetch_add(1, Ordering::Relaxed);
            inner.cache.insert(key.clone(), exe);
        }
        f(inner.cache.get(&key).unwrap())
    }

    /// Evaluate the denoiser `eps_theta(x, t)` for a whole batch, with
    /// per-row times. Pads to the nearest compiled bucket and slices the
    /// padding back off; batches larger than the top bucket are split.
    pub fn eval_eps(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        assert_eq!(x.rows(), t.len(), "x rows / t length mismatch");
        let d = self.manifest.dataset(dataset)?;
        assert_eq!(x.cols(), d.dim, "dim mismatch for {dataset}");
        let top = *self.manifest.batch_buckets.last().unwrap();
        if x.rows() > top {
            // Split into top-bucket chunks.
            let mut parts: Vec<Tensor> = Vec::new();
            let mut start = 0;
            while start < x.rows() {
                let n = top.min(x.rows() - start);
                let part = x.slice_rows(start, n);
                let tpart = &t[start..start + n];
                parts.push(self.eval_eps(dataset, &part, tpart)?);
                start += n;
            }
            let refs: Vec<&Tensor> = parts.iter().collect();
            return Ok(Tensor::vstack(&refs));
        }

        let bucket = self.manifest.bucket_for(x.rows());
        let rows = x.rows();
        let dim = x.cols();

        // Pad x (replicating the final row keeps the network inputs
        // in-distribution; outputs beyond `rows` are discarded).
        let mut xbuf = Vec::with_capacity(bucket * dim);
        xbuf.extend_from_slice(x.as_slice());
        let mut tbuf = Vec::with_capacity(bucket);
        tbuf.extend_from_slice(t);
        for _ in rows..bucket {
            xbuf.extend_from_slice(x.row(rows - 1));
            tbuf.push(t[rows - 1]);
        }

        let out = self.with_exe(dataset, Kind::Eps, bucket, |exe| {
            let xl = xla::Literal::vec1(&xbuf)
                .reshape(&[bucket as i64, dim as i64])
                .map_err(|e| format!("reshape x: {e:?}"))?;
            let tl = xla::Literal::vec1(&tbuf);
            let res = exe
                .execute::<xla::Literal>(&[xl, tl])
                .map_err(|e| format!("execute eps: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| format!("to_literal: {e:?}"))?;
            let tup = res.to_tuple1().map_err(|e| format!("to_tuple1: {e:?}"))?;
            tup.to_vec::<f32>().map_err(|e| format!("to_vec: {e:?}"))
        })?;

        self.evals.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(bucket, Ordering::Relaxed);
        let full = Tensor::from_vec(out, bucket, dim);
        Ok(if rows == bucket { full } else { full.slice_rows(0, rows) })
    }

    /// Run the fused solver-update artifact:
    /// `out = ab[0] * x + ab[1] * sum_k w[k] * eps[k]` (zero-padded to the
    /// artifact's K_MAX). The in-process twin is
    /// [`Tensor::kernel_weighted_sum`]; an integration test pins them to
    /// each other.
    pub fn combine(
        &self,
        dataset: &str,
        eps: &[&Tensor],
        w: &[f64],
        x: &Tensor,
        ab: (f64, f64),
    ) -> Result<Tensor, String> {
        assert_eq!(eps.len(), w.len());
        let d = self.manifest.dataset(dataset)?;
        let k_max = d.k_max;
        assert!(eps.len() <= k_max, "k={} exceeds artifact K_MAX={k_max}", eps.len());
        let rows = x.rows();
        let dim = x.cols();
        let bucket = self.manifest.bucket_for(rows);
        if rows > *self.manifest.batch_buckets.last().unwrap() {
            return Err(format!("combine batch {rows} exceeds top bucket"));
        }

        // Stack + zero-pad the buffer to (K_MAX, bucket, dim).
        let mut buf = vec![0.0f32; k_max * bucket * dim];
        for (kidx, e) in eps.iter().enumerate() {
            assert_eq!((e.rows(), e.cols()), (rows, dim));
            let base = kidx * bucket * dim;
            buf[base..base + rows * dim].copy_from_slice(e.as_slice());
        }
        let mut wbuf = vec![0.0f32; k_max];
        for (i, &wi) in w.iter().enumerate() {
            wbuf[i] = wi as f32;
        }
        let mut xbuf = vec![0.0f32; bucket * dim];
        xbuf[..rows * dim].copy_from_slice(x.as_slice());
        let abv = [ab.0 as f32, ab.1 as f32];

        let out = self.with_exe(dataset, Kind::Combine, bucket, |exe| {
            let ebl = xla::Literal::vec1(&buf)
                .reshape(&[k_max as i64, bucket as i64, dim as i64])
                .map_err(|e| format!("reshape eps_buf: {e:?}"))?;
            let wl = xla::Literal::vec1(&wbuf);
            let xl = xla::Literal::vec1(&xbuf)
                .reshape(&[bucket as i64, dim as i64])
                .map_err(|e| format!("reshape x: {e:?}"))?;
            let al = xla::Literal::vec1(&abv);
            let res = exe
                .execute::<xla::Literal>(&[ebl, wl, xl, al])
                .map_err(|e| format!("execute combine: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| format!("to_literal: {e:?}"))?;
            let tup = res.to_tuple1().map_err(|e| format!("to_tuple1: {e:?}"))?;
            tup.to_vec::<f32>().map_err(|e| format!("to_vec: {e:?}"))
        })?;
        let full = Tensor::from_vec(out, bucket, dim);
        Ok(if rows == bucket { full } else { full.slice_rows(0, rows) })
    }

    /// Borrow a dataset's manifest entry.
    pub fn dataset(&self, name: &str) -> Result<&DatasetEntry, String> {
        self.manifest.dataset(name)
    }
}

// Residency: the engine keeps lane iterates and eps histories in its
// own table; each op's model call goes through `eval_eps` like any
// slab evaluation. `ModelBank::resident` for `PjRtEngine` (in
// `coordinator::service`) exposes this to the scheduler.
impl ResidentState for PjRtEngine {
    fn open(&self, dataset: &str, x: &Tensor, keep_history: bool) -> Result<u64, String> {
        self.manifest.dataset(dataset)?;
        Ok(self.resident.open(dataset, x, keep_history))
    }

    fn exec(&self, handle: u64, op: &ResidentOp) -> Result<ResidentOutcome, String> {
        self.resident.exec(handle, op, |ds, x, t| self.eval_eps(ds, x, t))
    }

    fn snapshot(&self, handle: u64) -> Result<ResidentSnapshot, String> {
        self.resident.snapshot(handle)
    }

    fn close(&self, handle: u64) {
        self.resident.close(handle)
    }
}

/// [`EpsModel`] adapter over one dataset's compiled denoiser. Holds the
/// engine by `Arc` so it can be handed to the coordinator's loop thread.
pub struct PjRtEps {
    engine: std::sync::Arc<PjRtEngine>,
    dataset: String,
    dim: usize,
}

impl PjRtEps {
    pub fn new(engine: &std::sync::Arc<PjRtEngine>, dataset: &str) -> Result<Self, String> {
        let dim = engine.dataset(dataset)?.dim;
        Ok(PjRtEps { engine: engine.clone(), dataset: dataset.to_string(), dim })
    }
}

impl EpsModel for PjRtEps {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        self.engine
            .eval_eps(&self.dataset, x, t)
            .unwrap_or_else(|e| panic!("PJRT eval failed ({}): {e}", self.dataset))
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_count(&self) -> usize {
        self.engine.eval_count()
    }
}

/// Handle for driving the fused solver-update artifact of one dataset
/// (used by the perf benches to compare against the native Rust path).
pub struct CombineExec {
    engine: std::sync::Arc<PjRtEngine>,
    dataset: String,
}

impl CombineExec {
    pub fn new(engine: &std::sync::Arc<PjRtEngine>, dataset: &str) -> Result<Self, String> {
        engine.dataset(dataset)?;
        Ok(CombineExec { engine: engine.clone(), dataset: dataset.to_string() })
    }

    pub fn run(
        &self,
        eps: &[&Tensor],
        w: &[f64],
        x: &Tensor,
        ab: (f64, f64),
    ) -> Result<Tensor, String> {
        self.engine.combine(&self.dataset, eps, w, x, ab)
    }
}
