//! Device-resident lane state: the engine side of the residency
//! protocol.
//!
//! In the slab path the scheduler ships a lane's full iterate to the
//! engine and receives a full eps tensor back on **every** solver step
//! — O(rows x dim) host traffic per step. The residency protocol keeps
//! the iterate and the eps history in engine-owned buffers across
//! steps: after a one-time [`ResidentState::open`] upload, each step
//! sends only a [`ResidentOp`] (a handful of plan coefficients and
//! buffer indices) and receives a [`ResidentOutcome`] (per-row eps
//! distances, and the final iterate only on [`ResidentOp::Finish`]).
//! Per-step traffic is O(1) in the tensor dimension.
//!
//! Correctness contract: every kernel application here goes through
//! the *same* [`crate::kernels::fused`] wrappers the host-side lane
//! engine uses, in the same order, so a resident lane's iterate is
//! bitwise-identical to the host path's — with `simd` on or off. The
//! scheduler can therefore [`ResidentState::snapshot`] a lane at any
//! idle point and devolve it back to host stepping (for
//! split-on-divergence, member compaction, or mid-flight cancel)
//! without perturbing the trajectory. See DESIGN.md ("Kernel dispatch
//! tiers and the residency protocol").

use std::collections::HashMap;
use std::sync::Mutex;

use crate::kernels::fused;
use crate::tensor::Tensor;

/// One in-place advance of a resident iterate: `x = a*x + b*eps_c`.
///
/// Coefficients are `f64` (the plan's native dtype) and narrowed to
/// f32 at the kernel boundary, exactly where the host path narrows.
pub enum ResidentAdvance {
    /// DDIM / ERA-warmup update against the newest eps buffer.
    Newest { a: f64, b: f64 },
    /// Full ERA update: Lagrange predictor over the eps buffers named
    /// by `idx` with weights `w`, folded through the Adams–Moulton
    /// corrector weights `amw` (`amw[0]` scales the predictor,
    /// `amw[1 + m]` scales eps buffer `n - 1 - m`).
    Lagrange { a: f64, b: f64, idx: Vec<usize>, w: Vec<f64>, amw: Vec<f64> },
}

/// One resident solver step: optional pre-advance, then a model
/// evaluation at `t`, then an optional post-advance.
///
/// ERA lanes use `pre` (advance with the history, then evaluate at the
/// new grid point); DDIM lanes use `post` (evaluate, then advance with
/// the fresh eps) so the engine iterate equals the host iterate at
/// every idle point and devolution never has to replay a lagging
/// update.
pub struct ResidentStep {
    pub pre: Option<ResidentAdvance>,
    /// Evaluation time, already narrowed to the model's f32.
    pub t: f32,
    pub post: Option<ResidentAdvance>,
}

/// A scheduler-to-engine command for one resident lane.
pub enum ResidentOp {
    Step(ResidentStep),
    /// Apply the optional last advance, return the final iterate, and
    /// drop the lane's engine-side state.
    Finish { advance: Option<ResidentAdvance> },
}

/// What the engine sends back for one [`ResidentOp`].
pub struct ResidentOutcome {
    pub handle: u64,
    pub rows: usize,
    /// Per-row L2 distance between the fresh eps and the Lagrange
    /// prediction (empty unless the step's pre-advance was
    /// [`ResidentAdvance::Lagrange`]). Same fold as
    /// [`fused::row_l2_dists_into`], so host-side per-member means
    /// reproduce [`fused::mean_row_dist`] bitwise.
    pub row_dists: Vec<f64>,
    /// The final iterate; `Some` only for [`ResidentOp::Finish`].
    pub final_x: Option<Tensor>,
}

/// A full gather of a resident lane's state, used to devolve the lane
/// back to host stepping.
pub struct ResidentSnapshot {
    pub x: Tensor,
    pub eps: Vec<Tensor>,
}

/// The residency protocol surface a [`crate::coordinator::ModelBank`]
/// may expose. Engines without resident buffers simply don't, and the
/// scheduler stays on the slab path.
pub trait ResidentState: Send + Sync {
    /// Upload `x` and open a resident lane. `keep_history` retains
    /// every eps (ERA); otherwise only the newest survives (DDIM).
    fn open(&self, dataset: &str, x: &Tensor, keep_history: bool) -> Result<u64, String>;
    /// Execute one op. [`ResidentOp::Finish`] consumes the handle.
    fn exec(&self, handle: u64, op: &ResidentOp) -> Result<ResidentOutcome, String>;
    /// Gather the lane's full state (the lane stays open).
    fn snapshot(&self, handle: u64) -> Result<ResidentSnapshot, String>;
    /// Drop the lane's engine-side state. Idempotent.
    fn close(&self, handle: u64);
}

/// Engine-side buffers of one resident lane.
struct LaneState {
    dataset: String,
    x: Tensor,
    eps: Vec<Tensor>,
    /// Lagrange-predictor scratch; allocated on first ERA step and
    /// reused (it also backs the row-distance comparison).
    pred: Option<Tensor>,
    /// Corrector combination scratch.
    comb: Tensor,
    keep_history: bool,
}

#[derive(Default)]
struct TableInner {
    next: u64,
    lanes: HashMap<u64, LaneState>,
}

/// Host-memory reference implementation of the resident-lane store.
///
/// `PjRtEngine` and `MockBank` both embed one: the protocol's win is
/// eliminating the per-step scheduler<->engine tensor hand-off (and on
/// a device runtime, the host<->device copies behind it), which this
/// table models faithfully — ops in, scalars out, tensors only at
/// open/snapshot/finish.
pub struct ResidentTable {
    inner: Mutex<TableInner>,
}

impl Default for ResidentTable {
    fn default() -> Self {
        ResidentTable::new()
    }
}

impl ResidentTable {
    pub fn new() -> ResidentTable {
        ResidentTable { inner: Mutex::new(TableInner::default()) }
    }

    /// Number of open resident lanes (test/diagnostic aid).
    pub fn open_lanes(&self) -> usize {
        self.inner.lock().unwrap().lanes.len()
    }

    pub fn open(&self, dataset: &str, x: &Tensor, keep_history: bool) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.next += 1;
        let handle = inner.next;
        let comb = Tensor::zeros(x.rows(), x.cols());
        inner.lanes.insert(
            handle,
            LaneState {
                dataset: dataset.to_string(),
                x: x.clone(),
                eps: Vec::new(),
                pred: None,
                comb,
                keep_history,
            },
        );
        handle
    }

    /// Execute one op, using `eval` for the model call. The lock is
    /// held across the evaluation: resident ops for one handle are
    /// strictly sequential anyway (the scheduler never has two in
    /// flight), and cross-lane contention only occurs when several
    /// executors run resident ops at once.
    pub fn exec(
        &self,
        handle: u64,
        op: &ResidentOp,
        eval: impl Fn(&str, &Tensor, &[f32]) -> Result<Tensor, String>,
    ) -> Result<ResidentOutcome, String> {
        let mut inner = self.inner.lock().unwrap();
        match op {
            ResidentOp::Step(step) => {
                let lane = inner
                    .lanes
                    .get_mut(&handle)
                    .ok_or_else(|| format!("resident lane {handle} not open"))?;
                if let Some(adv) = &step.pre {
                    apply_advance(lane, adv)?;
                }
                let rows = lane.x.rows();
                let ts = vec![step.t; rows];
                let eps_new = eval(&lane.dataset, &lane.x, &ts)?;
                if eps_new.rows() != rows || eps_new.cols() != lane.x.cols() {
                    return Err(format!(
                        "resident eval returned {}x{} for a {}x{} lane",
                        eps_new.rows(),
                        eps_new.cols(),
                        rows,
                        lane.x.cols()
                    ));
                }
                let mut row_dists = Vec::new();
                if matches!(&step.pre, Some(ResidentAdvance::Lagrange { .. })) {
                    let pred = lane.pred.as_ref().expect("lagrange pre-advance set pred");
                    fused::row_l2_dists_into(
                        eps_new.as_slice(),
                        pred.as_slice(),
                        rows,
                        lane.x.cols(),
                        &mut row_dists,
                    );
                }
                if !lane.keep_history {
                    lane.eps.clear();
                }
                lane.eps.push(eps_new);
                if let Some(adv) = &step.post {
                    apply_advance(lane, adv)?;
                }
                Ok(ResidentOutcome { handle, rows, row_dists, final_x: None })
            }
            ResidentOp::Finish { advance } => {
                let mut lane = inner
                    .lanes
                    .remove(&handle)
                    .ok_or_else(|| format!("resident lane {handle} not open"))?;
                if let Some(adv) = advance {
                    apply_advance(&mut lane, adv)?;
                }
                let rows = lane.x.rows();
                Ok(ResidentOutcome { handle, rows, row_dists: Vec::new(), final_x: Some(lane.x) })
            }
        }
    }

    pub fn snapshot(&self, handle: u64) -> Result<ResidentSnapshot, String> {
        let inner = self.inner.lock().unwrap();
        let lane = inner
            .lanes
            .get(&handle)
            .ok_or_else(|| format!("resident lane {handle} not open"))?;
        Ok(ResidentSnapshot { x: lane.x.clone(), eps: lane.eps.clone() })
    }

    pub fn close(&self, handle: u64) {
        self.inner.lock().unwrap().lanes.remove(&handle);
    }
}

/// Apply one advance to a lane's buffers, replicating the host lane
/// engine's kernel sequence exactly (same wrappers, same order, same
/// f64->f32 narrowing points) so resident iterates stay bitwise equal
/// to host iterates.
fn apply_advance(lane: &mut LaneState, adv: &ResidentAdvance) -> Result<(), String> {
    let LaneState { x, eps, pred, comb, .. } = lane;
    match adv {
        ResidentAdvance::Newest { a, b } => {
            let newest = eps.last().ok_or("resident Newest advance with empty eps history")?;
            fused::affine_inplace(x.as_mut_slice(), *a as f32, *b as f32, newest.as_slice());
        }
        ResidentAdvance::Lagrange { a, b, idx, w, amw } => {
            let n = eps.len();
            if idx.len() != w.len() || amw.is_empty() || amw.len() - 1 > n {
                return Err("malformed resident Lagrange advance".into());
            }
            if idx.iter().any(|&j| j >= n) {
                return Err(format!("resident Lagrange index out of range (history {n})"));
            }
            let p = pred.get_or_insert_with(|| Tensor::zeros(x.rows(), x.cols()));
            fused::zero(p.as_mut_slice());
            for (&j, &wj) in idx.iter().zip(w.iter()) {
                fused::axpy(p.as_mut_slice(), wj as f32, eps[j].as_slice());
            }
            fused::zero(comb.as_mut_slice());
            fused::axpy(comb.as_mut_slice(), amw[0] as f32, p.as_slice());
            for back in 0..amw.len() - 1 {
                let cw = amw[back + 1] as f32;
                fused::axpy(comb.as_mut_slice(), cw, eps[n - 1 - back].as_slice());
            }
            fused::affine_inplace(x.as_mut_slice(), *a as f32, *b as f32, comb.as_slice());
        }
    }
    Ok(())
}

/// Host bytes a tensor hand-off costs (f32 payload).
pub fn tensor_bytes(t: &Tensor) -> u64 {
    (t.len() * 4) as u64
}

/// Host bytes one resident op costs on the wire: coefficients and
/// indices only — independent of rows and dim.
pub fn op_bytes(op: &ResidentOp) -> u64 {
    fn adv(a: &Option<ResidentAdvance>) -> u64 {
        match a {
            None => 0,
            Some(ResidentAdvance::Newest { .. }) => 16,
            Some(ResidentAdvance::Lagrange { idx, w, amw, .. }) => {
                16 + 8 * (idx.len() + w.len() + amw.len()) as u64
            }
        }
    }
    match op {
        ResidentOp::Step(s) => 4 + adv(&s.pre) + adv(&s.post),
        ResidentOp::Finish { advance } => adv(advance),
    }
}

/// Host bytes one resident outcome costs: per-row distances (O(rows),
/// dim-independent) plus the final iterate on finish.
pub fn outcome_bytes(o: &ResidentOutcome) -> u64 {
    let mut b = 16 + 8 * o.row_dists.len() as u64;
    if let Some(x) = &o.final_x {
        b += tensor_bytes(x);
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(seed: u64, rows: usize, cols: usize) -> Tensor {
        let mut state = seed;
        let mut v = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            v.push(((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5);
        }
        Tensor::from_vec(v, rows, cols)
    }

    fn echo_eval(_: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        // Deterministic stand-in model: eps = 0.5*x + t.
        let mut out = x.clone();
        for (r, &tv) in t.iter().enumerate() {
            for v in out.row_mut(r) {
                *v = 0.5 * *v + tv;
            }
        }
        Ok(out)
    }

    #[test]
    fn newest_post_advance_matches_host_sequence() {
        let table = ResidentTable::new();
        let x0 = tensor(1, 3, 4);
        let h = table.open("d", &x0, false);
        let op = ResidentOp::Step(ResidentStep {
            pre: None,
            t: 0.7,
            post: Some(ResidentAdvance::Newest { a: 0.9, b: -0.2 }),
        });
        let out = table.exec(h, &op, echo_eval).unwrap();
        assert_eq!(out.rows, 3);
        assert!(out.row_dists.is_empty());
        assert!(out.final_x.is_none());

        // Host replay: eval then affine_inplace with the same wrappers.
        let mut host = x0.clone();
        let eps = echo_eval("d", &host, &[0.7; 3]).unwrap();
        fused::affine_inplace(host.as_mut_slice(), 0.9, -0.2, eps.as_slice());
        let snap = table.snapshot(h).unwrap();
        assert_eq!(snap.x.as_slice(), host.as_slice());
        assert_eq!(snap.eps.len(), 1); // keep_history=false retains only newest
        table.close(h);
        assert_eq!(table.open_lanes(), 0);
    }

    #[test]
    fn lagrange_advance_is_bitwise_equal_to_host_kernels() {
        let table = ResidentTable::new();
        let x0 = tensor(2, 4, 5);
        let h = table.open("d", &x0, true);
        // Build three eps buffers with plain steps first.
        for (i, t) in [0.9f32, 0.6, 0.4].iter().enumerate() {
            let op = ResidentOp::Step(ResidentStep { pre: None, t: *t, post: None });
            let out = table.exec(h, &op, echo_eval).unwrap();
            assert_eq!(out.rows, 4);
            assert_eq!(table.snapshot(h).unwrap().eps.len(), i + 1);
        }
        let idx = vec![2usize, 1, 0];
        let w = vec![0.5f64, 0.3, 0.2];
        let amw = vec![0.7f64, 0.2, 0.1];
        let (a, b) = (0.95f64, -0.15f64);
        let op = ResidentOp::Step(ResidentStep {
            pre: Some(ResidentAdvance::Lagrange {
                a,
                b,
                idx: idx.clone(),
                w: w.clone(),
                amw: amw.clone(),
            }),
            t: 0.2,
            post: None,
        });
        let out = table.exec(h, &op, echo_eval).unwrap();
        assert_eq!(out.row_dists.len(), 4);

        // Host replay of the whole trajectory with the same wrappers.
        let mut hx = x0.clone();
        let mut heps = Vec::new();
        for t in [0.9f32, 0.6, 0.4] {
            heps.push(echo_eval("d", &hx, &vec![t; 4]).unwrap());
        }
        let mut pred = Tensor::zeros(4, 5);
        for (&j, &wj) in idx.iter().zip(w.iter()) {
            fused::axpy(pred.as_mut_slice(), wj as f32, heps[j].as_slice());
        }
        let mut comb = Tensor::zeros(4, 5);
        fused::axpy(comb.as_mut_slice(), amw[0] as f32, pred.as_slice());
        for back in 0..amw.len() - 1 {
            let n = heps.len();
            fused::axpy(comb.as_mut_slice(), amw[back + 1] as f32, heps[n - 1 - back].as_slice());
        }
        fused::affine_inplace(hx.as_mut_slice(), a as f32, b as f32, comb.as_slice());
        let eps_new = echo_eval("d", &hx, &[0.2; 4]).unwrap();
        let mut hdists = Vec::new();
        fused::row_l2_dists_into(eps_new.as_slice(), pred.as_slice(), 4, 5, &mut hdists);
        for (got, want) in out.row_dists.iter().zip(hdists.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        let snap = table.snapshot(h).unwrap();
        assert_eq!(snap.x.as_slice(), hx.as_slice());
        assert_eq!(snap.eps.len(), 4);
        table.close(h);
    }

    #[test]
    fn finish_returns_final_iterate_and_consumes_the_handle() {
        let table = ResidentTable::new();
        let x0 = tensor(3, 2, 3);
        let h = table.open("d", &x0, false);
        let step = ResidentOp::Step(ResidentStep { pre: None, t: 0.5, post: None });
        table.exec(h, &step, echo_eval).unwrap();
        let adv = Some(ResidentAdvance::Newest { a: 0.8, b: 0.1 });
        let out = table.exec(h, &ResidentOp::Finish { advance: adv }, echo_eval).unwrap();
        let fx = out.final_x.expect("finish returns x");
        let mut host = x0.clone();
        let eps = echo_eval("d", &host, &[0.5; 2]).unwrap();
        fused::affine_inplace(host.as_mut_slice(), 0.8, 0.1, eps.as_slice());
        assert_eq!(fx.as_slice(), host.as_slice());
        assert!(table.exec(h, &ResidentOp::Finish { advance: None }, echo_eval).is_err());
        assert_eq!(table.open_lanes(), 0);
    }

    #[test]
    fn malformed_lagrange_is_an_error_not_a_panic() {
        let table = ResidentTable::new();
        let h = table.open("d", &tensor(4, 2, 2), true);
        let bad = ResidentOp::Step(ResidentStep {
            pre: Some(ResidentAdvance::Lagrange {
                a: 1.0,
                b: 0.0,
                idx: vec![3],
                w: vec![1.0],
                amw: vec![1.0],
            }),
            t: 0.5,
            post: None,
        });
        assert!(table.exec(h, &bad, echo_eval).is_err());
        table.close(h);
    }

    #[test]
    fn wire_cost_is_dimension_independent() {
        let step = ResidentOp::Step(ResidentStep {
            pre: Some(ResidentAdvance::Lagrange {
                a: 1.0,
                b: 0.0,
                idx: vec![0, 1, 2, 3],
                w: vec![0.25; 4],
                amw: vec![0.5; 4],
            }),
            t: 0.5,
            post: None,
        });
        // 4 + (16 + 8*12) coefficient bytes, regardless of lane shape.
        assert_eq!(op_bytes(&step), 116);
        let out =
            ResidentOutcome { handle: 1, rows: 1024, row_dists: vec![0.0; 1024], final_x: None };
        assert_eq!(outcome_bytes(&out), 16 + 8 * 1024);
        let big = tensor(5, 8, 1 << 12);
        assert_eq!(tensor_bytes(&big), (8 * (1 << 12) * 4) as u64);
    }
}
