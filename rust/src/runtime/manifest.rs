//! Artifact registry: `artifacts/manifest.json` parsing + validation.
//!
//! The manifest is the contract between the build-time Python layers and
//! the Rust runtime. Everything the runtime needs to serve a dataset is
//! described here: which batch buckets were compiled, where each HLO
//! artifact lives, the noise schedule the model was trained under (with
//! probe values so the Rust mirror can be cross-checked to float
//! precision), and the reference moments used for FID.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::json::{self, Json};
use crate::metrics::Moments;
use crate::solvers::schedule::VpSchedule;

/// Supported manifest schema version (bump in lockstep with aot.py).
pub const MANIFEST_VERSION: usize = 3;

/// One artifact file: path relative to the artifacts root + content hash.
#[derive(Clone, Debug)]
pub struct ArtifactRef {
    pub path: PathBuf,
    pub sha: String,
}

/// Everything built for one dataset.
#[derive(Clone, Debug)]
pub struct DatasetEntry {
    pub name: String,
    pub dim: usize,
    /// Which paper dataset this synthetic manifold stands in for.
    pub stands_in_for: String,
    pub final_loss: f64,
    /// Denoiser artifacts per batch bucket.
    pub eps: BTreeMap<usize, ArtifactRef>,
    /// Fused solver-update artifacts per batch bucket.
    pub combine: BTreeMap<usize, ArtifactRef>,
    /// Max interpolation order the combine kernel was compiled for.
    pub k_max: usize,
    /// Reference moments of the data distribution (for FID).
    pub ref_stats: Moments,
    pub ref_n: usize,
}

/// Schedule probe: (t, alpha_bar, log_snr) triples from the Python side.
#[derive(Clone, Debug, Default)]
pub struct ScheduleProbe {
    pub t: Vec<f64>,
    pub alpha_bar: Vec<f64>,
    pub log_snr: Vec<f64>,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: usize,
    pub root: PathBuf,
    pub schedule: VpSchedule,
    pub probe: ScheduleProbe,
    pub batch_buckets: Vec<usize>,
    pub datasets: BTreeMap<String, DatasetEntry>,
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest, String> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts`?)", path.display()))?;
        let j = json::parse(&text).map_err(|e| format!("parse {}: {e:?}", path.display()))?;
        Self::from_json(&j, root)
    }

    pub fn from_json(j: &Json, root: PathBuf) -> Result<Manifest, String> {
        let version = j.get("version").as_usize().ok_or("missing version")?;
        if version != MANIFEST_VERSION {
            return Err(format!(
                "manifest version {version} != supported {MANIFEST_VERSION}; \
                 rebuild with `make artifacts`"
            ));
        }
        let sched_j = j.get("schedule");
        if sched_j.get("kind").as_str() != Some("vp") {
            return Err("unsupported schedule kind".into());
        }
        let schedule = VpSchedule::new(
            sched_j.get("beta_min").as_f64().ok_or("beta_min")?,
            sched_j.get("beta_max").as_f64().ok_or("beta_max")?,
        );
        let probe_j = sched_j.get("probe");
        let probe = ScheduleProbe {
            t: probe_j.get("t").as_f64_vec().unwrap_or_default(),
            alpha_bar: probe_j.get("alpha_bar").as_f64_vec().unwrap_or_default(),
            log_snr: probe_j.get("log_snr").as_f64_vec().unwrap_or_default(),
        };
        let batch_buckets: Vec<usize> = j
            .get("batch_buckets")
            .as_arr()
            .ok_or("batch_buckets")?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        if batch_buckets.is_empty() || batch_buckets.windows(2).any(|w| w[0] >= w[1]) {
            return Err("batch_buckets must be non-empty and ascending".into());
        }

        let mut datasets = BTreeMap::new();
        let ds_obj = j.get("datasets").as_obj().ok_or("datasets")?;
        for (name, d) in ds_obj {
            datasets.insert(name.clone(), parse_dataset(name, d)?);
        }
        Ok(Manifest { version, root, schedule, probe, batch_buckets, datasets })
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetEntry, String> {
        self.datasets.get(name).ok_or_else(|| {
            format!(
                "dataset '{name}' not in manifest (have: {})",
                self.datasets.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Smallest compiled bucket that fits `rows`, or the largest bucket
    /// if nothing fits (the caller then splits the batch).
    pub fn bucket_for(&self, rows: usize) -> usize {
        for &b in &self.batch_buckets {
            if rows <= b {
                return b;
            }
        }
        *self.batch_buckets.last().unwrap()
    }

    /// Absolute path of an artifact.
    pub fn resolve(&self, art: &ArtifactRef) -> PathBuf {
        self.root.join(&art.path)
    }

    /// Cross-check the Rust schedule mirror against the Python probe.
    /// Returns the max |alpha_bar| deviation.
    pub fn schedule_probe_error(&self) -> f64 {
        self.probe
            .t
            .iter()
            .zip(&self.probe.alpha_bar)
            .map(|(&t, &ab)| (self.schedule.alpha_bar(t) - ab).abs())
            .fold(0.0, f64::max)
    }
}

fn parse_artifact_map(j: &Json) -> Result<BTreeMap<usize, ArtifactRef>, String> {
    let obj = j.as_obj().ok_or("artifact map not an object")?;
    let mut out = BTreeMap::new();
    for (bucket, v) in obj {
        let b: usize = bucket.parse().map_err(|_| format!("bad bucket key {bucket}"))?;
        let path = v.get("path").as_str().ok_or("artifact path")?;
        let sha = v.get("sha").as_str().unwrap_or("").to_string();
        out.insert(b, ArtifactRef { path: PathBuf::from(path), sha });
    }
    Ok(out)
}

fn parse_dataset(name: &str, d: &Json) -> Result<DatasetEntry, String> {
    let dim = d.get("dim").as_usize().ok_or("dim")?;
    let rs = d.get("ref_stats");
    let mean = rs.get("mean").as_f64_vec().ok_or("ref mean")?;
    let cov = rs.get("cov").as_f64_vec().ok_or("ref cov")?;
    if mean.len() != dim || cov.len() != dim * dim {
        return Err(format!("{name}: ref_stats shape mismatch (dim {dim})"));
    }
    Ok(DatasetEntry {
        name: name.to_string(),
        dim,
        stands_in_for: d.get("stands_in_for").as_str().unwrap_or("").to_string(),
        final_loss: d.get("final_loss").as_f64().unwrap_or(f64::NAN),
        eps: parse_artifact_map(d.get("eps"))?,
        combine: parse_artifact_map(d.get("combine"))?,
        k_max: d.get("k_max").as_usize().ok_or("k_max")?,
        ref_stats: Moments::new(mean, cov),
        ref_n: rs.get("n").as_usize().unwrap_or(0),
    })
}

/// Per-dataset training report (loss + the Fig. 1 noise-error curve).
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub dataset: String,
    pub final_loss: f64,
    /// (t, mean ||eps - eps_hat||) pairs, t ascending — the paper's Fig. 1.
    pub error_curve: Vec<(f64, f64)>,
}

impl TrainReport {
    pub fn load(root: impl AsRef<Path>, dataset: &str) -> Result<TrainReport, String> {
        let path = root.as_ref().join(dataset).join("train_report.json");
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = json::parse(&text).map_err(|e| format!("parse train_report: {e:?}"))?;
        let ec = j.get("error_curve");
        let ts = ec.get("t").as_f64_vec().ok_or("error_curve.t")?;
        let es = ec.get("err").as_f64_vec().ok_or("error_curve.err")?;
        Ok(TrainReport {
            dataset: dataset.to_string(),
            final_loss: j.get("final_loss").as_f64().unwrap_or(f64::NAN),
            error_curve: ts.into_iter().zip(es).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest_json() -> String {
        r#"{
          "version": 3,
          "schedule": {"kind": "vp", "beta_min": 0.1, "beta_max": 20.0,
                       "probe": {"t": [0.5], "alpha_bar": [0.07906381245316065], "log_snr": [-2.455]}},
          "batch_buckets": [1, 16],
          "datasets": {
            "toy": {
              "dim": 2,
              "stands_in_for": "CIFAR-10",
              "final_loss": 0.5,
              "eps": {"1": {"path": "toy/eps_b1.hlo.txt", "sha": "aa"},
                      "16": {"path": "toy/eps_b16.hlo.txt", "sha": "bb"}},
              "combine": {"1": {"path": "toy/combine_b1.hlo.txt", "sha": "cc"}},
              "k_max": 8,
              "ref_stats": {"n": 10, "mean": [0.0, 0.0], "cov": [1.0, 0.0, 0.0, 1.0]}
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_tiny_manifest() {
        let j = json::parse(&tiny_manifest_json()).unwrap();
        let m = Manifest::from_json(&j, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.version, 3);
        assert_eq!(m.batch_buckets, vec![1, 16]);
        let d = m.dataset("toy").unwrap();
        assert_eq!(d.dim, 2);
        assert_eq!(d.eps.len(), 2);
        assert_eq!(d.stands_in_for, "CIFAR-10");
        assert!(m.dataset("nope").is_err());
    }

    #[test]
    fn bucket_selection() {
        let j = json::parse(&tiny_manifest_json()).unwrap();
        let m = Manifest::from_json(&j, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(2), 16);
        assert_eq!(m.bucket_for(16), 16);
        assert_eq!(m.bucket_for(400), 16); // caller splits
    }

    #[test]
    fn probe_error_small() {
        let j = json::parse(&tiny_manifest_json()).unwrap();
        let m = Manifest::from_json(&j, PathBuf::from("/tmp")).unwrap();
        assert!(m.schedule_probe_error() < 1e-6, "{}", m.schedule_probe_error());
    }

    #[test]
    fn rejects_wrong_version() {
        let text = tiny_manifest_json().replace("\"version\": 3", "\"version\": 2");
        let j = json::parse(&text).unwrap();
        assert!(Manifest::from_json(&j, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_unsorted_buckets() {
        let text = tiny_manifest_json().replace("[1, 16]", "[16, 1]");
        let j = json::parse(&text).unwrap();
        assert!(Manifest::from_json(&j, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // Integration-level check against the actual artifacts when they
        // exist (`make artifacts`); skipped silently otherwise so unit
        // runs don't depend on the build.
        let root = std::path::Path::new("artifacts");
        if !root.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(root).unwrap();
        assert!(m.schedule_probe_error() < 1e-5);
        for (name, d) in &m.datasets {
            for b in m.batch_buckets.iter() {
                assert!(d.eps.contains_key(b), "{name} missing eps bucket {b}");
            }
        }
    }
}
