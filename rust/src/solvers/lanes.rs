//! Batch-major solver **lanes**: struct-of-arrays stepping for the
//! serving hot path.
//!
//! The coordinator used to step one boxed [`Solver`] per request per
//! round — per-request virtual dispatch, scattered history rings, and
//! row-at-a-time fused-kernel calls. But every solver update in this
//! crate is a coefficient-weighted elementwise combination whose
//! scalars depend only on `(solver kind, plan, step index)` — never on
//! the row values — so requests sharing those can be stacked into one
//! contiguous tensor and advanced by a *single* pass of the same fused
//! kernels. That is exactly the shape DPM-Solver and SA-Solver exploit
//! for their precomputed coefficient schedules, applied across
//! requests instead of within one.
//!
//! A [`Lane`] groups co-resident requests keyed by `(dataset,
//! [`SolverKind`], plan identity, suffix base, guided-ness)` and holds
//! struct-of-arrays state: one stacked iterate `x`, stacked eps
//! history, per-member RNG cursors and per-member ERA selection state.
//! `step` + `deliver` advance *all* members at once. Per-member scalars
//! that are genuinely per-request stay per-member and provably cannot
//! change batch-mates' bits, because every kernel is row-local:
//!
//! * DDPM ancestral noise and stochastic-ERA churn draw from each
//!   member's own stream into that member's row span;
//! * classifier-free guidance combines each member's paired rows with
//!   that member's scale;
//! * ERA's error measure (Eq. 15) is computed per member over its row
//!   span, and when members' error-robust selections (Eq. 16/17)
//!   diverge, the minority groups **split off into sibling lanes**
//!   (gathered rows, gathered history) rather than falling back to
//!   scalar stepping — each resulting lane is again uniform and steps
//!   with one fused pass.
//!
//! Membership changes compact the stacked state: retiring one member
//! removes its row span from every live tensor with one `memmove`
//! each, leaving every surviving member's bytes — iterate, history,
//! RNG cursor — untouched (the compaction invariant pinned by the
//! lane-engine golden tests and proptests). A pending evaluation is
//! regenerated after compaction from the compacted state; every
//! kind's request-building step is idempotent, so the regenerated
//! request is bit-identical for survivors.
//!
//! The [`Solver`] trait remains the reference implementation: the
//! lane-engine trajectories are pinned bitwise against it for every
//! kind in `tests/lane_engine.rs`.
//!
//! [`Solver`]: crate::solvers::Solver

use std::collections::HashMap;
use std::sync::Arc;

use crate::kernels::{fused, PlanView, TensorPool};
use crate::rng::Rng;
use crate::runtime::resident::{
    ResidentAdvance, ResidentOp, ResidentOutcome, ResidentSnapshot, ResidentStep,
};
use crate::solvers::adams_explicit::{drift_into, AB4};
use crate::solvers::ddpm::ANCESTRAL_STREAM;
use crate::solvers::era::{select_indices_guarded, Selection, CHURN_STREAM};
use crate::solvers::{EvalRequest, SolverKind, UNCOND};
use crate::tensor::Tensor;

/// Consecutive scored steps whose relative `delta_eps` change must sit
/// below a member's threshold before the convergence controller
/// retires it (the "short window" of the trend predicate).
const CONV_WINDOW: u8 = 2;

/// Everything admission resolves before a request enters a lane — the
/// lane-engine twin of building a boxed solver from a
/// [`crate::solvers::TaskResolution`].
pub struct LaneAdmission {
    pub kind: SolverKind,
    /// `None` = zero-transition request (`strength = 0`): `x` is final.
    pub view: Option<PlanView>,
    /// Start iterate (`n_samples x dim`).
    pub x: Tensor,
    /// Stochastic-ERA churn level (0 = deterministic).
    pub churn: f64,
    /// Classifier-free guidance `(scale, class)` when requested.
    pub guided: Option<(f32, usize)>,
    /// Request seed (feeds the member's ancestral/churn stream).
    pub seed: u64,
    /// Convergence-controller threshold on the relative `delta_eps`
    /// change (0 = controller disabled; the fixed-NFE path is then
    /// bitwise untouched). ERA lanes only.
    pub conv_threshold: f64,
    /// NFE floor for early stop / QoS degradation (0 = no floor beyond
    /// the solver's structural minimum).
    pub min_nfe: usize,
}

/// One request's row group inside a lane.
pub struct Member {
    /// Scheduler slot id of the owning request.
    pub slot: usize,
    /// State-row offset within the lane's stacked tensors.
    pub start: usize,
    /// State rows (`n_samples`).
    pub rows: usize,
    /// Network evaluations consumed so far (paired evals count 2).
    pub nfe: usize,
    /// ERA error measure (Eq. 15); selection-dependent init.
    pub delta_eps: f64,
    churn: f64,
    scale: f32,
    class: usize,
    rng: Rng,
    /// Convergence controller (row-local; never touches lane numerics).
    /// Relative-change threshold on `delta_eps` (0 = disabled).
    conv_threshold: f64,
    /// Early-stop NFE floor (already folded with the solver minimum).
    min_nfe: usize,
    /// `delta_eps` at the previous scored step (NaN = none yet).
    prev_delta: f64,
    /// Consecutive scored steps with relative change below threshold.
    conv_streak: u8,
    /// QoS degradation latch: finish as soon as `nfe >= min_nfe`.
    degraded: bool,
}

/// A retired member's outcome, handed back to the scheduler.
pub struct Removed {
    pub slot: usize,
    /// The member's rows of the lane iterate at retirement.
    pub samples: Tensor,
    pub nfe: usize,
    /// Last error measure — ERA lanes only.
    pub delta_eps: Option<f64>,
    /// Retired by the convergence controller before exhausting its NFE
    /// budget (the delivered iterate took the closing DDIM jump).
    pub early_stop: bool,
}

/// Lane identity: members must agree on all of this to step together.
#[derive(Clone, PartialEq)]
struct LaneKey {
    dataset: String,
    kind: SolverKind,
    /// `Arc::as_ptr` of the shared plan (0 for zero-transition lanes).
    plan: usize,
    /// Suffix base of the view (`usize::MAX` for zero-transition lanes).
    base: usize,
    guided: bool,
}

#[derive(Clone, Copy, PartialEq)]
enum WarmStage {
    S1,
    S2,
    S3,
    S4,
    Multi,
}

#[derive(Clone, Copy, PartialEq)]
enum KindTag {
    Noop,
    Ddim,
    Ddpm,
    Iadams,
    Explicit,
    Dpm,
    Era,
}

/// Per-kind stacked stepping state. Tensors are stacked over member
/// rows; scalars are lane-uniform. Mirrors the per-request solvers'
/// fields and update order exactly (the bitwise-equivalence contract).
#[allow(clippy::large_enum_variant)]
enum Kernel {
    Noop,
    Ddim {
        i: usize,
    },
    Ddpm {
        i: usize,
        /// Ancestral-noise scratch, refilled per member span each step.
        z: Tensor,
    },
    Iadams {
        i: usize,
        /// Newest-first eps history (<= 4 stacked entries).
        hist: Vec<Tensor>,
        comb: Tensor,
        x_pred: Arc<Tensor>,
    },
    Explicit {
        fon: bool,
        i: usize,
        stage: WarmStage,
        /// Newest-first slope history (<= 4 stacked entries).
        hist: Vec<Tensor>,
        rk: Vec<Tensor>,
        x_base: Option<Arc<Tensor>>,
        combo: Tensor,
        drift: Tensor,
        /// Warmup stage-point scratch.
        u: Arc<Tensor>,
    },
    Dpm {
        i: usize,
        stage: u8,
        e0: Option<Tensor>,
        e1: Option<Tensor>,
        u: Arc<Tensor>,
    },
    Era {
        i: usize,
        k: usize,
        selection: Selection,
        /// Lagrange buffer Omega: stacked eps per visited grid point.
        eps: Vec<Tensor>,
        pred: Tensor,
        eps_c: Tensor,
        has_pred: bool,
        /// ERS selection scratches (capacity k; steady path allocation-free).
        idx: Vec<usize>,
        idx_b: Vec<usize>,
        abs: Vec<usize>,
        /// Churn-noise scratch (zero-sized when no member churns).
        z: Tensor,
    },
}

impl Kernel {
    fn tag(&self) -> KindTag {
        match self {
            Kernel::Noop => KindTag::Noop,
            Kernel::Ddim { .. } => KindTag::Ddim,
            Kernel::Ddpm { .. } => KindTag::Ddpm,
            Kernel::Iadams { .. } => KindTag::Iadams,
            Kernel::Explicit { .. } => KindTag::Explicit,
            Kernel::Dpm { .. } => KindTag::Dpm,
            Kernel::Era { .. } => KindTag::Era,
        }
    }
}

/// Host-side bookkeeping of a lane whose iterate and eps history live
/// in engine-owned buffers (see [`crate::runtime::resident`]). While
/// this is `Some`, the lane's `x` holds the *opening* iterate and the
/// kernel's `eps` stays empty — only step indices and plan
/// coefficients cross the host/engine boundary until the lane
/// finishes or devolves.
struct ResidentLane {
    handle: u64,
    /// Engine-side eps-history length (the host twin of `eps.len()`).
    eps_len: usize,
}

/// What the scheduler should do next with an idle resident lane.
pub enum ResidentCmd {
    /// Ship this op to the engine.
    Op(ResidentOp),
    /// Members' error-robust selections diverged: gather the lane back
    /// to host stepping (which will split it) before continuing.
    Devolve,
}

/// One batch-major lane: stacked state plus the member table.
pub struct Lane {
    key: LaneKey,
    view: Option<PlanView>,
    /// Stacked iterate, member row groups in `members` order.
    x: Arc<Tensor>,
    cols: usize,
    members: Vec<Member>,
    kernel: Kernel,
    guided: bool,
    /// Stacked paired eval buffer (`[cond; uncond]` per member; empty
    /// when not guided).
    x2: Arc<Tensor>,
    /// Stacked per-row conditioning channel (guided lanes).
    cond: Arc<Vec<f32>>,
    cond_dirty: bool,
    pending: Option<EvalRequest>,
    /// The *inner* (undoubled) evaluated point + time of the pending
    /// eval — FON's drift conversion needs them at delivery.
    inner_x: Option<Arc<Tensor>>,
    inner_t: f64,
    sealed: bool,
    done: bool,
    /// `Some` while the lane steps engine-resident (host state frozen).
    resident: Option<ResidentLane>,
}

impl Lane {
    fn eval_factor(&self) -> usize {
        if self.guided {
            2
        } else {
            1
        }
    }

    /// Rows one fused evaluation of this lane carries.
    pub fn eval_rows(&self) -> usize {
        self.x.rows() * self.eval_factor()
    }
}

/// The shard-wide lane table: admission, lockstep stepping with
/// split-on-divergence, delivery, and compaction.
pub struct LaneEngine {
    lanes: Vec<Option<Lane>>,
    free: Vec<usize>,
    slot_lane: HashMap<usize, usize>,
    pool: TensorPool,
    /// Join cap on a lane's eval rows (0 = unbounded). Matched to the
    /// batch policy's `max_rows` so whole-lane slabs stay zero-copy.
    max_lane_rows: usize,
}

fn initial_delta(kind: &SolverKind) -> f64 {
    match kind {
        SolverKind::Era { selection: Selection::ErrorRobust { lambda }, .. } => *lambda,
        SolverKind::Era { .. } => 1.0,
        _ => 0.0,
    }
}

fn member_rng(kind: &SolverKind, seed: u64) -> Rng {
    match kind {
        SolverKind::Era { .. } => Rng::for_stream(seed, CHURN_STREAM),
        SolverKind::Ddpm => Rng::for_stream(seed, ANCESTRAL_STREAM),
        _ => Rng::new(0),
    }
}

fn make_kernel(kind: &SolverKind, view: Option<&PlanView>) -> Kernel {
    let Some(view) = view else {
        return Kernel::Noop;
    };
    let n_points = view.grid().len();
    let empty = || Tensor::zeros(0, 0);
    match kind {
        SolverKind::Ddim => Kernel::Ddim { i: 0 },
        SolverKind::Ddpm => Kernel::Ddpm { i: 0, z: empty() },
        SolverKind::ImplicitAdams => Kernel::Iadams {
            i: 0,
            hist: Vec::with_capacity(5),
            comb: empty(),
            x_pred: Arc::new(empty()),
        },
        SolverKind::Pndm | SolverKind::Fon => {
            assert!(n_points >= 5, "PNDM/FON need >= 4 transitions (>= 13 NFE)");
            Kernel::Explicit {
                fon: matches!(kind, SolverKind::Fon),
                i: 0,
                stage: WarmStage::S1,
                hist: Vec::with_capacity(5),
                rk: Vec::with_capacity(3),
                x_base: None,
                combo: empty(),
                drift: empty(),
                u: Arc::new(empty()),
            }
        }
        SolverKind::Dpm { .. } | SolverKind::DpmFast => {
            assert!(view.has_dpm(), "DPM lane needs a plan with DPM coefficients");
            Kernel::Dpm { i: 0, stage: 0, e0: None, e1: None, u: Arc::new(empty()) }
        }
        SolverKind::Era { k, selection } => {
            assert!(*k >= 2, "interpolation order k must be >= 2");
            assert!(
                n_points > *k,
                "NFE budget {} too small for order k={k} (needs > k transitions)",
                n_points - 1
            );
            Kernel::Era {
                i: 0,
                k: *k,
                selection: selection.clone(),
                eps: Vec::with_capacity(n_points),
                pred: empty(),
                eps_c: empty(),
                has_pred: false,
                idx: Vec::with_capacity(*k),
                idx_b: Vec::with_capacity(*k),
                abs: Vec::with_capacity(*k),
                z: empty(),
            }
        }
    }
}

/// Allocate the lane's stacked scratch tensors once membership is
/// final (first step seals the lane against further joins).
fn seal(lane: &mut Lane, pool: &mut TensorPool) {
    lane.sealed = true;
    let rows = lane.x.rows();
    let cols = lane.cols;
    if lane.guided {
        lane.x2 = Arc::new(pool.take(2 * rows, cols));
    }
    let churny = lane.members.iter().any(|m| m.churn > 0.0);
    match &mut lane.kernel {
        Kernel::Noop | Kernel::Ddim { .. } => {}
        Kernel::Ddpm { z, .. } => *z = pool.take(rows, cols),
        Kernel::Iadams { comb, x_pred, .. } => {
            *comb = pool.take(rows, cols);
            *x_pred = Arc::new(pool.take(rows, cols));
        }
        Kernel::Explicit { fon, combo, drift, u, .. } => {
            *combo = pool.take(rows, cols);
            if *fon {
                *drift = pool.take(rows, cols);
            }
            *u = Arc::new(pool.take(rows, cols));
        }
        Kernel::Dpm { u, .. } => *u = Arc::new(pool.take(rows, cols)),
        Kernel::Era { pred, eps_c, z, .. } => {
            *pred = pool.take(rows, cols);
            *eps_c = pool.take(rows, cols);
            if churny {
                *z = pool.take(rows, cols);
            }
        }
    }
}

fn recompute_starts(members: &mut [Member]) {
    let mut at = 0;
    for m in members.iter_mut() {
        m.start = at;
        at += m.rows;
    }
}

fn build_cond(members: &[Member]) -> Vec<f32> {
    let total: usize = members.iter().map(|m| m.rows).sum();
    let mut c = Vec::with_capacity(2 * total);
    for m in members {
        c.resize(c.len() + m.rows, m.class as f32);
        c.resize(c.len() + m.rows, UNCOND);
    }
    c
}

/// Remove a member's row span from a stacked tensor (no-op on
/// zero-sized placeholder scratches).
fn trim(t: &mut Tensor, start: usize, n: usize) {
    if t.rows() > 0 {
        t.remove_rows(start, n);
    }
}

fn arc_trim(t: &mut Arc<Tensor>, start: usize, n: usize) {
    if t.rows() > 0 {
        Arc::make_mut(t).remove_rows(start, n);
    }
}

/// Remove one state-row span from every live kernel tensor.
fn kernel_remove_rows(kernel: &mut Kernel, start: usize, n: usize) {
    match kernel {
        Kernel::Noop | Kernel::Ddim { .. } => {}
        Kernel::Ddpm { z, .. } => trim(z, start, n),
        Kernel::Iadams { hist, comb, x_pred, .. } => {
            for h in hist.iter_mut() {
                trim(h, start, n);
            }
            trim(comb, start, n);
            arc_trim(x_pred, start, n);
        }
        Kernel::Explicit { hist, rk, x_base, combo, drift, u, .. } => {
            for h in hist.iter_mut() {
                trim(h, start, n);
            }
            for r in rk.iter_mut() {
                trim(r, start, n);
            }
            if let Some(b) = x_base {
                arc_trim(b, start, n);
            }
            trim(combo, start, n);
            trim(drift, start, n);
            arc_trim(u, start, n);
        }
        Kernel::Dpm { e0, e1, u, .. } => {
            if let Some(t) = e0 {
                trim(t, start, n);
            }
            if let Some(t) = e1 {
                trim(t, start, n);
            }
            arc_trim(u, start, n);
        }
        Kernel::Era { eps, pred, eps_c, z, .. } => {
            for e in eps.iter_mut() {
                trim(e, start, n);
            }
            trim(pred, start, n);
            trim(eps_c, start, n);
            trim(z, start, n);
        }
    }
}

/// Gather `spans` of `src` into one stacked tensor from the pool.
fn gather_spans(
    pool: &mut TensorPool,
    src: &Tensor,
    spans: &[(usize, usize)],
    rows: usize,
    cols: usize,
) -> Tensor {
    let mut out = pool.take(rows, cols);
    let mut at = 0;
    for &(s, n) in spans {
        out.row_span_mut(at, n).copy_from_slice(src.row_span(s, n));
        at += n;
    }
    out
}

fn recycle_lane(lane: Lane, pool: &mut TensorPool) {
    let Lane { x, x2, kernel, pending, inner_x, .. } = lane;
    // Release the request views first so the Arcs unwind to one owner.
    drop(pending);
    drop(inner_x);
    if let Ok(t) = Arc::try_unwrap(x) {
        pool.give(t);
    }
    if let Ok(t) = Arc::try_unwrap(x2) {
        pool.give(t);
    }
    match kernel {
        Kernel::Noop | Kernel::Ddim { .. } => {}
        Kernel::Ddpm { z, .. } => pool.give(z),
        Kernel::Iadams { hist, comb, x_pred, .. } => {
            for h in hist {
                pool.give(h);
            }
            pool.give(comb);
            if let Ok(t) = Arc::try_unwrap(x_pred) {
                pool.give(t);
            }
        }
        Kernel::Explicit { hist, rk, x_base, combo, drift, u, .. } => {
            for h in hist {
                pool.give(h);
            }
            for r in rk {
                pool.give(r);
            }
            if let Some(b) = x_base {
                if let Ok(t) = Arc::try_unwrap(b) {
                    pool.give(t);
                }
            }
            pool.give(combo);
            pool.give(drift);
            if let Ok(t) = Arc::try_unwrap(u) {
                pool.give(t);
            }
        }
        Kernel::Dpm { e0, e1, u, .. } => {
            if let Some(t) = e0 {
                pool.give(t);
            }
            if let Some(t) = e1 {
                pool.give(t);
            }
            if let Ok(t) = Arc::try_unwrap(u) {
                pool.give(t);
            }
        }
        Kernel::Era { eps, pred, eps_c, z, .. } => {
            for e in eps {
                pool.give(e);
            }
            pool.give(pred);
            pool.give(eps_c);
            pool.give(z);
        }
    }
}

/// AB predictor combination from newest-first history into `comb`
/// (order adapts to fill level) — mirrors `ImplicitAdamsPc::predict_eps`.
fn predict_ab(hist: &[Tensor], comb: &mut Tensor) {
    let n = hist.len();
    if n == 1 {
        comb.as_mut_slice().copy_from_slice(hist[0].as_slice());
        return;
    }
    let w: &[f64] = match n {
        2 => &[1.5, -0.5],
        3 => &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
        _ => &AB4,
    };
    let mut parts: [&[f32]; 4] = [&[]; 4];
    for (slot, h) in parts.iter_mut().zip(hist.iter()) {
        *slot = h.as_slice();
    }
    fused::weighted_sum_into(comb.as_mut_slice(), &parts[..w.len()], w);
}

/// True when the kernel has consumed every transition. ERA lanes flag
/// `done` inside their advance (the final evaluation is skipped).
fn kernel_done(lane: &Lane) -> bool {
    let Some(view) = lane.view.as_ref() else {
        return true;
    };
    match &lane.kernel {
        Kernel::Noop => true,
        Kernel::Ddim { i }
        | Kernel::Ddpm { i, .. }
        | Kernel::Iadams { i, .. }
        | Kernel::Explicit { i, .. } => *i + 1 >= view.grid().len(),
        Kernel::Dpm { i, .. } => *i >= view.steps(),
        Kernel::Era { .. } => lane.done,
    }
}

/// Build (or rebuild, after compaction — every branch is idempotent)
/// the lane's next evaluation request from its current state.
fn build_request(lane: &mut Lane) {
    let view = lane.view.clone().expect("request on a zero-transition lane");
    let (x_inner, t) = match &mut lane.kernel {
        Kernel::Noop => unreachable!("noop lanes never request"),
        Kernel::Ddim { i } | Kernel::Ddpm { i, .. } => (Arc::clone(&lane.x), view.t(*i)),
        Kernel::Era { i, .. } => (Arc::clone(&lane.x), view.t(*i)),
        Kernel::Iadams { i, hist, comb, x_pred } => {
            if hist.is_empty() {
                (Arc::clone(&lane.x), view.t(*i))
            } else {
                predict_ab(hist, comb);
                let (a, b) = view.ddim_coeffs(*i);
                let xp = Arc::make_mut(x_pred);
                fused::affine_into(
                    xp.as_mut_slice(),
                    a as f32,
                    lane.x.as_slice(),
                    b as f32,
                    comb.as_slice(),
                );
                (Arc::clone(x_pred), view.t(*i + 1))
            }
        }
        Kernel::Explicit { fon, i, stage, rk, x_base, u, .. } => {
            let t_cur = view.t(*i);
            let t_next = view.t(*i + 1);
            if *i >= 3 {
                (Arc::clone(&lane.x), t_cur)
            } else if *stage == WarmStage::S1 {
                *x_base = Some(Arc::clone(&lane.x));
                (Arc::clone(&lane.x), t_cur)
            } else {
                let sched = view.sched();
                let base = x_base.as_ref().unwrap_or(&lane.x);
                let ub = Arc::make_mut(u);
                if *fon {
                    let h = t_next - t_cur; // negative
                    let (slope, step, t_to) = match *stage {
                        WarmStage::S2 => (&rk[0], 0.5 * h, t_cur + 0.5 * h),
                        WarmStage::S3 => (&rk[1], 0.5 * h, t_cur + 0.5 * h),
                        WarmStage::S4 => (&rk[2], h, t_next),
                        _ => unreachable!(),
                    };
                    ub.as_mut_slice().copy_from_slice(base.as_slice());
                    fused::axpy(ub.as_mut_slice(), step as f32, slope.as_slice());
                    (Arc::clone(u), t_to)
                } else {
                    let t_mid = 0.5 * (t_cur + t_next);
                    let (slope, t_to) = match *stage {
                        WarmStage::S2 => (&rk[0], t_mid),
                        WarmStage::S3 => (&rk[1], t_mid),
                        WarmStage::S4 => (&rk[2], t_next),
                        _ => unreachable!(),
                    };
                    let (a, b) = sched.ddim_coeffs(t_cur, t_to);
                    fused::affine_into(
                        ub.as_mut_slice(),
                        a as f32,
                        base.as_slice(),
                        b as f32,
                        slope.as_slice(),
                    );
                    (Arc::clone(u), t_to)
                }
            }
        }
        Kernel::Dpm { i, stage, e0, e1, u } => {
            let sp = view.dpm_step(*i);
            match (sp.order, *stage) {
                (_, 0) => (Arc::clone(&lane.x), view.t(*i)),
                (2, 1) | (3, 1) => {
                    let e0t = e0.as_ref().expect("dpm stage 1 without e0");
                    let ub = Arc::make_mut(u);
                    fused::affine_into(
                        ub.as_mut_slice(),
                        sp.a_s1 as f32,
                        lane.x.as_slice(),
                        sp.b_s1 as f32,
                        e0t.as_slice(),
                    );
                    (Arc::clone(u), sp.t_s1)
                }
                (3, 2) => {
                    let e0t = e0.as_ref().expect("dpm stage 2 without e0");
                    let e1t = e1.as_ref().expect("dpm stage 2 without e1");
                    let ub = Arc::make_mut(u);
                    fused::affine_into(
                        ub.as_mut_slice(),
                        sp.a_s2 as f32,
                        lane.x.as_slice(),
                        sp.b_s2 as f32,
                        e0t.as_slice(),
                    );
                    let c = sp.c_s2 as f32;
                    fused::axpy(ub.as_mut_slice(), c, e1t.as_slice());
                    fused::axpy(ub.as_mut_slice(), -c, e0t.as_slice());
                    (Arc::clone(u), sp.t_s2)
                }
                _ => unreachable!("invalid dpm stage"),
            }
        }
    };
    lane.inner_t = t;
    let req = if lane.guided {
        if lane.cond_dirty {
            lane.cond = Arc::new(build_cond(&lane.members));
            lane.cond_dirty = false;
        }
        let x2m = Arc::make_mut(&mut lane.x2);
        for m in &lane.members {
            x2m.row_span_mut(2 * m.start, m.rows)
                .copy_from_slice(x_inner.row_span(m.start, m.rows));
            x2m.row_span_mut(2 * m.start + m.rows, m.rows)
                .copy_from_slice(x_inner.row_span(m.start, m.rows));
        }
        EvalRequest { x: Arc::clone(&lane.x2), t, cond: Some(Arc::clone(&lane.cond)) }
    } else {
        EvalRequest { x: Arc::clone(&x_inner), t, cond: None }
    };
    lane.inner_x = Some(x_inner);
    lane.pending = Some(req);
}

/// ERA transition: mirrors `EraSolver::advance` + the done check of its
/// `next_eval`, with per-member churn streams.
fn era_advance(lane: &mut Lane) {
    let view = lane.view.clone().expect("era lane without a view");
    let n_points = view.grid().len();
    let ran_pred = {
        let Kernel::Era { i, k, selection, eps, pred, eps_c, idx, abs, .. } = &mut lane.kernel
        else {
            unreachable!()
        };
        let (a, b) = view.ddim_coeffs(*i);
        let ran = if *i < *k - 1 {
            // Warmup (Alg. 1 line 5-7): plain DDIM with the newest eps.
            let newest = eps.last().expect("advance before first eval");
            let x = Arc::make_mut(&mut lane.x);
            fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, newest.as_slice());
            false
        } else {
            // ERS selection over buffer entries 0..=bi. After a split,
            // every member of this lane selects the same indices, so
            // member 0's measured error stands for the lane.
            let bi = eps.len() - 1;
            match selection {
                Selection::FixedLast => {
                    idx.clear();
                    idx.extend((bi + 1 - *k)..=bi);
                }
                Selection::ErrorRobust { lambda } => {
                    select_indices_guarded(idx, bi, *k, lane.members[0].delta_eps / *lambda);
                }
                Selection::ConstantScale { scale } => select_indices_guarded(idx, bi, *k, *scale),
            }
            let w = view.lagrange_weights_into(*i + 1, idx, abs);
            fused::zero(pred.as_mut_slice());
            for (&n, &wm) in idx.iter().zip(w.iter()) {
                fused::axpy(pred.as_mut_slice(), wm as f32, eps[n].as_slice());
            }
            let n = eps.len();
            let order = n.min(3) + 1;
            let amw = view.am_weights(order);
            fused::zero(eps_c.as_mut_slice());
            fused::axpy(eps_c.as_mut_slice(), amw[0] as f32, pred.as_slice());
            for back in 0..order - 1 {
                fused::axpy(
                    eps_c.as_mut_slice(),
                    amw[back + 1] as f32,
                    eps[n - 1 - back].as_slice(),
                );
            }
            let x = Arc::make_mut(&mut lane.x);
            fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, eps_c.as_slice());
            true
        };
        *i += 1;
        ran
    };
    let Kernel::Era { i, has_pred, z, .. } = &mut lane.kernel else {
        unreachable!()
    };
    *has_pred = ran_pred;
    // Stochastic churn after interior transitions, per-member streams.
    if *i + 1 < n_points && z.rows() > 0 {
        let ab_prev = view.alpha_bar_at(*i - 1);
        let ab_cur = view.alpha_bar_at(*i);
        let alpha = ab_prev / ab_cur;
        let var = (1.0 - ab_cur) / (1.0 - ab_prev) * (1.0 - alpha);
        if var > 0.0 {
            let xm = Arc::make_mut(&mut lane.x);
            for m in lane.members.iter_mut() {
                if m.churn <= 0.0 {
                    continue;
                }
                m.rng.fill_normal(z.row_span_mut(m.start, m.rows));
                fused::axpy(
                    xm.row_span_mut(m.start, m.rows),
                    (m.churn * var.sqrt()) as f32,
                    z.row_span(m.start, m.rows),
                );
            }
        }
    }
    if *i + 1 >= n_points {
        // Final iterate reached; its evaluation would never be used.
        lane.done = true;
    }
}

/// Per-member ERS selections for this step; `None` when every member
/// agrees with member 0 (the steady, allocation-free path). Returned
/// groups are slot lists for the minority selections.
fn era_split_groups(lane: &mut Lane) -> Option<Vec<Vec<usize>>> {
    if lane.members.len() < 2 {
        return None;
    }
    let Kernel::Era { i, k, selection, eps, idx, idx_b, .. } = &mut lane.kernel else {
        return None;
    };
    let Selection::ErrorRobust { lambda } = selection else {
        return None;
    };
    if eps.is_empty() || *i < *k - 1 {
        return None;
    }
    let bi = eps.len() - 1;
    select_indices_guarded(idx, bi, *k, lane.members[0].delta_eps / *lambda);
    let mut groups: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
    for m in lane.members.iter().skip(1) {
        select_indices_guarded(idx_b, bi, *k, m.delta_eps / *lambda);
        if idx_b.as_slice() == idx.as_slice() {
            continue;
        }
        match groups.iter_mut().find(|g| g.0.as_slice() == idx_b.as_slice()) {
            Some(g) => g.1.push(m.slot),
            None => groups.push((idx_b.clone(), vec![m.slot])),
        }
    }
    if groups.is_empty() {
        None
    } else {
        Some(groups.into_iter().map(|(_, slots)| slots).collect())
    }
}

/// Advance (ERA only — other kinds advance at delivery, mirroring
/// their `on_eval`) and build the next request, or flag completion.
fn advance_and_request(lane: &mut Lane) {
    match lane.kernel.tag() {
        KindTag::Noop => {
            lane.done = true;
            return;
        }
        KindTag::Era => {
            let first = matches!(&lane.kernel, Kernel::Era { eps, .. } if eps.is_empty());
            if !first {
                era_advance(lane);
                if lane.done {
                    return;
                }
            }
        }
        _ => {
            if kernel_done(lane) {
                lane.done = true;
                return;
            }
        }
    }
    build_request(lane);
}

/// Collapse a guided lane's paired model output in place: combine each
/// member's cond/uncond halves with that member's scale, pack the
/// combined rows down to state layout, and truncate. Zero-alloc.
fn guided_collapse(lane: &mut Lane, eps: &mut Tensor) {
    let state_rows = lane.x.rows();
    assert_eq!(eps.rows(), 2 * state_rows, "paired evaluation rows mismatch");
    let c = lane.cols;
    for m in &lane.members {
        let off = 2 * m.start * c;
        let half = m.rows * c;
        let span = &mut eps.as_mut_slice()[off..off + 2 * half];
        let (cond_half, uncond_half) = span.split_at_mut(half);
        fused::guided_combine(cond_half, uncond_half, m.scale);
    }
    // Pack each member's combined rows down to its state-row span.
    // Members are processed in start order, so writes never clobber a
    // later member's unread source (dst end <= next src start).
    for m in &lane.members {
        let src = 2 * m.start * c;
        let dst = m.start * c;
        let n = m.rows * c;
        eps.as_mut_slice().copy_within(src..src + n, dst);
    }
    eps.truncate_rows(state_rows);
}

fn ddim_deliver(lane: &mut Lane, eps: Tensor) {
    let view = lane.view.clone().expect("ddim lane without a view");
    let Kernel::Ddim { i } = &mut lane.kernel else {
        unreachable!()
    };
    let (a, b) = view.ddim_coeffs(*i);
    let x = Arc::make_mut(&mut lane.x);
    fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, eps.as_slice());
    *i += 1;
}

fn ddpm_deliver(lane: &mut Lane, eps: Tensor) {
    let view = lane.view.clone().expect("ddpm lane without a view");
    let Kernel::Ddpm { i, z } = &mut lane.kernel else {
        unreachable!()
    };
    let ab_cur = view.alpha_bar_at(*i);
    let ab_next = view.alpha_bar_at(*i + 1);
    let alpha = ab_cur / ab_next;
    let coef = ((1.0 - alpha) / (1.0 - ab_cur).sqrt()) as f32;
    let inv_sqrt_alpha = (1.0 / alpha.sqrt()) as f32;
    let x = Arc::make_mut(&mut lane.x);
    fused::axpy(x.as_mut_slice(), -coef, eps.as_slice());
    fused::scale(x.as_mut_slice(), inv_sqrt_alpha);
    let last = *i + 2 == view.grid().len();
    if !last {
        let var = (1.0 - ab_next) / (1.0 - ab_cur) * (1.0 - alpha);
        if var > 0.0 {
            // Per-member ancestral streams into the member's span, then
            // one stacked axpy (the scale is lane-uniform).
            for m in lane.members.iter_mut() {
                m.rng.fill_normal(z.row_span_mut(m.start, m.rows));
            }
            fused::axpy(x.as_mut_slice(), var.sqrt() as f32, z.as_slice());
        }
    }
    *i += 1;
}

fn iadams_deliver(lane: &mut Lane, pool: &mut TensorPool, eps: Tensor) {
    let view = lane.view.clone().expect("iadams lane without a view");
    let Kernel::Iadams { i, hist, comb, .. } = &mut lane.kernel else {
        unreachable!()
    };
    let (a, b) = view.ddim_coeffs(*i);
    if hist.is_empty() {
        let x = Arc::make_mut(&mut lane.x);
        fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, eps.as_slice());
        hist.insert(0, eps);
        *i += 1;
        return;
    }
    let order = (hist.len() + 1).min(4);
    let w = view.am_weights(order);
    {
        let out = comb.as_mut_slice();
        fused::zero(out);
        fused::axpy(out, w[0] as f32, eps.as_slice());
        for (h, &wm) in hist.iter().take(order - 1).zip(w[1..].iter()) {
            fused::axpy(out, wm as f32, h.as_slice());
        }
    }
    let x = Arc::make_mut(&mut lane.x);
    fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, comb.as_slice());
    hist.insert(0, eps);
    if hist.len() > 4 {
        let evicted = hist.pop().expect("over-full history");
        pool.give(evicted);
    }
    *i += 1;
}

fn explicit_deliver(
    lane: &mut Lane,
    pool: &mut TensorPool,
    x_req: Arc<Tensor>,
    t_req: f64,
    eps: Tensor,
) {
    let view = lane.view.clone().expect("explicit lane without a view");
    let rows = lane.x.rows();
    let cols = lane.cols;
    let Kernel::Explicit { fon, i, stage, hist, rk, x_base, combo, drift, .. } = &mut lane.kernel
    else {
        unreachable!()
    };
    let sched = view.sched();
    let t_cur = view.t(*i);
    let t_next = view.t(*i + 1);

    if *i < 3 {
        // Warmup: convert to the working quantity (may allocate, like
        // the per-request warmup) and run the RK stage machine.
        let val = if *fon {
            let mut f = pool.take(rows, cols);
            drift_into(&sched, f.as_mut_slice(), x_req.as_slice(), eps.as_slice(), t_req);
            f
        } else {
            eps
        };
        drop(x_req);
        match *stage {
            WarmStage::S1 => {
                hist.insert(0, val.clone());
                rk.push(val);
                *stage = WarmStage::S2;
            }
            WarmStage::S2 => {
                rk.push(val);
                *stage = WarmStage::S3;
            }
            WarmStage::S3 => {
                rk.push(val);
                *stage = WarmStage::S4;
            }
            WarmStage::S4 => {
                let combo_t = Tensor::weighted_sum(
                    &[&rk[0], &rk[1], &rk[2], &val],
                    &[1.0 / 6.0, 2.0 / 6.0, 2.0 / 6.0, 1.0 / 6.0],
                );
                let mut base = x_base.take().expect("warmup base missing");
                {
                    let bm = Arc::make_mut(&mut base);
                    if *fon {
                        bm.axpy((t_next - t_cur) as f32, &combo_t);
                    } else {
                        let (aa, bb) = sched.ddim_coeffs(t_cur, t_next);
                        fused::affine_inplace(
                            bm.as_mut_slice(),
                            aa as f32,
                            bb as f32,
                            combo_t.as_slice(),
                        );
                    }
                }
                lane.x = base;
                for t in rk.drain(..) {
                    pool.give(t);
                }
                pool.give(val);
                *i += 1;
                *stage = if *i < 3 { WarmStage::S1 } else { WarmStage::Multi };
            }
            WarmStage::Multi => unreachable!(),
        }
        return;
    }

    // Multistep phase: push the new slope, AB4-combine, transfer.
    let val = if *fon {
        drift_into(&sched, drift.as_mut_slice(), x_req.as_slice(), eps.as_slice(), t_req);
        std::mem::replace(drift, Tensor::zeros(0, 0))
    } else {
        eps
    };
    drop(x_req);
    hist.insert(0, val);
    let evicted = if hist.len() > 4 { hist.pop() } else { None };
    if *fon {
        *drift = evicted.unwrap_or_else(|| pool.take(rows, cols));
    } else if let Some(t) = evicted {
        pool.give(t);
    }
    assert!(hist.len() == 4, "multistep phase requires a full history");
    {
        let out = combo.as_mut_slice();
        fused::zero(out);
        for (h, &wm) in hist.iter().take(4).zip(AB4.iter()) {
            fused::axpy(out, wm as f32, h.as_slice());
        }
    }
    let x = Arc::make_mut(&mut lane.x);
    if *fon {
        fused::axpy(x.as_mut_slice(), (t_next - t_cur) as f32, combo.as_slice());
    } else {
        let (a, b) = view.ddim_coeffs(*i);
        fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, combo.as_slice());
    }
    *i += 1;
}

fn dpm_deliver(lane: &mut Lane, pool: &mut TensorPool, eps: Tensor) {
    let view = lane.view.clone().expect("dpm lane without a view");
    let Kernel::Dpm { i, stage, e0, e1, .. } = &mut lane.kernel else {
        unreachable!()
    };
    let sp = view.dpm_step(*i);
    match (sp.order, *stage) {
        (2, 0) | (3, 0) => {
            *e0 = Some(eps);
            *stage = 1;
        }
        (3, 1) => {
            *e1 = Some(eps);
            *stage = 2;
        }
        (1, 0) | (2, 1) | (3, 2) => {
            let x = Arc::make_mut(&mut lane.x);
            match sp.order {
                1 | 2 => {
                    fused::affine_inplace(
                        x.as_mut_slice(),
                        sp.a_f as f32,
                        sp.b_f as f32,
                        eps.as_slice(),
                    );
                }
                3 => {
                    let e0t = e0.as_ref().expect("dpm finish without e0");
                    fused::affine_inplace(
                        x.as_mut_slice(),
                        sp.a_f as f32,
                        sp.b_f as f32,
                        e0t.as_slice(),
                    );
                    let cf = sp.c_f as f32;
                    fused::axpy(x.as_mut_slice(), cf, eps.as_slice());
                    fused::axpy(x.as_mut_slice(), -cf, e0t.as_slice());
                }
                _ => unreachable!(),
            }
            if let Some(t) = e0.take() {
                pool.give(t);
            }
            if let Some(t) = e1.take() {
                pool.give(t);
            }
            pool.give(eps);
            *stage = 0;
            *i += 1;
        }
        _ => unreachable!("invalid dpm stage"),
    }
}

/// Feed one freshly scored `delta_eps` into a member's convergence
/// trend. Pure bookkeeping — it never touches lane numerics, and a
/// zero threshold keeps the streak permanently at zero, so the
/// fixed-NFE path is bitwise unaffected.
fn observe_delta(m: &mut Member) {
    if m.conv_threshold <= 0.0 {
        return;
    }
    let prev = m.prev_delta;
    m.prev_delta = m.delta_eps;
    if !prev.is_finite() || !m.delta_eps.is_finite() {
        m.conv_streak = 0;
        return;
    }
    let rel = (m.delta_eps - prev).abs() / prev.abs().max(1e-12);
    if rel < m.conv_threshold {
        m.conv_streak = m.conv_streak.saturating_add(1);
    } else {
        m.conv_streak = 0;
    }
}

fn era_deliver(lane: &mut Lane, eps_new: Tensor) {
    let c = lane.cols;
    let Kernel::Era { eps, pred, has_pred, .. } = &mut lane.kernel else {
        unreachable!()
    };
    if *has_pred {
        *has_pred = false;
        // Eq. 15 per member over its own rows — identical accumulation
        // to the per-request measure.
        for m in lane.members.iter_mut() {
            m.delta_eps = fused::mean_row_dist(
                eps_new.row_span(m.start, m.rows),
                pred.row_span(m.start, m.rows),
                m.rows,
                c,
            ) as f64;
            observe_delta(m);
        }
    }
    eps.push(eps_new);
}

fn deliver_lane(lane: &mut Lane, pool: &mut TensorPool, mut eps: Tensor) {
    assert!(lane.pending.is_some(), "deliver without a pending evaluation");
    lane.pending = None;
    let x_req = lane.inner_x.take().expect("deliver without an inner request");
    let t_req = lane.inner_t;
    if lane.guided {
        guided_collapse(lane, &mut eps);
    }
    assert_eq!(eps.rows(), lane.x.rows(), "lane eps rows mismatch");
    let bump = lane.eval_factor();
    for m in lane.members.iter_mut() {
        m.nfe += bump;
    }
    match lane.kernel.tag() {
        KindTag::Noop => panic!("noop lane received an evaluation"),
        KindTag::Ddim => {
            drop(x_req);
            ddim_deliver(lane, eps);
        }
        KindTag::Ddpm => {
            drop(x_req);
            ddpm_deliver(lane, eps);
        }
        KindTag::Iadams => {
            drop(x_req);
            iadams_deliver(lane, pool, eps);
        }
        KindTag::Explicit => explicit_deliver(lane, pool, x_req, t_req, eps),
        KindTag::Dpm => {
            drop(x_req);
            dpm_deliver(lane, pool, eps);
        }
        KindTag::Era => {
            drop(x_req);
            era_deliver(lane, eps);
        }
    }
}

impl LaneEngine {
    /// `max_lane_rows` caps a lane's fused-eval rows at admission so a
    /// whole-lane slab never exceeds the batch policy's `max_rows`
    /// (0 = unbounded).
    pub fn new(max_lane_rows: usize) -> LaneEngine {
        LaneEngine {
            lanes: Vec::new(),
            free: Vec::new(),
            slot_lane: HashMap::new(),
            pool: TensorPool::new(256),
            max_lane_rows,
        }
    }

    /// Upper bound of live lane ids (for scheduler iteration; ids are
    /// recycled, so check [`LaneEngine::has_lane`]).
    pub fn lane_slots(&self) -> usize {
        self.lanes.len()
    }

    pub fn has_lane(&self, id: usize) -> bool {
        self.lanes.get(id).is_some_and(|l| l.is_some())
    }

    /// Live lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.iter().flatten().count()
    }

    /// Total members across live lanes.
    pub fn member_total(&self) -> usize {
        self.lanes.iter().flatten().map(|l| l.members.len()).sum()
    }

    pub fn members(&self, id: usize) -> &[Member] {
        &self.lanes[id].as_ref().expect("members of empty lane").members
    }

    pub fn dataset(&self, id: usize) -> &str {
        &self.lanes[id].as_ref().expect("dataset of empty lane").key.dataset
    }

    pub fn pending(&self, id: usize) -> Option<&EvalRequest> {
        self.lanes[id].as_ref().and_then(|l| l.pending.as_ref())
    }

    pub fn is_done(&self, id: usize) -> bool {
        self.lanes[id].as_ref().is_some_and(|l| l.done)
    }

    /// Lane currently holding `slot`, if any.
    pub fn lane_of_slot(&self, slot: usize) -> Option<usize> {
        self.slot_lane.get(&slot).copied()
    }

    /// ERA lanes: the last error-robust selection — `(grid index i, the
    /// selected Lagrange basis indices)`. The selection is lane-uniform
    /// (divergent members were split off), so one read covers every
    /// member. `None` for non-ERA lanes, and before the first selection
    /// has been computed (the scratch starts empty).
    pub fn era_selection(&self, id: usize) -> Option<(usize, &[usize])> {
        let lane = self.lanes.get(id)?.as_ref()?;
        match &lane.kernel {
            Kernel::Era { i, idx, .. } if !idx.is_empty() => Some((*i, idx.as_slice())),
            _ => None,
        }
    }

    /// Stacked tensors handed out that required fresh allocation
    /// (diagnostics; steady-state stepping allocates none).
    pub fn pool_allocations(&self) -> usize {
        self.pool.allocations()
    }

    fn alloc(&mut self, lane: Lane) -> usize {
        match self.free.pop() {
            Some(id) => {
                debug_assert!(self.lanes[id].is_none());
                self.lanes[id] = Some(lane);
                id
            }
            None => {
                self.lanes.push(Some(lane));
                self.lanes.len() - 1
            }
        }
    }

    fn find_joinable(&self, key: &LaneKey, add_eval_rows: usize) -> Option<usize> {
        self.lanes.iter().enumerate().find_map(|(id, l)| {
            let l = l.as_ref()?;
            if l.sealed || l.done || &l.key != key {
                return None;
            }
            if self.max_lane_rows > 0 && l.eval_rows() + add_eval_rows > self.max_lane_rows {
                return None;
            }
            Some(id)
        })
    }

    /// Insert one admitted request: join an existing unsealed lane with
    /// the same key, or open a new one. Returns the lane id.
    pub fn admit(&mut self, slot: usize, dataset: &str, adm: LaneAdmission) -> usize {
        let rows = adm.x.rows();
        let cols = adm.x.cols();
        let guided = adm.guided.is_some();
        let (scale, class) = adm.guided.unwrap_or((0.0, 0));
        let key = LaneKey {
            dataset: dataset.to_string(),
            kind: adm.kind.clone(),
            plan: adm
                .view
                .as_ref()
                .map(|v| Arc::as_ptr(v.plan()) as usize)
                .unwrap_or(0),
            base: adm.view.as_ref().map(|v| v.base()).unwrap_or(usize::MAX),
            guided,
        };
        let member = Member {
            slot,
            start: 0,
            rows,
            nfe: 0,
            delta_eps: initial_delta(&adm.kind),
            churn: adm.churn,
            scale,
            class,
            rng: member_rng(&adm.kind, adm.seed),
            conv_threshold: adm.conv_threshold,
            min_nfe: adm.min_nfe,
            prev_delta: f64::NAN,
            conv_streak: 0,
            degraded: false,
        };
        let eval_rows = rows * if guided { 2 } else { 1 };
        let join = if adm.view.is_some() {
            self.find_joinable(&key, eval_rows)
        } else {
            None // zero-transition lanes are done at admit; never join
        };
        if let Some(id) = join {
            let lane = self.lanes[id].as_mut().unwrap();
            let mut m = member;
            m.start = lane.x.rows();
            Arc::make_mut(&mut lane.x).extend_rows(adm.x.as_slice());
            lane.members.push(m);
            lane.cond_dirty = true;
            self.slot_lane.insert(slot, id);
            return id;
        }
        let kernel = make_kernel(&adm.kind, adm.view.as_ref());
        let done = matches!(kernel, Kernel::Noop);
        let lane = Lane {
            key,
            view: adm.view,
            x: Arc::new(adm.x),
            cols,
            members: vec![member],
            kernel,
            guided,
            x2: Arc::new(Tensor::zeros(0, 0)),
            cond: Arc::new(Vec::new()),
            cond_dirty: true,
            pending: None,
            inner_x: None,
            inner_t: 0.0,
            sealed: false,
            done,
            resident: None,
        };
        let id = self.alloc(lane);
        self.slot_lane.insert(slot, id);
        id
    }

    /// Advance one lane by one pull: seal on first step, run ERA's
    /// per-member selection (splitting divergent members off into
    /// sibling lanes), and set each resulting lane's pending eval or
    /// done flag. Ids of every lane touched (the stepped one plus any
    /// split-offs) are appended to `affected`.
    pub fn step_lane(&mut self, id: usize, affected: &mut Vec<usize>) {
        let first = affected.len();
        affected.push(id);
        {
            let LaneEngine { lanes, pool, .. } = self;
            let lane = lanes[id].as_mut().expect("step of empty lane");
            if lane.done || lane.pending.is_some() || lane.resident.is_some() {
                return;
            }
            if !lane.sealed {
                seal(lane, pool);
            }
        }
        let groups = era_split_groups(self.lanes[id].as_mut().unwrap());
        if let Some(groups) = groups {
            for g in &groups {
                let nid = self.split_off(id, g);
                affected.push(nid);
            }
        }
        let mut j = first;
        while j < affected.len() {
            let lid = affected[j];
            j += 1;
            let lane = self.lanes[lid].as_mut().unwrap();
            advance_and_request(lane);
        }
    }

    /// Feed one lane evaluation back; advances every member.
    pub fn deliver(&mut self, id: usize, eps: Tensor) {
        let LaneEngine { lanes, pool, .. } = self;
        let lane = lanes[id].as_mut().expect("deliver to empty lane");
        deliver_lane(lane, pool, eps);
    }

    /// Move the given member slots out into a sibling lane (ERA
    /// split-on-divergence). State rows and every live history tensor
    /// are gathered for the movers and compacted out of the original;
    /// neither group's bytes change.
    fn split_off(&mut self, id: usize, slots: &[usize]) -> usize {
        let new_lane = {
            let LaneEngine { lanes, pool, .. } = &mut *self;
            let lane = lanes[id].as_mut().expect("split of empty lane");
            debug_assert!(lane.pending.is_none(), "split with a pending eval");
            let cols = lane.cols;
            let idxs: Vec<usize> = slots
                .iter()
                .map(|s| {
                    lane.members
                        .iter()
                        .position(|m| m.slot == *s)
                        .expect("split slot not in lane")
                })
                .collect();
            debug_assert!(idxs.windows(2).all(|w| w[0] < w[1]));
            let spans: Vec<(usize, usize)> =
                idxs.iter().map(|&mi| (lane.members[mi].start, lane.members[mi].rows)).collect();
            let moved_rows: usize = spans.iter().map(|&(_, n)| n).sum();
            let churny = idxs.iter().any(|&mi| lane.members[mi].churn > 0.0);
            let x_new = gather_spans(pool, &lane.x, &spans, moved_rows, cols);
            let kernel_new = match &lane.kernel {
                Kernel::Era { i, k, selection, eps, pred, has_pred, .. } => {
                    let mut eps_new = Vec::with_capacity(eps.capacity());
                    for e in eps.iter() {
                        eps_new.push(gather_spans(pool, e, &spans, moved_rows, cols));
                    }
                    Kernel::Era {
                        i: *i,
                        k: *k,
                        selection: selection.clone(),
                        eps: eps_new,
                        pred: gather_spans(pool, pred, &spans, moved_rows, cols),
                        eps_c: pool.take(moved_rows, cols),
                        has_pred: *has_pred,
                        idx: Vec::with_capacity(*k),
                        idx_b: Vec::with_capacity(*k),
                        abs: Vec::with_capacity(*k),
                        z: if churny { pool.take(moved_rows, cols) } else { Tensor::zeros(0, 0) },
                    }
                }
                _ => unreachable!("only ERA lanes split"),
            };
            let mut moved: Vec<Member> = Vec::with_capacity(idxs.len());
            for &mi in idxs.iter().rev() {
                moved.push(lane.members.remove(mi));
            }
            moved.reverse();
            for &(s, n) in spans.iter().rev() {
                arc_trim(&mut lane.x, s, n);
                kernel_remove_rows(&mut lane.kernel, s, n);
                if lane.guided {
                    arc_trim(&mut lane.x2, 2 * s, 2 * n);
                }
            }
            recompute_starts(&mut lane.members);
            lane.cond_dirty = true;
            recompute_starts(&mut moved);
            Lane {
                key: lane.key.clone(),
                view: lane.view.clone(),
                x: Arc::new(x_new),
                cols,
                members: moved,
                kernel: kernel_new,
                guided: lane.guided,
                x2: if lane.guided {
                    Arc::new(pool.take(2 * moved_rows, cols))
                } else {
                    Arc::new(Tensor::zeros(0, 0))
                },
                cond: Arc::new(Vec::new()),
                cond_dirty: true,
                pending: None,
                inner_x: None,
                inner_t: 0.0,
                sealed: true,
                done: false,
                resident: None,
            }
        };
        let nid = self.alloc(new_lane);
        for s in slots {
            self.slot_lane.insert(*s, nid);
        }
        nid
    }

    /// Retire one member mid-trajectory (cancel/deadline), compacting
    /// its rows out of the lane — and out of `eps`, the lane's just-
    /// assembled (pre-delivery) evaluation, when one is in hand. A
    /// not-yet-dispatched pending eval is regenerated from the
    /// compacted state. Survivors' bits are untouched.
    pub fn remove_member(
        &mut self,
        id: usize,
        slot: usize,
        eps: Option<&mut Tensor>,
    ) -> Removed {
        let mut emptied = false;
        let removed = {
            let lane = self.lanes[id].as_mut().expect("remove from empty lane");
            let mi = lane
                .members
                .iter()
                .position(|m| m.slot == slot)
                .expect("slot not in lane");
            let (start, rows) = (lane.members[mi].start, lane.members[mi].rows);
            let had_pending = lane.pending.is_some();
            lane.pending = None;
            lane.inner_x = None;
            let samples = lane.x.slice_rows(start, rows);
            let m = lane.members.remove(mi);
            let delta = if matches!(lane.kernel, Kernel::Era { .. }) {
                Some(m.delta_eps)
            } else {
                None
            };
            let f = if lane.guided { 2 } else { 1 };
            if let Some(e) = eps {
                e.remove_rows(f * start, f * rows);
            }
            if lane.members.is_empty() {
                emptied = true;
            } else {
                arc_trim(&mut lane.x, start, rows);
                kernel_remove_rows(&mut lane.kernel, start, rows);
                if lane.guided {
                    arc_trim(&mut lane.x2, 2 * start, 2 * rows);
                }
                recompute_starts(&mut lane.members);
                lane.cond_dirty = true;
                if had_pending {
                    build_request(lane);
                }
            }
            Removed { slot, samples, nfe: m.nfe, delta_eps: delta, early_stop: false }
        };
        self.slot_lane.remove(&slot);
        if emptied {
            let LaneEngine { lanes, pool, free, .. } = &mut *self;
            let lane = lanes[id].take().unwrap();
            recycle_lane(lane, pool);
            free.push(id);
        }
        removed
    }

    /// Member slots whose convergence predicate holds after the last
    /// delivery: the `delta_eps` trend stayed below the member's
    /// relative threshold for [`CONV_WINDOW`] consecutive scored steps
    /// (or a QoS degradation latched), and the member's NFE floor is
    /// met. ERA lanes only. Resident lanes are reported too — the
    /// scheduler must devolve them before calling
    /// [`LaneEngine::finish_member_early`], which needs the host-side
    /// eps history.
    pub fn converged_members(&self, id: usize) -> Vec<usize> {
        let Some(lane) = self.lanes.get(id).and_then(|l| l.as_ref()) else {
            return Vec::new();
        };
        if lane.done || !matches!(lane.kernel, Kernel::Era { .. }) {
            return Vec::new();
        }
        if lane.resident.is_none() {
            let Kernel::Era { eps, .. } = &lane.kernel else { unreachable!() };
            if eps.is_empty() {
                return Vec::new();
            }
        }
        lane.members
            .iter()
            .filter(|m| {
                m.nfe >= m.min_nfe.max(1)
                    && (m.degraded || (m.conv_threshold > 0.0 && m.conv_streak >= CONV_WINDOW))
            })
            .map(|m| m.slot)
            .collect()
    }

    /// QoS degradation: latch `slot`'s member to finish as soon as its
    /// NFE floor is met, regardless of the convergence trend. ERA
    /// lanes only (the early finish interpolates the buffered noise
    /// history); returns whether the latch newly applied.
    pub fn degrade_member(&mut self, slot: usize) -> bool {
        let Some(&id) = self.slot_lane.get(&slot) else {
            return false;
        };
        let lane = self.lanes[id].as_mut().expect("degrade in empty lane");
        if lane.done || !matches!(lane.kernel, Kernel::Era { .. }) {
            return false;
        }
        let m = lane
            .members
            .iter_mut()
            .find(|m| m.slot == slot)
            .expect("slot not in lane");
        if m.degraded {
            return false;
        }
        m.degraded = true;
        true
    }

    /// Retire a converged member early: close its trajectory with one
    /// DDIM jump from the current grid point to the endpoint using its
    /// span of the newest buffered noise estimate (DDIM transitions
    /// with a fixed eps compose exactly, so a converged estimate lands
    /// within the predictor's own error of the fixed-NFE endpoint),
    /// then compact the rows out via [`LaneEngine::remove_member`].
    /// Survivors' bits are untouched.
    pub fn finish_member_early(&mut self, id: usize, slot: usize) -> Removed {
        let jumped = {
            let lane = self.lanes[id].as_ref().expect("early finish in empty lane");
            debug_assert!(lane.resident.is_none(), "early finish of a resident lane");
            let view = lane.view.as_ref().expect("era lane without a view");
            let m = lane
                .members
                .iter()
                .find(|m| m.slot == slot)
                .expect("slot not in lane");
            let Kernel::Era { i, eps, .. } = &lane.kernel else {
                unreachable!("early finish on a non-ERA lane")
            };
            let newest = eps.last().expect("early finish before first eval");
            let last = view.grid().len() - 1;
            let (a, b) = view.sched().ddim_coeffs(view.t(*i), view.t(last));
            let mut out = lane.x.slice_rows(m.start, m.rows);
            fused::affine_inplace(
                out.as_mut_slice(),
                a as f32,
                b as f32,
                newest.row_span(m.start, m.rows),
            );
            out
        };
        let mut removed = self.remove_member(id, slot, None);
        removed.samples = jumped;
        removed.early_stop = true;
        removed
    }

    /// Consume a finished lane: every member retires at once (lanes
    /// run in lockstep, so completion is lane-granular).
    pub fn finish_lane(&mut self, id: usize) -> Vec<Removed> {
        let LaneEngine { lanes, pool, slot_lane, free, .. } = &mut *self;
        let lane = lanes[id].take().expect("finish of empty lane");
        free.push(id);
        assert!(lane.done, "finish of an unfinished lane");
        let is_era = matches!(lane.kernel, Kernel::Era { .. });
        let out = lane
            .members
            .iter()
            .map(|m| Removed {
                slot: m.slot,
                samples: lane.x.slice_rows(m.start, m.rows),
                nfe: m.nfe,
                delta_eps: if is_era { Some(m.delta_eps) } else { None },
                early_stop: false,
            })
            .collect();
        for m in &lane.members {
            slot_lane.remove(&m.slot);
        }
        recycle_lane(lane, pool);
        out
    }

    /// State rows of a lane (0 for an empty id).
    pub fn lane_rows(&self, id: usize) -> usize {
        self.lanes.get(id).and_then(|l| l.as_ref()).map(|l| l.x.rows()).unwrap_or(0)
    }

    /// Borrow a lane's stacked iterate (the upload payload of
    /// [`crate::runtime::resident::ResidentState::open`]).
    pub fn lane_x(&self, id: usize) -> &Tensor {
        &self.lanes[id].as_ref().expect("iterate of empty lane").x
    }

    /// Engine-side handle of a resident lane (`None` = host stepping).
    pub fn resident_handle(&self, id: usize) -> Option<u64> {
        self.lanes.get(id)?.as_ref()?.resident.as_ref().map(|r| r.handle)
    }

    /// Whether `id` can convert to engine-resident stepping: a fresh
    /// (never-evaluated, never-split) deterministic DDIM or ERA lane.
    /// Churny members need host-side RNG streams, guided lanes need
    /// the paired-eval collapse, and lanes with history would need a
    /// history upload — all stay on the host path.
    pub fn resident_eligible(&self, id: usize) -> bool {
        let Some(lane) = self.lanes.get(id).and_then(|l| l.as_ref()) else {
            return false;
        };
        if lane.done
            || lane.guided
            || lane.view.is_none()
            || lane.pending.is_some()
            || lane.resident.is_some()
            || lane.members.iter().any(|m| m.churn > 0.0 || m.conv_threshold > 0.0 || m.degraded)
        {
            return false;
        }
        match &lane.kernel {
            Kernel::Ddim { i } => *i == 0,
            Kernel::Era { i, eps, .. } => *i == 0 && eps.is_empty(),
            _ => false,
        }
    }

    /// Whether the engine should retain the full eps history for this
    /// lane (ERA interpolates over it; DDIM needs only the newest).
    pub fn resident_keeps_history(&self, id: usize) -> bool {
        let lane = self.lanes[id].as_ref().expect("residency of empty lane");
        matches!(lane.kernel, Kernel::Era { .. })
    }

    /// Mark an eligible lane engine-resident under `handle`. Seals the
    /// lane: membership is as frozen as after a first host step (and
    /// the ERA scratches the seal allocates are exactly what a later
    /// devolution steps back into).
    pub fn resident_convert(&mut self, id: usize, handle: u64) {
        debug_assert!(self.resident_eligible(id), "resident_convert of ineligible lane");
        let LaneEngine { lanes, pool, .. } = self;
        let lane = lanes[id].as_mut().expect("convert of empty lane");
        if !lane.sealed {
            seal(lane, pool);
        }
        lane.resident = Some(ResidentLane { handle, eps_len: 0 });
    }

    /// Build the next op for an idle resident lane, mirroring the host
    /// step exactly: same selection, same coefficient narrowing, same
    /// step-index bookkeeping — only the kernel applications move
    /// engine-side. ERA's grid index advances here (at op build, like
    /// `era_advance`); DDIM's advances at outcome delivery (like
    /// `ddim_deliver`).
    pub fn resident_next_op(&mut self, id: usize) -> ResidentCmd {
        let lane = self.lanes[id].as_mut().expect("resident op of empty lane");
        debug_assert!(!lane.done && lane.pending.is_none());
        let eps_len = lane.resident.as_ref().expect("op for host lane").eps_len;
        let view = lane.view.clone().expect("resident lane without a view");
        let n_points = view.grid().len();
        let Lane { kernel, members, .. } = lane;
        match kernel {
            Kernel::Ddim { i } => {
                if *i + 1 >= n_points {
                    return ResidentCmd::Op(ResidentOp::Finish { advance: None });
                }
                let (a, b) = view.ddim_coeffs(*i);
                ResidentCmd::Op(ResidentOp::Step(ResidentStep {
                    pre: None,
                    t: view.t(*i) as f32,
                    post: Some(ResidentAdvance::Newest { a, b }),
                }))
            }
            Kernel::Era { i, k, selection, idx, idx_b, abs, .. } => {
                if eps_len == 0 {
                    // First evaluation: no history to advance with yet.
                    return ResidentCmd::Op(ResidentOp::Step(ResidentStep {
                        pre: None,
                        t: view.t(*i) as f32,
                        post: None,
                    }));
                }
                let (a, b) = view.ddim_coeffs(*i);
                let adv = if *i < *k - 1 {
                    ResidentAdvance::Newest { a, b }
                } else {
                    let bi = eps_len - 1;
                    match selection {
                        Selection::FixedLast => {
                            idx.clear();
                            idx.extend((bi + 1 - *k)..=bi);
                        }
                        Selection::ErrorRobust { lambda } => {
                            select_indices_guarded(idx, bi, *k, members[0].delta_eps / *lambda);
                            // The host path would split divergent
                            // members here (`era_split_groups`); gather
                            // the lane back instead and let it.
                            for m in members.iter().skip(1) {
                                select_indices_guarded(idx_b, bi, *k, m.delta_eps / *lambda);
                                if idx_b.as_slice() != idx.as_slice() {
                                    return ResidentCmd::Devolve;
                                }
                            }
                        }
                        Selection::ConstantScale { scale } => {
                            select_indices_guarded(idx, bi, *k, *scale)
                        }
                    }
                    let w = view.lagrange_weights_into(*i + 1, idx, abs);
                    let order = eps_len.min(3) + 1;
                    let amw = view.am_weights(order);
                    ResidentAdvance::Lagrange {
                        a,
                        b,
                        idx: idx.clone(),
                        w: w.to_vec(),
                        amw: amw.to_vec(),
                    }
                };
                *i += 1;
                if *i + 1 >= n_points {
                    // Mirrors `era_advance`'s done check: the final
                    // iterate's evaluation would never be used.
                    ResidentCmd::Op(ResidentOp::Finish { advance: Some(adv) })
                } else {
                    ResidentCmd::Op(ResidentOp::Step(ResidentStep {
                        pre: Some(adv),
                        t: view.t(*i) as f32,
                        post: None,
                    }))
                }
            }
            _ => unreachable!("only DDIM/ERA lanes go resident"),
        }
    }

    /// Deliver a resident op's outcome: nfe bumps and per-member error
    /// measures (Eq. 15) on a step, the final iterate on a finish.
    pub fn resident_deliver(&mut self, id: usize, outcome: ResidentOutcome) {
        let lane = self.lanes[id].as_mut().expect("resident deliver to empty lane");
        debug_assert_eq!(outcome.rows, lane.x.rows());
        match outcome.final_x {
            Some(fx) => {
                lane.x = Arc::new(fx);
                lane.done = true;
                // The engine dropped its state with the finish op.
                lane.resident = None;
            }
            None => {
                lane.resident.as_mut().expect("deliver to host lane").eps_len += 1;
                for m in lane.members.iter_mut() {
                    m.nfe += 1;
                }
                if !outcome.row_dists.is_empty() {
                    // Same accumulation as `fused::mean_row_dist` over
                    // each member's span of the engine's row distances.
                    for m in lane.members.iter_mut() {
                        let mut acc = 0.0f64;
                        for d in &outcome.row_dists[m.start..m.start + m.rows] {
                            acc += *d;
                        }
                        m.delta_eps = ((acc / m.rows as f64) as f32) as f64;
                        observe_delta(m);
                    }
                }
                if let Kernel::Ddim { i } = &mut lane.kernel {
                    *i += 1;
                }
            }
        }
    }

    /// Gather a resident lane back to host stepping from an engine
    /// snapshot. Only legal at an idle point (no op in flight), where
    /// the engine state is bitwise what the host state would be — the
    /// next `step_lane` continues as if the lane had never left.
    pub fn resident_devolve(&mut self, id: usize, snap: ResidentSnapshot) {
        let lane = self.lanes[id].as_mut().expect("devolve of empty lane");
        let rl = lane.resident.take().expect("devolve of host lane");
        debug_assert!(!lane.done && lane.pending.is_none());
        debug_assert_eq!(snap.x.rows(), lane.x.rows());
        lane.x = Arc::new(snap.x);
        if let Kernel::Era { eps, .. } = &mut lane.kernel {
            debug_assert_eq!(snap.eps.len(), rl.eps_len);
            *eps = snap.eps;
        }
        let _ = rl;
    }

    /// Drop a lane wholesale (failure path); returns the member slots
    /// so the caller can fail their requests.
    pub fn drop_lane(&mut self, id: usize) -> Vec<usize> {
        let LaneEngine { lanes, pool, slot_lane, free, .. } = &mut *self;
        let lane = lanes[id].take().expect("drop of empty lane");
        free.push(id);
        let slots: Vec<usize> = lane.members.iter().map(|m| m.slot).collect();
        for s in &slots {
            slot_lane.remove(s);
        }
        recycle_lane(lane, pool);
        slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::eps_model::{AnalyticGmm, EpsModel, NoisyEps};
    use crate::solvers::schedule::{make_grid, GridKind, VpSchedule};
    use crate::solvers::{sample_with, TaskSpec};

    fn admission(kind: &SolverKind, nfe: usize, rows: usize, seed: u64) -> LaneAdmission {
        admission_task(kind, nfe, rows, seed, &TaskSpec::default())
    }

    fn admission_task(
        kind: &SolverKind,
        nfe: usize,
        rows: usize,
        seed: u64,
        task: &TaskSpec,
    ) -> LaneAdmission {
        let sched = VpSchedule::default();
        let steps = kind.steps_for_nfe(nfe);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let plan = Arc::new(kind.make_plan(sched, grid, nfe));
        let mut rng = Rng::for_stream(seed, 0x5eed);
        let x0 = rng.normal_tensor(rows, 2);
        let res = kind.resolve_task(plan, x0, task).expect("resolve task");
        LaneAdmission {
            kind: kind.clone(),
            view: res.view,
            x: res.x,
            churn: res.churn,
            guided: res.guided,
            seed,
            conv_threshold: 0.0,
            min_nfe: 0,
        }
    }

    /// Drive every lane to completion against `model`; returns
    /// slot -> Removed.
    fn run_all(eng: &mut LaneEngine, model: &dyn EpsModel) -> HashMap<usize, Removed> {
        let mut out = HashMap::new();
        let mut affected = Vec::new();
        loop {
            let mut progressed = false;
            for id in 0..eng.lane_slots() {
                if !eng.has_lane(id) {
                    continue;
                }
                progressed = true;
                if eng.is_done(id) {
                    for r in eng.finish_lane(id) {
                        out.insert(r.slot, r);
                    }
                    continue;
                }
                if eng.pending(id).is_none() {
                    affected.clear();
                    eng.step_lane(id, &mut affected);
                    continue;
                }
                let (x, t, cond) = {
                    let req = eng.pending(id).unwrap();
                    (Arc::clone(&req.x), req.t, req.cond.clone())
                };
                let tv = vec![t as f32; x.rows()];
                let eps = match &cond {
                    None => model.eval(&x, &tv),
                    Some(c) => model.eval_cond(&x, &tv, c),
                };
                drop(x);
                drop(cond);
                eng.deliver(id, eps);
            }
            if !progressed {
                break;
            }
        }
        out
    }

    fn reference(
        kind: &SolverKind,
        nfe: usize,
        rows: usize,
        seed: u64,
        task: &TaskSpec,
        model: &dyn EpsModel,
    ) -> (Tensor, usize) {
        let sched = VpSchedule::default();
        let steps = kind.steps_for_nfe(nfe);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let plan = Arc::new(kind.make_plan(sched, grid, nfe));
        let mut rng = Rng::for_stream(seed, 0x5eed);
        let x0 = rng.normal_tensor(rows, 2);
        let mut s = kind.build_task(plan, x0, seed, task).expect("build solver");
        let out = sample_with(s.as_mut(), model);
        (out, s.nfe())
    }

    #[test]
    fn same_config_requests_share_one_lane_until_sealed() {
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let kind = SolverKind::Ddim;
        let mut eng = LaneEngine::new(0);
        let a = admission(&kind, 8, 4, 1);
        let b = admission_with_same_plan(&a, &kind, 8, 3, 2);
        let id0 = eng.admit(0, "gmm8", a);
        let id1 = eng.admit(1, "gmm8", b);
        assert_eq!(id0, id1, "identical configs must share a lane pre-seal");
        assert_eq!(eng.members(id0).len(), 2);
        assert_eq!(eng.lane_count(), 1);
        // After the first step the lane is sealed: a third identical
        // request opens a new lane.
        let mut affected = Vec::new();
        eng.step_lane(id0, &mut affected);
        let c = admission_with_same_plan_by_id(&eng, id0, &kind, 8, 4, 3);
        let id2 = eng.admit(2, "gmm8", c);
        assert_ne!(id0, id2, "sealed lanes must not accept joins");
        let out = run_all(&mut eng, &model);
        assert_eq!(out.len(), 3);
        assert_eq!(out[&0].samples.rows(), 4);
        assert_eq!(out[&1].samples.rows(), 3);
        assert_eq!(out[&0].nfe, 8);
    }

    /// Rebuild an admission over the *same* plan Arc as `a` so lane
    /// keys match (plan identity is part of the key).
    fn admission_with_same_plan(
        a: &LaneAdmission,
        kind: &SolverKind,
        _nfe: usize,
        rows: usize,
        seed: u64,
    ) -> LaneAdmission {
        let view = a.view.clone();
        let mut rng = Rng::for_stream(seed, 0x5eed);
        LaneAdmission {
            kind: kind.clone(),
            view,
            x: rng.normal_tensor(rows, 2),
            churn: 0.0,
            guided: None,
            seed,
            conv_threshold: 0.0,
            min_nfe: 0,
        }
    }

    fn admission_with_same_plan_by_id(
        eng: &LaneEngine,
        id: usize,
        kind: &SolverKind,
        _nfe: usize,
        rows: usize,
        seed: u64,
    ) -> LaneAdmission {
        let view = eng.lanes[id].as_ref().unwrap().view.clone();
        let mut rng = Rng::for_stream(seed, 0x5eed);
        LaneAdmission {
            kind: kind.clone(),
            view,
            x: rng.normal_tensor(rows, 2),
            churn: 0.0,
            guided: None,
            seed,
            conv_threshold: 0.0,
            min_nfe: 0,
        }
    }

    #[test]
    fn stacked_ddim_lane_matches_boxed_solvers_bitwise() {
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let kind = SolverKind::Ddim;
        let mut eng = LaneEngine::new(0);
        let a = admission(&kind, 10, 5, 11);
        let b = admission_with_same_plan(&a, &kind, 10, 3, 12);
        eng.admit(0, "gmm8", a);
        eng.admit(1, "gmm8", b);
        let out = run_all(&mut eng, &model);
        for (slot, rows, seed) in [(0usize, 5usize, 11u64), (1, 3, 12)] {
            let (want, want_nfe) = reference(&kind, 10, rows, seed, &TaskSpec::default(), &model);
            assert_eq!(out[&slot].samples.as_slice(), want.as_slice(), "slot {slot}");
            assert_eq!(out[&slot].nfe, want_nfe);
            assert!(out[&slot].delta_eps.is_none());
        }
    }

    #[test]
    fn era_lane_splits_on_divergence_and_stays_bitwise() {
        // A noisy model gives each member its own delta_eps; selections
        // diverge and the lane must split while every member's
        // trajectory stays identical to its boxed solver.
        let sched = VpSchedule::default();
        let model = NoisyEps::new(AnalyticGmm::gmm8(sched), 0.8, 2.0, 5);
        let kind = SolverKind::parse("era-4@0.3").unwrap();
        let mut eng = LaneEngine::new(0);
        let a = admission(&kind, 12, 4, 21);
        let b = admission_with_same_plan(&a, &kind, 12, 4, 22);
        let c = admission_with_same_plan(&a, &kind, 12, 4, 23);
        eng.admit(0, "gmm8", a);
        eng.admit(1, "gmm8", b);
        eng.admit(2, "gmm8", c);
        let out = run_all(&mut eng, &model);
        for (slot, seed) in [(0usize, 21u64), (1, 22), (2, 23)] {
            let (want, want_nfe) = reference(&kind, 12, 4, seed, &TaskSpec::default(), &model);
            assert_eq!(out[&slot].samples.as_slice(), want.as_slice(), "slot {slot}");
            assert_eq!(out[&slot].nfe, want_nfe);
            assert!(out[&slot].delta_eps.is_some(), "era lanes report delta_eps");
        }
    }

    #[test]
    fn compaction_mid_trajectory_leaves_survivors_bitwise() {
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let kind = SolverKind::parse("era").unwrap();
        let mut eng = LaneEngine::new(0);
        let a = admission(&kind, 10, 4, 31);
        let b = admission_with_same_plan(&a, &kind, 10, 2, 32);
        let c = admission_with_same_plan(&a, &kind, 10, 3, 33);
        let id = eng.admit(0, "gmm8", a);
        eng.admit(1, "gmm8", b);
        eng.admit(2, "gmm8", c);
        // Step + deliver four rounds, then retire the middle member.
        let mut affected = Vec::new();
        for _ in 0..4 {
            for lid in 0..eng.lane_slots() {
                if eng.has_lane(lid) && eng.pending(lid).is_none() && !eng.is_done(lid) {
                    affected.clear();
                    eng.step_lane(lid, &mut affected);
                }
            }
            for lid in 0..eng.lane_slots() {
                if !eng.has_lane(lid) {
                    continue;
                }
                if let Some(req) = eng.pending(lid) {
                    let x = Arc::clone(&req.x);
                    let tv = vec![req.t as f32; x.rows()];
                    let eps = model.eval(&x, &tv);
                    drop(x);
                    eng.deliver(lid, eps);
                }
            }
        }
        let removed = eng.remove_member(id, 1, None);
        assert_eq!(removed.samples.rows(), 2);
        assert!(removed.nfe > 0 && removed.nfe < 10, "partial nfe, got {}", removed.nfe);
        let out = run_all(&mut eng, &model);
        for (slot, rows, seed) in [(0usize, 4usize, 31u64), (2, 3, 33)] {
            let (want, _) = reference(&kind, 10, rows, seed, &TaskSpec::default(), &model);
            assert_eq!(
                out[&slot].samples.as_slice(),
                want.as_slice(),
                "survivor {slot} perturbed by compaction"
            );
        }
    }

    #[test]
    fn zero_transition_lane_is_done_at_admit() {
        let kind = SolverKind::Ddim;
        let task = TaskSpec {
            strength: 0.0,
            init: Some(Tensor::from_vec(vec![1.0, -1.0, 0.5, 2.0], 2, 2)),
            ..Default::default()
        };
        let adm = admission_task(&kind, 8, 2, 7, &task);
        let mut eng = LaneEngine::new(0);
        let id = eng.admit(9, "gmm8", adm);
        assert!(eng.is_done(id));
        let out = eng.finish_lane(id);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].nfe, 0);
        assert_eq!(out[0].samples.rows(), 2);
        assert_eq!(eng.lane_count(), 0);
    }

    #[test]
    fn lane_cap_limits_joins() {
        let kind = SolverKind::Ddim;
        let mut eng = LaneEngine::new(6);
        let a = admission(&kind, 8, 4, 1);
        let b = admission_with_same_plan(&a, &kind, 8, 4, 2);
        let id0 = eng.admit(0, "gmm8", a);
        let id1 = eng.admit(1, "gmm8", b);
        assert_ne!(id0, id1, "join would exceed the lane row cap");
    }
}
