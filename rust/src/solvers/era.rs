//! ERA-Solver (the paper's contribution, Alg. 1).
//!
//! Predictor–corrector on the diffusion ODE where
//! * the **predictor** is a Lagrange interpolation (Eq. 13/14) over `k`
//!   noise estimates chosen from the *Lagrange buffer* of everything
//!   observed so far (Eq. 12) — zero extra network evaluations;
//! * the buffer indices are chosen by the **error-robust selection**
//!   (ERS): uniform initial indices (Eq. 16) warped through a power
//!   function whose exponent is the measured prediction error
//!   `delta_eps / lambda` (Eq. 17), biasing toward *earlier* (more
//!   accurate, per Fig. 1) estimates when the error grows;
//! * the **error measure** `delta_eps` is the distance between what the
//!   predictor said the noise at `t_i` would be and what the network
//!   actually returned there (Eq. 15) — a reference-free proxy for the
//!   network's estimation error, validated against the training-time
//!   error curve (Fig. 3 vs Fig. 1);
//! * the **corrector** is Adams–Moulton order 4 (Eq. 11) with the
//!   predicted noise in the implicit slot.
//!
//! The first `k-1` transitions bootstrap the buffer with plain DDIM
//! (Alg. 1 line 5-7). Each transition costs exactly one network
//! evaluation — at the *new* point `(x_{t_{i+1}}, t_{i+1})`, which both
//! refreshes the buffer and scores the predictor — except the final one,
//! whose evaluation no future step would consume and is therefore
//! skipped; total NFE equals the number of grid transitions.
//!
//! Hot-path layout: all interpolation math is amortised off the network
//! *and* off the step. DDIM/AM coefficients and the per-`(step,
//! indices)` Lagrange weights come from the shared [`TrajectoryPlan`]
//! (weights are memoised across requests); the iterate, predictor and
//! corrector buffers update in place; buffer entries are adopted by
//! move into preallocated storage. A steady-state ERA step performs
//! zero heap allocations (pinned by `benches/bench_step_overhead.rs`).

use std::sync::Arc;

use crate::kernels::{fused, PlanView, TrajectoryPlan};
use crate::rng::Rng;
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// RNG stream id for the stochastic-ERA churn noise. Per-request:
/// `Rng::for_stream(seed, CHURN_STREAM)` — independent of the prior
/// noise (0x5eed) and DDPM ancestral (0xD0) streams, and consumed in a
/// fixed per-transition order, so the trajectory is bit-reproducible
/// however the request is batched or sharded.
pub const CHURN_STREAM: u64 = 0x5DE0;

/// How the Lagrange bases are selected from the buffer (the paper's
/// ablation axis: Tab. 4/5 and Fig. 5/6).
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    /// Eq. 16/17 with exponent `delta_eps / lambda` (the contribution).
    ErrorRobust { lambda: f64 },
    /// `tau_m = i - m`: always the newest k entries (Tab. 4/5 "fixed").
    FixedLast,
    /// Eq. 17 with a constant exponent instead of the error measure
    /// (Fig. 5/6 "constant scale" ablation).
    ConstantScale { scale: f64 },
}

/// A record of one ERS decision, kept for the Fig. 3 diagnostics.
#[derive(Clone, Debug)]
pub struct SelectionTrace {
    /// Solver step index i at which the selection was made.
    pub step: usize,
    /// Measured error (Eq. 15) in force at that step.
    pub delta_eps: f64,
    /// Buffer indices chosen as Lagrange bases (ascending).
    pub indices: Vec<usize>,
}

/// Compute the selected buffer indices for buffer length `i + 1`
/// (entries `0..=i`), interpolation order `k` and power-function
/// exponent `p` (Eq. 16/17). Exposed for property tests.
///
/// Indices are returned ascending, pairwise distinct, within `0..=i`,
/// and always include `i` (the newest estimate anchors the interpolant
/// at the current time). Floor-induced collisions are resolved by
/// shifting the earlier index down — this preserves the "lean earlier
/// when the error is high" intent while keeping the Lagrange system
/// nonsingular.
pub fn select_indices(i: usize, k: usize, p: f64) -> Vec<usize> {
    let mut idx = Vec::with_capacity(k);
    select_indices_into(&mut idx, i, k, p);
    idx
}

/// Guarded form of [`select_indices_into`]: a non-finite exponent
/// (NaN/Inf eps from the model poisons the `mean_row_dist` fold, so
/// `delta_eps / lambda` stops being a number) falls back to the
/// newest-k bases — the same indices `Selection::FixedLast` would
/// pick. `NaN.powf` ordering is unspecified, so without the guard the
/// Lagrange-basis choice becomes nondeterministic; with it, every
/// caller (boxed solver, lane engine, resident path) degrades to the
/// identical deterministic selection and batch-mates stay untouched.
pub fn select_indices_guarded(idx: &mut Vec<usize>, i: usize, k: usize, p: f64) {
    if p.is_finite() {
        select_indices_into(idx, i, k, p);
    } else {
        assert!(k >= 1 && i + 1 >= k, "buffer too short: i={i}, k={k}");
        idx.clear();
        idx.extend((i + 1 - k)..=i);
    }
}

/// In-place form of [`select_indices`]: fills `idx` (cleared first) so
/// the per-step selection reuses one scratch vector.
pub fn select_indices_into(idx: &mut Vec<usize>, i: usize, k: usize, p: f64) {
    assert!(k >= 1 && i + 1 >= k, "buffer too short: i={i}, k={k}");
    idx.clear();
    if i == 0 {
        idx.push(0);
        return;
    }
    // Eq. 16: uniform cover tau_hat_m = (i/k)*m for m = 1..=k, then
    // Eq. 17: tau_m = floor((tau_hat_m / i)^p * i). Note tau_hat_m / i
    // is exactly m/k, which keeps m = k pinned at 1.0 (computing
    // (i/k)*m / i in floats can round below 1 and unanchor the newest
    // entry — caught by prop_select_indices_invariants).
    for m in 1..=k {
        let frac = m as f64 / k as f64;
        let tau = (frac.powf(p) * i as f64).floor() as usize;
        idx.push(tau.min(i));
    }
    // The newest estimate always anchors the interpolant at the current
    // time; resolve floor collisions by pushing earlier entries down
    // (backward pass keeps the "lean earlier when error is high" intent
    // and the Lagrange system nonsingular). Pre-clamp each slot into the
    // band that leaves room for its neighbours — extreme exponents
    // collapse every warped index to 0 (p >> 1) or i (p << 1), and the
    // band is what guarantees the backward pass cannot underflow.
    idx[k - 1] = i;
    for (m, v) in idx.iter_mut().enumerate() {
        *v = (*v).clamp(m, i - (k - 1 - m));
    }
    for m in (0..k - 1).rev() {
        if idx[m] >= idx[m + 1] {
            idx[m] = idx[m + 1] - 1;
        }
    }
    debug_assert!(idx.windows(2).all(|w| w[0] < w[1]));
    debug_assert_eq!(*idx.last().unwrap(), i);
}

/// ERA-Solver state machine (one concurrent sampling request).
pub struct EraSolver {
    plan: PlanView,
    x: Arc<Tensor>,
    i: usize,
    nfe: usize,
    k: usize,
    selection: Selection,
    /// Lagrange buffer Omega (Eq. 12): `eps[n]` is the noise the network
    /// returned at grid point n (entries adopt the model's output by
    /// move; storage preallocated for the whole trajectory).
    eps: Vec<Tensor>,
    /// Eq. 15, initialised to lambda so the first exponent is 1
    /// (identity warp), per Alg. 1 line 2.
    delta_eps: f64,
    /// Predictor output awaiting scoring against the next observation.
    pred: Tensor,
    has_pred: bool,
    /// Corrector combination scratch.
    eps_c: Tensor,
    /// ERS selection scratch (capacity k).
    idx_buf: Vec<usize>,
    /// Absolute-index scratch for suffix-view Lagrange memo lookups.
    abs_buf: Vec<usize>,
    /// SDE churn level (0 = deterministic ERA). When positive, each
    /// interior transition is followed by `churn * sqrt(var_ddpm)`-scaled
    /// Gaussian noise from the per-request stream (SA-Solver-style
    /// stochastic Adams sampling on top of the error-robust predictor).
    churn: f64,
    /// Churn stream + preallocated noise scratch (empty when churn = 0).
    noise_rng: Rng,
    z: Tensor,
    pending: bool,
    done: bool,
    /// Flat preallocated ERS decision log: `(step, delta_eps)` plus k
    /// indices per corrected step (Fig. 3 diagnostics without per-step
    /// allocation).
    trace_meta: Vec<(usize, f64)>,
    trace_idx: Vec<usize>,
}

impl EraSolver {
    pub fn new(
        sched: VpSchedule,
        grid: Vec<f64>,
        x0: Tensor,
        k: usize,
        selection: Selection,
    ) -> Self {
        assert!(grid.len() >= 2, "need at least one transition");
        EraSolver::with_plan(Arc::new(TrajectoryPlan::new(sched, grid)), x0, k, selection)
    }

    /// Build over a shared precomputed plan (the serving path; the
    /// plan's Lagrange memo is then shared across requests).
    pub fn with_plan(
        plan: Arc<TrajectoryPlan>,
        x0: Tensor,
        k: usize,
        selection: Selection,
    ) -> Self {
        EraSolver::with_view(PlanView::full(plan), x0, k, selection, 0.0, 0)
    }

    /// Build over a (possibly suffix) window of a shared plan, with an
    /// optional stochastic churn level. `seed` feeds only the churn
    /// stream; deterministic trajectories (`churn = 0`) ignore it.
    pub fn with_view(
        plan: PlanView,
        x0: Tensor,
        k: usize,
        selection: Selection,
        churn: f64,
        seed: u64,
    ) -> Self {
        let n_points = plan.grid().len();
        assert!(n_points >= 2, "need at least one transition");
        assert!(k >= 2, "interpolation order k must be >= 2");
        assert!(
            n_points > k,
            "NFE budget {} too small for order k={k} (needs > k transitions)",
            n_points - 1
        );
        assert!(churn >= 0.0, "churn must be nonnegative");
        let lambda = match selection {
            Selection::ErrorRobust { lambda } => lambda,
            _ => 1.0,
        };
        let (rows, cols) = (x0.rows(), x0.cols());
        let steps = n_points - 1;
        EraSolver {
            plan,
            x: Arc::new(x0),
            i: 0,
            nfe: 0,
            k,
            selection,
            eps: Vec::with_capacity(n_points),
            delta_eps: lambda,
            pred: Tensor::zeros(rows, cols),
            has_pred: false,
            eps_c: Tensor::zeros(rows, cols),
            idx_buf: Vec::with_capacity(k),
            abs_buf: Vec::with_capacity(k),
            churn,
            noise_rng: Rng::for_stream(seed, CHURN_STREAM),
            z: if churn > 0.0 { Tensor::zeros(rows, cols) } else { Tensor::zeros(0, 0) },
            pending: false,
            done: false,
            trace_meta: Vec::with_capacity(steps),
            trace_idx: Vec::with_capacity(steps * k),
        }
    }

    /// The power-function exponent of Eq. 17 under the active selection.
    fn exponent(&self) -> f64 {
        match &self.selection {
            Selection::ErrorRobust { lambda } => self.delta_eps / lambda,
            Selection::ConstantScale { scale } => *scale,
            Selection::FixedLast => 1.0, // unused
        }
    }

    /// One transition x_{t_i} -> x_{t_{i+1}} using everything buffered.
    /// Returns true when the predictor ran (main, corrected phase).
    fn advance(&mut self) -> bool {
        let (a, b) = self.plan.ddim_coeffs(self.i);

        let ran_predictor = if self.i < self.k - 1 {
            // Warmup (Alg. 1 line 5-7): plain DDIM with the newest eps.
            let newest = self.eps.last().expect("advance before first eval");
            let x = Arc::make_mut(&mut self.x);
            fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, newest.as_slice());
            false
        } else {
            // ERS selection (Eq. 16/17) over buffer entries 0..=bi.
            let bi = self.eps.len() - 1;
            match &self.selection {
                Selection::FixedLast => {
                    // tau_m = i - m, ascending.
                    self.idx_buf.clear();
                    self.idx_buf.extend((bi + 1 - self.k)..=bi);
                }
                _ => {
                    let p = self.exponent();
                    select_indices_guarded(&mut self.idx_buf, bi, self.k, p);
                }
            }
            self.trace_meta.push((self.i, self.delta_eps));
            self.trace_idx.extend_from_slice(&self.idx_buf);

            // Predictor (Eq. 13/14, Alg. 1 line 9-12): interpolate the
            // selected bases at t_{i+1}. Basis weights are memoised in
            // the shared plan (suffix views translate to absolute grid
            // indices, so all strengths share one memo).
            let w = self.plan.lagrange_weights_into(self.i + 1, &self.idx_buf, &mut self.abs_buf);
            fused::zero(self.pred.as_mut_slice());
            for (&n, &wm) in self.idx_buf.iter().zip(w.iter()) {
                fused::axpy(self.pred.as_mut_slice(), wm as f32, self.eps[n].as_slice());
            }

            // Corrector (line 13, Eq. 11): AM4 with eps_pred in the
            // implicit slot and the newest buffered estimates in the
            // explicit slots.
            let n = self.eps.len();
            let order = n.min(3) + 1; // implicit slot + up to 3 history slots
            let amw = self.plan.am_weights(order);
            fused::zero(self.eps_c.as_mut_slice());
            fused::axpy(self.eps_c.as_mut_slice(), amw[0] as f32, self.pred.as_slice());
            for back in 0..order - 1 {
                fused::axpy(
                    self.eps_c.as_mut_slice(),
                    amw[back + 1] as f32,
                    self.eps[n - 1 - back].as_slice(),
                );
            }
            let x = Arc::make_mut(&mut self.x);
            fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, self.eps_c.as_slice());
            true
        };
        self.i += 1;

        // Stochastic variant: ancestral-scale churn after every interior
        // transition (never on the final one — the endpoint stays a data
        // sample). The scale is the DDPM posterior std of the transition
        // just taken, multiplied by the churn factor; the predictor's
        // next error measurement then sees the perturbation, which is
        // exactly the estimation-error regime ERS is built for.
        if self.churn > 0.0 && self.i + 1 < self.plan.grid().len() {
            let ab_prev = self.plan.alpha_bar_at(self.i - 1);
            let ab_cur = self.plan.alpha_bar_at(self.i);
            let alpha = ab_prev / ab_cur;
            let var = (1.0 - ab_cur) / (1.0 - ab_prev) * (1.0 - alpha);
            if var > 0.0 {
                self.noise_rng.fill_normal(self.z.as_mut_slice());
                let x = Arc::make_mut(&mut self.x);
                fused::axpy(
                    x.as_mut_slice(),
                    (self.churn * var.sqrt()) as f32,
                    self.z.as_slice(),
                );
            }
        }
        ran_predictor
    }

    /// ERS decision log (Fig. 3 diagnostics), materialised from the
    /// flat per-step records.
    pub fn selection_trace(&self) -> Vec<SelectionTrace> {
        self.trace_meta
            .iter()
            .enumerate()
            .map(|(j, &(step, delta_eps))| SelectionTrace {
                step,
                delta_eps,
                indices: self.trace_idx[j * self.k..(j + 1) * self.k].to_vec(),
            })
            .collect()
    }

    /// Current Eq. 15 error measure.
    pub fn delta_eps(&self) -> f64 {
        self.delta_eps
    }
}

impl Solver for EraSolver {
    fn name(&self) -> String {
        let base = match &self.selection {
            Selection::ErrorRobust { .. } => format!("era-{}", self.k),
            Selection::FixedLast => format!("era-fixed-{}", self.k),
            Selection::ConstantScale { .. } => format!("era-const-{}", self.k),
        };
        if self.churn > 0.0 {
            format!("sde-{base}")
        } else {
            base
        }
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.done {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        if self.eps.is_empty() {
            // Alg. 1 line 3: seed the buffer at (x_{t_0}, t_0).
            self.pending = true;
            return Some(EvalRequest { x: Arc::clone(&self.x), t: self.plan.t(0), cond: None });
        }
        // Advance one transition; the evaluation (if any) happens at the
        // *new* point, which feeds both the buffer and the error measure.
        self.has_pred = self.advance();
        if self.i + 1 >= self.plan.grid().len() {
            // Final iterate reached; its evaluation would never be used.
            self.done = true;
            return None;
        }
        self.pending = true;
        Some(EvalRequest { x: Arc::clone(&self.x), t: self.plan.t(self.i), cond: None })
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;
        // Update the error measure (Eq. 15 / Alg. 1 line 16) against what
        // the predictor claimed this noise would be.
        if self.has_pred {
            self.has_pred = false;
            self.delta_eps = fused::mean_row_dist(
                eps.as_slice(),
                self.pred.as_slice(),
                eps.rows(),
                eps.cols(),
            ) as f64;
        }
        self.eps.push(eps);
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn nfe(&self) -> usize {
        self.nfe
    }

    fn delta_eps(&self) -> Option<f64> {
        Some(self.delta_eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, CountingEps, NoisyEps};
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    fn gmm_reference() -> metrics::Moments {
        metrics::Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225])
    }

    #[test]
    fn select_indices_identity_exponent_is_uniform() {
        // p = 1 leaves Eq. 16's uniform cover untouched.
        let idx = select_indices(12, 4, 1.0);
        assert_eq!(idx, vec![3, 6, 9, 12]);
    }

    #[test]
    fn select_indices_high_error_leans_early() {
        // Larger exponent pushes all non-anchor indices toward 0.
        let lo = select_indices(12, 4, 1.0);
        let hi = select_indices(12, 4, 3.0);
        assert_eq!(*hi.last().unwrap(), 12);
        for (a, b) in hi.iter().zip(lo.iter()).take(3) {
            assert!(a <= b, "{hi:?} vs {lo:?}");
        }
        assert!(hi[0] < lo[0]);
    }

    #[test]
    fn select_indices_low_scale_leans_late() {
        // Exponent < 1 warps toward the newest entries.
        let lo = select_indices(12, 4, 0.3);
        assert!(lo[0] >= 3, "{lo:?}");
    }

    #[test]
    fn select_indices_always_valid() {
        // Distinct, ascending, in range, anchored at i — across the whole
        // operating envelope (also exercised by proptests at larger scale).
        for i in 1..60 {
            for k in 2..=6.min(i + 1) {
                for &p in &[0.1, 0.5, 1.0, 2.0, 5.0, 20.0] {
                    let idx = select_indices(i, k, p);
                    assert_eq!(idx.len(), k);
                    assert!(idx.windows(2).all(|w| w[0] < w[1]), "i={i} k={k} p={p}: {idx:?}");
                    assert!(*idx.last().unwrap() == i);
                    assert!(idx[0] <= i);
                }
            }
        }
    }

    #[test]
    fn select_indices_guarded_falls_back_to_newest_k() {
        // Finite exponents are passed through untouched...
        let mut a = Vec::new();
        let mut b = Vec::new();
        select_indices_guarded(&mut a, 12, 4, 2.0);
        select_indices_into(&mut b, 12, 4, 2.0);
        assert_eq!(a, b);
        // ...while NaN / Inf degrade to the FixedLast indices, always
        // the same ones (deterministic under a poisoned error signal).
        for p in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            select_indices_guarded(&mut a, 12, 4, p);
            assert_eq!(a, vec![9, 10, 11, 12], "p={p}");
        }
    }

    #[test]
    fn select_indices_into_reuses_buffer() {
        let mut buf = Vec::with_capacity(4);
        select_indices_into(&mut buf, 12, 4, 1.0);
        assert_eq!(buf, vec![3, 6, 9, 12]);
        select_indices_into(&mut buf, 20, 3, 2.0);
        assert_eq!(buf.len(), 3);
        assert_eq!(*buf.last().unwrap(), 20);
    }

    #[test]
    fn one_nfe_per_transition() {
        let sched = VpSchedule::default();
        let nfe = 10;
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let mut s = EraSolver::new(
            sched,
            grid,
            rng.normal_tensor(8, 2),
            4,
            Selection::ErrorRobust { lambda: 5.0 },
        );
        let m = CountingEps::new(AnalyticGmm::gmm8(sched));
        let _ = sample_with(&mut s, &m);
        assert_eq!(s.nfe(), nfe);
        assert_eq!(m.calls(), nfe);
    }

    #[test]
    fn converges_with_exact_model() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 20, 1.0, 1e-3);
        let mut rng = Rng::new(1);
        let mut s = EraSolver::new(
            sched,
            grid,
            rng.normal_tensor(500, 2),
            4,
            Selection::ErrorRobust { lambda: 5.0 },
        );
        let out = sample_with(&mut s, &AnalyticGmm::gmm8(sched));
        assert!(out.all_finite());
        let cov = metrics::mode_coverage(&out, &crate::data::gmm8_modes(), 0.5);
        assert!(cov > 0.95, "mode coverage {cov}");
    }

    #[test]
    fn beats_ddim_at_low_nfe_exact_model() {
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let reference = gmm_reference();
        let nfe = 10;
        let mut rng = Rng::new(2);
        let x0 = rng.normal_tensor(2000, 2);
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);

        let mut era = EraSolver::new(
            sched,
            grid.clone(),
            x0.clone(),
            4,
            Selection::ErrorRobust { lambda: 5.0 },
        );
        let fid_era = metrics::fid(&sample_with(&mut era, &model), &reference);
        let mut dd = crate::solvers::ddim::Ddim::new(sched, grid, x0);
        let fid_ddim = metrics::fid(&sample_with(&mut dd, &model), &reference);
        assert!(fid_era < fid_ddim, "era {fid_era} vs ddim {fid_ddim}");
    }

    #[test]
    fn ers_beats_fixed_under_error_high_order() {
        // The paper's Tab. 4 contrast: with a noisy model and a
        // high-order predictor (k=6), fixed selection destabilises
        // (paper: FID 315 at NFE 20) while ERS stays usable.
        let sched = VpSchedule::default();
        let model = NoisyEps::new(AnalyticGmm::gmm8(sched), 1.5, 2.0, 5);
        let reference = gmm_reference();
        let run = |selection: Selection| {
            let grid = make_grid(&sched, GridKind::Uniform, 15, 1.0, 1e-3);
            let mut rng = Rng::new(3);
            let mut s =
                EraSolver::new(sched, grid, rng.normal_tensor(1500, 2), 6, selection);
            metrics::fid(&sample_with(&mut s, &model), &reference)
        };
        let fid_ers = run(Selection::ErrorRobust { lambda: 5.0 });
        let fid_fixed = run(Selection::FixedLast);
        assert!(
            fid_ers < fid_fixed / 3.0,
            "ERS {fid_ers} should decisively beat fixed {fid_fixed} under error"
        );
    }

    #[test]
    fn trace_records_every_corrected_step() {
        let sched = VpSchedule::default();
        let nfe = 12;
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let mut rng = Rng::new(4);
        let mut s = EraSolver::new(
            sched,
            grid,
            rng.normal_tensor(4, 2),
            4,
            Selection::ErrorRobust { lambda: 5.0 },
        );
        let _ = sample_with(&mut s, &AnalyticGmm::gmm8(sched));
        // Corrected steps: transitions k-1 .. nfe-1.
        assert_eq!(s.selection_trace().len(), nfe - (4 - 1));
        for tr in s.selection_trace() {
            assert!(tr.delta_eps >= 0.0);
            assert_eq!(tr.indices.len(), 4);
        }
    }

    #[test]
    fn delta_eps_small_for_exact_model() {
        // With a perfect model the predictor converges on the truth and
        // the measured error stays small relative to a noisy model's.
        let sched = VpSchedule::default();
        let run = |noisy: bool| {
            let grid = make_grid(&sched, GridKind::Uniform, 15, 1.0, 1e-3);
            let mut rng = Rng::new(6);
            let mut s = EraSolver::new(
                sched,
                grid,
                rng.normal_tensor(64, 2),
                4,
                Selection::ErrorRobust { lambda: 5.0 },
            );
            let clean = AnalyticGmm::gmm8(sched);
            if noisy {
                let m = NoisyEps::new(AnalyticGmm::gmm8(sched), 0.8, 2.0, 8);
                let _ = sample_with(&mut s, &m);
            } else {
                let _ = sample_with(&mut s, &clean);
            }
            let sum: f64 = s.selection_trace().iter().skip(1).map(|t| t.delta_eps).sum();
            sum / (s.selection_trace().len() - 1) as f64
        };
        assert!(run(false) < run(true));
    }

    #[test]
    fn constant_scale_matches_error_robust_shape() {
        // ConstantScale is the Fig. 5/6 ablation: it must run end to end
        // and produce finite samples for a range of scales.
        let sched = VpSchedule::default();
        for &scale in &[0.25, 0.5, 1.0, 2.0, 4.0] {
            let grid = make_grid(&sched, GridKind::Uniform, 12, 1.0, 1e-3);
            let mut rng = Rng::new(7);
            let mut s = EraSolver::new(
                sched,
                grid,
                rng.normal_tensor(32, 2),
                3,
                Selection::ConstantScale { scale },
            );
            let out = sample_with(&mut s, &AnalyticGmm::gmm8(sched));
            assert!(out.all_finite(), "scale {scale}");
        }
    }

    #[test]
    fn shared_plan_requests_agree_with_private_plans() {
        // Two requests over one shared plan (the serving path, memo
        // shared) must match a run with a private plan bit for bit.
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 12, 1.0, 1e-3);
        let model = AnalyticGmm::gmm8(sched);
        let shared = Arc::new(TrajectoryPlan::new(sched, grid.clone()));
        // Identical seeds: the second request replays the first's ERS
        // decisions, so its Lagrange lookups must hit the shared memo.
        for seed in [11u64, 11] {
            let mut rng = Rng::new(seed);
            let x0 = rng.normal_tensor(16, 2);
            let sel = Selection::ErrorRobust { lambda: 5.0 };
            let mut a = EraSolver::with_plan(shared.clone(), x0.clone(), 4, sel.clone());
            let mut b = EraSolver::new(sched, grid.clone(), x0, 4, sel);
            assert_eq!(
                sample_with(&mut a, &model).as_slice(),
                sample_with(&mut b, &model).as_slice(),
                "seed {seed}"
            );
        }
        assert!(shared.lagrange_hits() > 0, "second request must hit the shared memo");
    }

    #[test]
    fn stochastic_era_is_seed_deterministic_and_differs_from_ode() {
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let run = |churn: f64, seed: u64| {
            let grid = make_grid(&sched, GridKind::Uniform, 14, 1.0, 1e-3);
            let plan = Arc::new(TrajectoryPlan::new(sched, grid));
            let mut rng = Rng::new(9);
            let x0 = rng.normal_tensor(16, 2);
            let mut s = EraSolver::with_view(
                crate::kernels::PlanView::full(plan),
                x0,
                4,
                Selection::ErrorRobust { lambda: 5.0 },
                churn,
                seed,
            );
            sample_with(&mut s, &model)
        };
        let a = run(0.4, 1);
        let b = run(0.4, 1);
        let c = run(0.4, 2);
        let ode = run(0.0, 1);
        assert_eq!(a.as_slice(), b.as_slice(), "same seed must replay exactly");
        assert_ne!(a.as_slice(), c.as_slice(), "distinct seeds must differ");
        assert_ne!(a.as_slice(), ode.as_slice(), "churn must perturb the ODE path");
        assert!(a.all_finite());
        // The churned trajectory still lands on the data manifold.
        let big = {
            let grid = make_grid(&sched, GridKind::Uniform, 20, 1.0, 1e-3);
            let plan = Arc::new(TrajectoryPlan::new(sched, grid));
            let mut rng = Rng::new(10);
            let mut s = EraSolver::with_view(
                crate::kernels::PlanView::full(plan),
                rng.normal_tensor(400, 2),
                4,
                Selection::ErrorRobust { lambda: 5.0 },
                0.3,
                7,
            );
            sample_with(&mut s, &model)
        };
        let cov = metrics::mode_coverage(&big, &crate::data::gmm8_modes(), 0.5);
        assert!(cov > 0.9, "stochastic coverage {cov}");
    }

    #[test]
    fn suffix_view_runs_the_tail_of_the_grid() {
        // An ERA trajectory over a suffix view consumes exactly the
        // remaining transitions and shares the full plan's memo.
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let grid = make_grid(&sched, GridKind::Uniform, 16, 1.0, 1e-3);
        let plan = Arc::new(TrajectoryPlan::new(sched, grid));
        let view = crate::kernels::PlanView::suffix(plan.clone(), 6);
        let mut rng = Rng::new(12);
        let mut s = EraSolver::with_view(
            view,
            rng.normal_tensor(8, 2),
            4,
            Selection::ErrorRobust { lambda: 5.0 },
            0.0,
            0,
        );
        let out = sample_with(&mut s, &model);
        assert_eq!(s.nfe(), 10, "suffix of 10 transitions = 10 evals");
        assert!(out.all_finite());
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn rejects_budget_below_order() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 3, 1.0, 1e-3);
        let _ = EraSolver::new(
            sched,
            grid,
            Tensor::zeros(1, 2),
            4,
            Selection::ErrorRobust { lambda: 5.0 },
        );
    }
}
