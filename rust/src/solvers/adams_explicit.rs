//! Explicit linear-multistep baselines from Liu et al. 2021:
//!
//! * **PNDM** — pseudo linear multistep: the Adams–Bashforth-4 noise
//!   combination (paper Eq. 9) pushed through the DDIM transfer (Eq. 8),
//!   warmed up with 3 pseudo-Runge–Kutta steps (4 evals each — this is
//!   why the paper's PNDM rows start at NFE 13/15).
//! * **FON** — classic fourth-order explicit Adams applied directly to
//!   the probability-flow ODE
//!       dx/dt = -0.5 beta(t) x + 0.5 beta(t) eps_theta(x,t) / sigma(t),
//!   warmed up with plain RK4. Uses fixed AB4 coefficients, i.e. assumes
//!   a uniform grid (the configuration the paper runs it in).
//!
//! The warmup stages evaluate at off-grid midpoints and may allocate;
//! the multistep phase (everything after the first 3 steps) runs
//! allocation-free: AB4 combinations into a reusable scratch, the
//! transfer in place with plan coefficients, and history in a
//! [`HistoryRing`] whose evicted slot becomes FON's next drift scratch.

use std::sync::Arc;

use crate::kernels::{fused, HistoryRing, PlanView, ScratchArena, TrajectoryPlan};
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// AB4 weights (Eq. 9), newest history first.
pub const AB4: [f64; 4] = [55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0];

#[derive(Clone, Copy, PartialEq, Debug)]
enum Variant {
    Pndm,
    Fon,
}

/// Progress inside one pseudo-RK warmup step (4 evaluations).
#[derive(Clone, Copy, PartialEq, Debug)]
enum Stage {
    S1,
    S2,
    S3,
    S4,
    /// Past warmup: one eval per multistep transition.
    Multi,
}

/// Probability-flow drift `f = -0.5 beta x + 0.5 beta eps / sigma` into
/// a caller-owned buffer (FON's working quantity). Public so the lane
/// engine's stacked FON stepping shares the exact expression.
pub fn drift_into(sched: &VpSchedule, out: &mut [f32], x: &[f32], eps: &[f32], t: f64) {
    let beta = sched.beta_min + t * (sched.beta_max - sched.beta_min);
    let sigma = sched.sigma(t).max(1e-12);
    out.copy_from_slice(x);
    fused::scale(out, (-0.5 * beta) as f32);
    fused::axpy(out, (0.5 * beta / sigma) as f32, eps);
}

pub struct ExplicitAdams {
    plan: PlanView,
    variant: Variant,
    x: Arc<Tensor>,
    i: usize,
    nfe: usize,
    stage: Stage,
    /// Newest-first history: eps values (PNDM) or f values (FON).
    hist: HistoryRing,
    /// RK intermediates of the current warmup step.
    rk: Vec<Tensor>,
    /// x at the start of the current warmup step.
    x_base: Option<Arc<Tensor>>,
    /// Outstanding request (x, t), kept to derive f from eps for FON.
    pending: Option<(Arc<Tensor>, f64)>,
    warmup_steps: usize,
    /// AB4 combination scratch (multistep phase).
    combo: Tensor,
    /// FON drift scratch; swaps through the history ring so steady
    /// steps reuse the evicted slot instead of allocating.
    drift_scratch: Tensor,
    /// Warmup-stage point buffers: each RK stage takes one, and the
    /// stage's evaluated point is given back in `on_eval` once its
    /// `Arc` unwinds to a single owner (balanced take/give).
    arena: ScratchArena,
}

impl ExplicitAdams {
    pub fn new_pndm(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        Self::new(sched, grid, x0, Variant::Pndm)
    }

    pub fn new_fon(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        Self::new(sched, grid, x0, Variant::Fon)
    }

    fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, variant: Variant) -> Self {
        Self::with_plan(Arc::new(TrajectoryPlan::new(sched, grid)), x0, variant)
    }

    /// Build over a shared precomputed plan (the serving path).
    pub fn with_plan_pndm(plan: Arc<TrajectoryPlan>, x0: Tensor) -> Self {
        Self::with_plan(plan, x0, Variant::Pndm)
    }

    pub fn with_plan_fon(plan: Arc<TrajectoryPlan>, x0: Tensor) -> Self {
        Self::with_plan(plan, x0, Variant::Fon)
    }

    /// Build over a (possibly suffix) window of a shared plan.
    pub fn with_view_pndm(view: PlanView, x0: Tensor) -> Self {
        Self::with_view(view, x0, Variant::Pndm)
    }

    pub fn with_view_fon(view: PlanView, x0: Tensor) -> Self {
        Self::with_view(view, x0, Variant::Fon)
    }

    fn with_plan(plan: Arc<TrajectoryPlan>, x0: Tensor, variant: Variant) -> Self {
        Self::with_view(PlanView::full(plan), x0, variant)
    }

    fn with_view(plan: PlanView, x0: Tensor, variant: Variant) -> Self {
        assert!(plan.grid().len() >= 5, "PNDM/FON need >= 4 transitions (>= 13 NFE)");
        let (rows, cols) = (x0.rows(), x0.cols());
        ExplicitAdams {
            plan,
            variant,
            x: Arc::new(x0),
            i: 0,
            nfe: 0,
            stage: Stage::S1,
            hist: HistoryRing::new(4),
            rk: Vec::with_capacity(3),
            x_base: None,
            pending: None,
            warmup_steps: 3,
            combo: Tensor::zeros(rows, cols),
            // Only FON converts eps -> drift; PNDM never touches this.
            drift_scratch: match variant {
                Variant::Fon => Tensor::zeros(rows, cols),
                Variant::Pndm => Tensor::zeros(0, 0),
            },
            arena: ScratchArena::new(rows, cols),
        }
    }

    fn in_warmup(&self) -> bool {
        self.i < self.warmup_steps
    }

    /// The (x, t) to evaluate next given the current stage. Warmup
    /// stage points are built into arena buffers (`u = a·base + b·slope`
    /// through the fused kernels — elementwise identical to the old
    /// clone-then-update form).
    fn request(&mut self) -> (Arc<Tensor>, f64) {
        let t_cur = self.plan.t(self.i);
        let t_next = self.plan.t(self.i + 1);
        if !self.in_warmup() {
            return (Arc::clone(&self.x), t_cur);
        }
        if self.stage == Stage::S1 {
            return (Arc::clone(&self.x), t_cur);
        }
        let sched = self.plan.sched();
        let mut u = self.arena.take();
        let base = self.x_base.as_ref().unwrap_or(&self.x);
        match self.variant {
            Variant::Pndm => {
                let t_mid = 0.5 * (t_cur + t_next);
                // x_s = phi(base, e_s, t -> t_s) for the stage's slope.
                let (slope, t_to) = match self.stage {
                    Stage::S2 => (&self.rk[0], t_mid),
                    Stage::S3 => (&self.rk[1], t_mid),
                    Stage::S4 => (&self.rk[2], t_next),
                    _ => unreachable!(),
                };
                let (a, b) = sched.ddim_coeffs(t_cur, t_to);
                fused::affine_into(
                    u.as_mut_slice(),
                    a as f32,
                    base.as_slice(),
                    b as f32,
                    slope.as_slice(),
                );
                (Arc::new(u), t_to)
            }
            Variant::Fon => {
                let h = t_next - t_cur; // negative
                let (slope, step, t_to) = match self.stage {
                    Stage::S2 => (&self.rk[0], 0.5 * h, t_cur + 0.5 * h),
                    Stage::S3 => (&self.rk[1], 0.5 * h, t_cur + 0.5 * h),
                    Stage::S4 => (&self.rk[2], h, t_next),
                    _ => unreachable!(),
                };
                u.as_mut_slice().copy_from_slice(base.as_slice());
                fused::axpy(u.as_mut_slice(), step as f32, slope.as_slice());
                (Arc::new(u), t_to)
            }
        }
    }
}

impl Solver for ExplicitAdams {
    fn name(&self) -> String {
        match self.variant {
            Variant::Pndm => "pndm".into(),
            Variant::Fon => "fon".into(),
        }
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(self.pending.is_none(), "next_eval called with an eval outstanding");
        if self.in_warmup() && self.stage == Stage::S1 {
            self.x_base = Some(Arc::clone(&self.x));
        }
        let (x, t) = self.request();
        self.pending = Some((Arc::clone(&x), t));
        Some(EvalRequest { x, t, cond: None })
    }

    fn on_eval(&mut self, eps: Tensor) {
        let (x_req, t_req) = self.pending.take().expect("on_eval without a pending request");
        self.nfe += 1;
        let sched = self.plan.sched();
        let t_cur = self.plan.t(self.i);
        let t_next = self.plan.t(self.i + 1);

        if self.in_warmup() {
            // Convert the raw eps into this variant's working quantity
            // (warmup may allocate; the multistep phase below does not).
            let val = match self.variant {
                Variant::Pndm => eps,
                Variant::Fon => {
                    let mut f = Tensor::zeros(eps.rows(), eps.cols());
                    drift_into(
                        &sched,
                        f.as_mut_slice(),
                        x_req.as_slice(),
                        eps.as_slice(),
                        t_req,
                    );
                    f
                }
            };
            // Recycle the stage point: S2-S4 requests came from the
            // arena, and once the caller has dropped its view the Arc
            // unwinds to a single owner. S1 shares the iterate itself,
            // so try_unwrap fails there and the clone just drops.
            if let Ok(buf) = Arc::try_unwrap(x_req) {
                self.arena.give(buf);
            }
            match self.stage {
                Stage::S1 => {
                    // First slope of this step also feeds the multistep
                    // history (the PNDM convention).
                    self.hist.push(val.clone());
                    self.rk.push(val);
                    self.stage = Stage::S2;
                }
                Stage::S2 | Stage::S3 => {
                    self.rk.push(val);
                    self.stage = if self.stage == Stage::S2 { Stage::S3 } else { Stage::S4 };
                }
                Stage::S4 => {
                    // Combine: (v1 + 2 v2 + 2 v3 + v4) / 6.
                    let combo = Tensor::weighted_sum(
                        &[&self.rk[0], &self.rk[1], &self.rk[2], &val],
                        &[1.0 / 6.0, 2.0 / 6.0, 2.0 / 6.0, 1.0 / 6.0],
                    );
                    let mut base = self.x_base.take().expect("warmup base missing");
                    {
                        let b = Arc::make_mut(&mut base);
                        match self.variant {
                            Variant::Pndm => {
                                let (a, bb) = sched.ddim_coeffs(t_cur, t_next);
                                fused::affine_inplace(
                                    b.as_mut_slice(),
                                    a as f32,
                                    bb as f32,
                                    combo.as_slice(),
                                );
                            }
                            Variant::Fon => b.axpy((t_next - t_cur) as f32, &combo),
                        }
                    }
                    self.x = base;
                    self.rk.clear();
                    self.i += 1;
                    self.stage = if self.in_warmup() { Stage::S1 } else { Stage::Multi };
                }
                Stage::Multi => unreachable!(),
            }
            return;
        }

        // Multistep phase: push the new slope, AB4-combine, transfer —
        // all in place.
        let (rows, cols) = (self.x.rows(), self.x.cols());
        let val = match self.variant {
            Variant::Pndm => eps,
            Variant::Fon => {
                drift_into(
                    &sched,
                    self.drift_scratch.as_mut_slice(),
                    x_req.as_slice(),
                    eps.as_slice(),
                    t_req,
                );
                std::mem::replace(&mut self.drift_scratch, Tensor::zeros(0, 0))
            }
        };
        // x_req aliases self.x in the multistep phase; release it before
        // the in-place update below or Arc::make_mut would deep-copy the
        // iterate every step (the exact clone this layer removes).
        drop(x_req);
        let evicted = self.hist.push(val);
        if self.variant == Variant::Fon {
            // Adopt the evicted slot as the next drift scratch (steady
            // state: the ring is full, so this never allocates).
            self.drift_scratch = evicted.unwrap_or_else(|| Tensor::zeros(rows, cols));
        }
        assert!(self.hist.len() == 4, "multistep phase requires a full history");
        {
            let out = self.combo.as_mut_slice();
            fused::zero(out);
            for (h, &wm) in self.hist.iter().take(4).zip(AB4.iter()) {
                fused::axpy(out, wm as f32, h.as_slice());
            }
        }
        let x = Arc::make_mut(&mut self.x);
        match self.variant {
            Variant::Pndm => {
                let (a, b) = self.plan.ddim_coeffs(self.i);
                fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, self.combo.as_slice());
            }
            Variant::Fon => {
                fused::axpy(x.as_mut_slice(), (t_next - t_cur) as f32, self.combo.as_slice());
            }
        }
        self.i += 1;
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.plan.grid().len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, CountingEps};
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    fn run(variant: &str, steps: usize, batch: usize) -> (Tensor, usize) {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let x0 = rng.normal_tensor(batch, 2);
        let mut s: Box<dyn Solver> = match variant {
            "pndm" => Box::new(ExplicitAdams::new_pndm(sched, grid, x0)),
            _ => Box::new(ExplicitAdams::new_fon(sched, grid, x0)),
        };
        let m = CountingEps::new(AnalyticGmm::gmm8(sched));
        let out = sample_with(s.as_mut(), &m);
        (out, s.nfe())
    }

    #[test]
    fn pndm_nfe_accounting() {
        // 3 warmup steps x 4 evals + (steps-3) x 1 eval.
        let (_, nfe) = run("pndm", 10, 8);
        assert_eq!(nfe, 12 + 7);
    }

    #[test]
    fn fon_nfe_accounting() {
        let (_, nfe) = run("fon", 8, 8);
        assert_eq!(nfe, 12 + 5);
    }

    #[test]
    fn pndm_converges_exact_model() {
        let (out, _) = run("pndm", 25, 200);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 185, "{on_ring}/200 on ring");
    }

    #[test]
    fn fon_converges_exact_model() {
        let (out, _) = run("fon", 40, 200);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.6 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 170, "{on_ring}/200 on ring");
    }

    #[test]
    fn pndm_beats_ddim_at_equal_nfe() {
        // The headline property of multistep methods on smooth models.
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let reference =
            crate::metrics::Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225]);
        let nfe = 20;

        let mut rng = Rng::new(3);
        let x0 = rng.normal_tensor(2000, 2);
        let grid_p = make_grid(&sched, GridKind::Uniform, nfe - 9, 1.0, 1e-3);
        let mut pndm = ExplicitAdams::new_pndm(sched, grid_p, x0.clone());
        let out_p = sample_with(&mut pndm, &model);
        assert_eq!(pndm.nfe(), nfe);

        let grid_d = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let mut ddim = crate::solvers::ddim::Ddim::new(sched, grid_d, x0);
        let out_d = sample_with(&mut ddim, &model);

        let fid_p = crate::metrics::fid(&out_p, &reference);
        let fid_d = crate::metrics::fid(&out_d, &reference);
        assert!(fid_p < fid_d * 1.5, "pndm {fid_p} vs ddim {fid_d}");
    }

    #[test]
    #[should_panic(expected = ">= 4 transitions")]
    fn too_few_steps_panics() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 3, 1.0, 1e-3);
        let _ = ExplicitAdams::new_pndm(sched, grid, Tensor::zeros(1, 2));
    }
}
