//! Explicit linear-multistep baselines from Liu et al. 2021:
//!
//! * **PNDM** — pseudo linear multistep: the Adams–Bashforth-4 noise
//!   combination (paper Eq. 9) pushed through the DDIM transfer (Eq. 8),
//!   warmed up with 3 pseudo-Runge–Kutta steps (4 evals each — this is
//!   why the paper's PNDM rows start at NFE 13/15).
//! * **FON** — classic fourth-order explicit Adams applied directly to
//!   the probability-flow ODE
//!       dx/dt = -0.5 beta(t) x + 0.5 beta(t) eps_theta(x,t) / sigma(t),
//!   warmed up with plain RK4. Uses fixed AB4 coefficients, i.e. assumes
//!   a uniform grid (the configuration the paper runs it in).

use std::collections::VecDeque;

use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// AB4 weights (Eq. 9), newest history first.
pub const AB4: [f64; 4] = [55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0];

#[derive(Clone, Copy, PartialEq, Debug)]
enum Variant {
    Pndm,
    Fon,
}

/// Progress inside one pseudo-RK warmup step (4 evaluations).
#[derive(Clone, Copy, PartialEq, Debug)]
enum Stage {
    S1,
    S2,
    S3,
    S4,
    /// Past warmup: one eval per multistep transition.
    Multi,
}

pub struct ExplicitAdams {
    sched: VpSchedule,
    grid: Vec<f64>,
    variant: Variant,
    x: Tensor,
    i: usize,
    nfe: usize,
    stage: Stage,
    /// Newest-first history: eps values (PNDM) or f values (FON).
    hist: VecDeque<Tensor>,
    /// RK intermediates of the current warmup step.
    rk: Vec<Tensor>,
    /// x at the start of the current warmup step.
    x_base: Option<Tensor>,
    /// Outstanding request (x, t), kept to derive f from eps for FON.
    pending: Option<(Tensor, f64)>,
    warmup_steps: usize,
}

impl ExplicitAdams {
    pub fn new_pndm(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        Self::new(sched, grid, x0, Variant::Pndm)
    }

    pub fn new_fon(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        Self::new(sched, grid, x0, Variant::Fon)
    }

    fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, variant: Variant) -> Self {
        assert!(grid.len() >= 5, "PNDM/FON need >= 4 transitions (>= 13 NFE)");
        ExplicitAdams {
            sched,
            grid,
            variant,
            x: x0,
            i: 0,
            nfe: 0,
            stage: Stage::S1,
            hist: VecDeque::with_capacity(4),
            rk: Vec::with_capacity(3),
            x_base: None,
            pending: None,
            warmup_steps: 3,
        }
    }

    /// DDIM transfer phi(x, eps, t_from -> t_to).
    fn phi(&self, x: &Tensor, eps: &Tensor, t_from: f64, t_to: f64) -> Tensor {
        let (a, b) = self.sched.ddim_coeffs(t_from, t_to);
        x.affine(a as f32, b as f32, eps)
    }

    /// Probability-flow drift f(x, t) from an eps evaluation.
    fn drift(&self, x: &Tensor, eps: &Tensor, t: f64) -> Tensor {
        let beta = self.sched.beta_min + t * (self.sched.beta_max - self.sched.beta_min);
        let sigma = self.sched.sigma(t).max(1e-12);
        // f = -0.5 beta x + 0.5 beta eps / sigma
        let mut f = x.clone();
        f.scale((-0.5 * beta) as f32);
        f.axpy((0.5 * beta / sigma) as f32, eps);
        f
    }

    fn in_warmup(&self) -> bool {
        self.i < self.warmup_steps
    }

    /// The (x, t) to evaluate next given the current stage.
    fn request(&self) -> (Tensor, f64) {
        let t_cur = self.grid[self.i];
        let t_next = self.grid[self.i + 1];
        if !self.in_warmup() {
            return (self.x.clone(), t_cur);
        }
        match self.variant {
            Variant::Pndm => {
                let t_mid = 0.5 * (t_cur + t_next);
                let base = self.x_base.as_ref().unwrap_or(&self.x);
                match self.stage {
                    Stage::S1 => (self.x.clone(), t_cur),
                    // x1 = phi(x, e1, t, t_mid)
                    Stage::S2 => (self.phi(base, &self.rk[0], t_cur, t_mid), t_mid),
                    // x2 = phi(x, e2, t, t_mid)
                    Stage::S3 => (self.phi(base, &self.rk[1], t_cur, t_mid), t_mid),
                    // x3 = phi(x, e3, t, t_next)
                    Stage::S4 => (self.phi(base, &self.rk[2], t_cur, t_next), t_next),
                    Stage::Multi => unreachable!(),
                }
            }
            Variant::Fon => {
                let h = t_next - t_cur; // negative
                let base = self.x_base.as_ref().unwrap_or(&self.x);
                match self.stage {
                    Stage::S1 => (self.x.clone(), t_cur),
                    Stage::S2 => {
                        let mut u = base.clone();
                        u.axpy((0.5 * h) as f32, &self.rk[0]);
                        (u, t_cur + 0.5 * h)
                    }
                    Stage::S3 => {
                        let mut u = base.clone();
                        u.axpy((0.5 * h) as f32, &self.rk[1]);
                        (u, t_cur + 0.5 * h)
                    }
                    Stage::S4 => {
                        let mut u = base.clone();
                        u.axpy(h as f32, &self.rk[2]);
                        (u, t_next)
                    }
                    Stage::Multi => unreachable!(),
                }
            }
        }
    }

    fn push_hist(&mut self, v: Tensor) {
        self.hist.push_front(v);
        if self.hist.len() > 4 {
            self.hist.pop_back();
        }
    }
}

impl Solver for ExplicitAdams {
    fn name(&self) -> String {
        match self.variant {
            Variant::Pndm => "pndm".into(),
            Variant::Fon => "fon".into(),
        }
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(self.pending.is_none(), "next_eval called with an eval outstanding");
        if self.in_warmup() && self.stage == Stage::S1 {
            self.x_base = Some(self.x.clone());
        }
        let (x, t) = self.request();
        self.pending = Some((x.clone(), t));
        Some(EvalRequest { x, t })
    }

    fn on_eval(&mut self, eps: Tensor) {
        let (x_req, t_req) = self.pending.take().expect("on_eval without a pending request");
        self.nfe += 1;
        let t_cur = self.grid[self.i];
        let t_next = self.grid[self.i + 1];

        // Convert the raw eps into this variant's working quantity.
        let val = match self.variant {
            Variant::Pndm => eps,
            Variant::Fon => self.drift(&x_req, &eps, t_req),
        };

        if self.in_warmup() {
            match self.stage {
                Stage::S1 => {
                    // First slope of this step also feeds the multistep
                    // history (the PNDM convention).
                    self.push_hist(val.clone());
                    self.rk.push(val);
                    self.stage = Stage::S2;
                }
                Stage::S2 | Stage::S3 => {
                    self.rk.push(val);
                    self.stage = if self.stage == Stage::S2 { Stage::S3 } else { Stage::S4 };
                }
                Stage::S4 => {
                    // Combine: (v1 + 2 v2 + 2 v3 + v4) / 6.
                    let combo = Tensor::weighted_sum(
                        &[&self.rk[0], &self.rk[1], &self.rk[2], &val],
                        &[1.0 / 6.0, 2.0 / 6.0, 2.0 / 6.0, 1.0 / 6.0],
                    );
                    let base = self.x_base.take().expect("warmup base missing");
                    self.x = match self.variant {
                        Variant::Pndm => self.phi(&base, &combo, t_cur, t_next),
                        Variant::Fon => {
                            let mut x = base;
                            x.axpy((t_next - t_cur) as f32, &combo);
                            x
                        }
                    };
                    self.rk.clear();
                    self.i += 1;
                    self.stage = if self.in_warmup() { Stage::S1 } else { Stage::Multi };
                }
                Stage::Multi => unreachable!(),
            }
            return;
        }

        // Multistep phase: push the new slope, AB4-combine, transfer.
        self.push_hist(val);
        let n = self.hist.len().min(4);
        assert!(n == 4, "multistep phase requires a full history");
        let refs: Vec<&Tensor> = self.hist.iter().take(4).collect();
        let combo = Tensor::weighted_sum(&refs, &AB4);
        self.x = match self.variant {
            Variant::Pndm => self.phi(&self.x, &combo, t_cur, t_next),
            Variant::Fon => {
                let mut x = self.x.clone();
                x.axpy((t_next - t_cur) as f32, &combo);
                x
            }
        };
        self.i += 1;
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.grid.len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, CountingEps};
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    fn run(variant: &str, steps: usize, batch: usize) -> (Tensor, usize) {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let x0 = rng.normal_tensor(batch, 2);
        let mut s: Box<dyn Solver> = match variant {
            "pndm" => Box::new(ExplicitAdams::new_pndm(sched, grid, x0)),
            _ => Box::new(ExplicitAdams::new_fon(sched, grid, x0)),
        };
        let m = CountingEps::new(AnalyticGmm::gmm8(sched));
        let out = sample_with(s.as_mut(), &m);
        (out, s.nfe())
    }

    #[test]
    fn pndm_nfe_accounting() {
        // 3 warmup steps x 4 evals + (steps-3) x 1 eval.
        let (_, nfe) = run("pndm", 10, 8);
        assert_eq!(nfe, 12 + 7);
    }

    #[test]
    fn fon_nfe_accounting() {
        let (_, nfe) = run("fon", 8, 8);
        assert_eq!(nfe, 12 + 5);
    }

    #[test]
    fn pndm_converges_exact_model() {
        let (out, _) = run("pndm", 25, 200);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 185, "{on_ring}/200 on ring");
    }

    #[test]
    fn fon_converges_exact_model() {
        let (out, _) = run("fon", 40, 200);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.6 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 170, "{on_ring}/200 on ring");
    }

    #[test]
    fn pndm_beats_ddim_at_equal_nfe() {
        // The headline property of multistep methods on smooth models.
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let reference =
            crate::metrics::Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225]);
        let nfe = 20;

        let mut rng = Rng::new(3);
        let x0 = rng.normal_tensor(2000, 2);
        let grid_p = make_grid(&sched, GridKind::Uniform, nfe - 9, 1.0, 1e-3);
        let mut pndm = ExplicitAdams::new_pndm(sched, grid_p, x0.clone());
        let out_p = sample_with(&mut pndm, &model);
        assert_eq!(pndm.nfe(), nfe);

        let grid_d = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let mut ddim = crate::solvers::ddim::Ddim::new(sched, grid_d, x0);
        let out_d = sample_with(&mut ddim, &model);

        let fid_p = crate::metrics::fid(&out_p, &reference);
        let fid_d = crate::metrics::fid(&out_d, &reference);
        assert!(fid_p < fid_d * 1.5, "pndm {fid_p} vs ddim {fid_d}");
    }

    #[test]
    #[should_panic(expected = ">= 4 transitions")]
    fn too_few_steps_panics() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 3, 1.0, 1e-3);
        let _ = ExplicitAdams::new_pndm(sched, grid, Tensor::zeros(1, 2));
    }
}
