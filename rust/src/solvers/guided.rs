//! Classifier-free guidance as a solver adapter.
//!
//! Wraps any inner [`Solver`] and turns each of its N-row evaluations
//! into one 2N-row *paired* evaluation: rows `0..N` are the cond rows
//! (carrying `guide_class` in the per-row conditioning channel), rows
//! `N..2N` the uncond rows ([`UNCOND`]). The pairs ride the ordinary
//! batcher slabs — a slab may split them across engine calls freely,
//! because the combination happens only after the full 2N-row output is
//! reassembled: [`fused::guided_combine`] collapses the halves in place
//! (`eps = uncond + s * (cond - uncond)`, Ho & Salimans 2022; the
//! guidance-aware fast-sampler pattern of DPM-Solver) and the tensor is
//! truncated to its guided N rows before the inner solver adopts it.
//!
//! Zero-alloc steady state: the doubled eval buffer and the cond channel
//! are built once at construction; a step costs two row-block memcpys,
//! one fused combine pass, and an allocation-free `Vec::truncate` —
//! pinned by the guided case of `benches/bench_step_overhead.rs`.
//!
//! NFE accounting: each paired evaluation counts as 2 (the model does
//! twice the row work), so a guided request reports twice the inner
//! trajectory's evaluations.

use std::sync::Arc;

use crate::kernels::fused;
use crate::solvers::{EvalRequest, Solver, UNCOND};
use crate::tensor::Tensor;

/// Classifier-free-guidance wrapper around any solver.
pub struct Guided {
    inner: Box<dyn Solver>,
    scale: f32,
    rows: usize,
    cols: usize,
    /// Paired 2N-row eval buffer: `[cond rows; uncond rows]`, refreshed
    /// from the inner iterate each step (copy-on-write safe).
    x2: Arc<Tensor>,
    /// Per-row conditioning channel, fixed for the whole trajectory.
    cond: Arc<Vec<f32>>,
    pending: bool,
    nfe: usize,
}

impl Guided {
    pub fn new(inner: Box<dyn Solver>, scale: f32, class: usize) -> Guided {
        assert!(scale != 0.0, "guidance scale 0 is the unconditional path; don't wrap");
        let (rows, cols) = (inner.current().rows(), inner.current().cols());
        let mut cond = vec![class as f32; rows];
        cond.resize(2 * rows, UNCOND);
        Guided {
            inner,
            scale,
            rows,
            cols,
            x2: Arc::new(Tensor::zeros(2 * rows, cols)),
            cond: Arc::new(cond),
            pending: false,
            nfe: 0,
        }
    }

    /// The wrapped solver (tests / diagnostics).
    pub fn inner(&self) -> &dyn Solver {
        self.inner.as_ref()
    }
}

impl Solver for Guided {
    fn name(&self) -> String {
        format!("guided-{}", self.inner.name())
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        assert!(!self.pending, "next_eval called with an eval outstanding");
        let req = self.inner.next_eval()?;
        debug_assert_eq!(req.x.rows(), self.rows, "inner eval rows drifted");
        debug_assert!(req.cond.is_none(), "inner solver must not set cond");
        let t = req.t;
        {
            // Previous round's view has been dropped by now, so this is
            // a plain in-place refresh (copy-on-write if a caller still
            // holds one — correct either way).
            let x2 = Arc::make_mut(&mut self.x2);
            let (cond_half, uncond_half) = x2.as_mut_slice().split_at_mut(self.rows * self.cols);
            cond_half.copy_from_slice(req.x.as_slice());
            uncond_half.copy_from_slice(req.x.as_slice());
        }
        // Release the inner iterate view before its in-place update.
        drop(req);
        self.pending = true;
        Some(EvalRequest { x: Arc::clone(&self.x2), t, cond: Some(Arc::clone(&self.cond)) })
    }

    fn on_eval(&mut self, mut eps2: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        assert_eq!(eps2.rows(), 2 * self.rows, "paired evaluation rows mismatch");
        {
            let (cond_half, uncond_half) = eps2.as_mut_slice().split_at_mut(self.rows * self.cols);
            fused::guided_combine(cond_half, uncond_half, self.scale);
        }
        // Keep only the guided rows; Vec::truncate keeps the allocation,
        // so the inner solver adopts the combined eps by move with zero
        // heap traffic.
        eps2.truncate_rows(self.rows);
        self.nfe += 2;
        self.inner.on_eval(eps2);
    }

    fn current(&self) -> &Tensor {
        self.inner.current()
    }

    fn is_done(&self) -> bool {
        self.inner.is_done()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }

    fn delta_eps(&self) -> Option<f64> {
        self.inner.delta_eps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, EpsModel};
    use crate::solvers::schedule::{make_grid, GridKind, VpSchedule};
    use crate::solvers::{sample_with, SolverKind};

    fn build_guided(scale: f32, class: usize, rows: usize, nfe: usize) -> Guided {
        let sched = VpSchedule::default();
        let kind = SolverKind::Ddim;
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let plan = std::sync::Arc::new(kind.make_plan(sched, grid, nfe));
        let mut rng = Rng::new(11);
        Guided::new(kind.build_with_plan(plan, rng.normal_tensor(rows, 2), 0), scale, class)
    }

    #[test]
    fn pairs_rows_and_counts_double_nfe() {
        let mut g = build_guided(2.0, 3, 4, 6);
        let model = AnalyticGmm::gmm8(VpSchedule::default());
        // Drive the first paired evaluation by hand to inspect it.
        let req = g.next_eval().unwrap();
        assert_eq!(req.x.rows(), 8, "paired request doubles rows");
        let cond = req.cond.as_ref().unwrap();
        assert_eq!(&cond[..4], &[3.0; 4]);
        assert_eq!(&cond[4..], &[UNCOND; 4]);
        // Both halves start as copies of the inner iterate.
        assert_eq!(req.x.row_span(0, 4), req.x.row_span(4, 4));
        let t = vec![req.t as f32; 8];
        let c = cond.as_ref().clone();
        let eps = model.eval_cond(&req.x, &t, &c);
        drop(req);
        g.on_eval(eps);
        // Finish the trajectory through the generic driver.
        let out = sample_with(&mut g, &model);
        assert_eq!(out.rows(), 4, "result keeps the requested rows");
        assert_eq!(g.nfe(), 12, "6 paired steps = 12 evaluations");
        assert!(out.all_finite());
    }

    #[test]
    fn guided_samples_concentrate_on_the_target_mode() {
        // Strong guidance toward one gmm8 mode pulls essentially every
        // sample onto it, while the unconditional run spreads over the
        // ring — the qualitative CFG effect.
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let class = 0usize;
        let target = model.centers[class].clone();
        let kind = SolverKind::parse("era").unwrap();
        let nfe = 20;
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let plan = std::sync::Arc::new(kind.make_plan(sched, grid, nfe));
        let mut rng = Rng::new(5);
        let x0 = rng.normal_tensor(128, 2);

        // Scale 1.0: the combination recovers the conditional score, so
        // the trajectory samples the single-mode conditional directly —
        // the most predictable end-to-end check of the pairing plumbing.
        let mut guided =
            Guided::new(kind.build_with_plan(plan.clone(), x0.clone(), 5), 1.0, class);
        let out = sample_with(&mut guided, &model);
        let mut near = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let d2 = (row[0] as f64 - target[0]).powi(2) + (row[1] as f64 - target[1]).powi(2);
            if d2.sqrt() < 0.7 {
                near += 1;
            }
        }
        assert!(near > 115, "{near}/128 near the guided mode");

        let mut uncond = kind.build_with_plan(plan, x0, 5);
        let base = sample_with(&mut *uncond, &model);
        let mut base_near = 0;
        for r in 0..base.rows() {
            let row = base.row(r);
            let d2 = (row[0] as f64 - target[0]).powi(2) + (row[1] as f64 - target[1]).powi(2);
            if d2.sqrt() < 0.7 {
                base_near += 1;
            }
        }
        assert!(base_near < near / 2, "uncond {base_near} vs guided {near}");
    }

    #[test]
    #[should_panic(expected = "don't wrap")]
    fn scale_zero_rejected() {
        let _ = build_guided(0.0, 0, 2, 5);
    }
}
