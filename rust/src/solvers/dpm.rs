//! DPM-Solver (Lu et al. 2022a): exponential-integrator solvers of order
//! 1/2/3 in the half-logSNR variable lambda = log(alpha/sigma), plus the
//! paper's "fast" order schedule that spends an NFE budget as mostly
//! third-order steps.
//!
//! Order 1 is algebraically identical to DDIM (a unit test pins this).
//! The singlestep formulas follow Lu et al. Algorithms 1 and 2 with
//! r1 = 1/3, r2 = 2/3.
//!
//! All exponential-integrator coefficients (and the logSNR midpoint
//! inversions they require) are precomputed per step in the shared
//! [`TrajectoryPlan`] — they depend only on `(order schedule, grid,
//! schedule)`, exactly the DPM-Solver observation that its coefficient
//! schedule is computable once per trajectory. Steps run in place; the
//! intermediate-stage evaluation point reuses one scratch tensor.

use std::sync::Arc;

use crate::kernels::{fused, PlanView, TrajectoryPlan};
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// The DPM-Solver-fast order schedule for an NFE budget (Lu et al. §3.4):
/// as many order-3 steps as fit, with the remainder as one order-2 and/or
/// order-1 step.
pub fn fast_order_schedule(nfe: usize) -> Vec<usize> {
    assert!(nfe >= 1);
    match nfe {
        1 => vec![1],
        2 => vec![2],
        3 => vec![2, 1],
        _ => match nfe % 3 {
            0 => {
                let mut v = vec![3; nfe / 3 - 1];
                v.extend([2, 1]);
                v
            }
            1 => {
                let mut v = vec![3; nfe / 3];
                v.push(1);
                v
            }
            _ => {
                let mut v = vec![3; nfe / 3];
                v.push(2);
                v
            }
        },
    }
}

/// Fixed-order schedule that exactly spends `nfe` evaluations.
pub fn fixed_order_schedule(order: usize, nfe: usize) -> Vec<usize> {
    assert!((1..=3).contains(&order));
    assert!(nfe >= 1);
    let full = nfe / order;
    let rem = nfe % order;
    let mut v = vec![order; full];
    if rem > 0 {
        v.push(rem);
    }
    if v.is_empty() {
        v.push(nfe.min(order));
    }
    v
}

/// Progress inside one (possibly multi-eval) step.
struct StepState {
    /// eps(x, t_cur).
    e0: Option<Tensor>,
    /// eps at the first intermediate point (order 3).
    e1: Option<Tensor>,
    /// Evaluations consumed inside this step so far.
    stage: usize,
}

pub struct DpmSolver {
    plan: PlanView,
    x: Arc<Tensor>,
    i: usize,
    nfe: usize,
    st: StepState,
    pending: bool,
    label: String,
    /// Intermediate-stage evaluation point (reused each step).
    u: Arc<Tensor>,
}

impl DpmSolver {
    /// Fixed-order solver over every transition of the grid.
    pub fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, order: usize) -> Self {
        let orders = vec![order; grid.len() - 1];
        Self::with_orders(sched, grid, x0, orders, format!("dpm-{order}"))
    }

    /// DPM-Solver-fast for an explicit NFE budget. The grid must have
    /// `fast_order_schedule(nfe).len()` transitions (the budget cannot be
    /// recovered from the grid alone: budgets 9/10/11 all take 4 steps).
    pub fn new_fast(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, nfe: usize) -> Self {
        let orders = fast_order_schedule(nfe);
        Self::with_orders(sched, grid, x0, orders, "dpm-fast".into())
    }

    pub fn with_orders(
        sched: VpSchedule,
        grid: Vec<f64>,
        x0: Tensor,
        orders: Vec<usize>,
        label: String,
    ) -> Self {
        let plan = TrajectoryPlan::new(sched, grid).with_dpm_orders(&orders);
        Self::with_plan(Arc::new(plan), x0, label)
    }

    /// Build over a shared precomputed plan (must carry DPM step
    /// coefficients — i.e. come from a DPM [`crate::solvers::SolverKind`]).
    pub fn with_plan(plan: Arc<TrajectoryPlan>, x0: Tensor, label: String) -> Self {
        DpmSolver::with_view(PlanView::full(plan), x0, label)
    }

    /// Build over a (possibly suffix) window of a shared plan; the view's
    /// transitions use their own precomputed per-step coefficients.
    pub fn with_view(plan: PlanView, x0: Tensor, label: String) -> Self {
        assert!(plan.has_dpm(), "DpmSolver needs a plan with DPM coefficients");
        let u = Arc::new(Tensor::zeros(x0.rows(), x0.cols()));
        DpmSolver {
            plan,
            x: Arc::new(x0),
            i: 0,
            nfe: 0,
            st: StepState { e0: None, e1: None, stage: 0 },
            pending: false,
            label,
            u,
        }
    }

    /// The (x, t) this step needs at its current stage. Intermediate
    /// points are built in place into the `u` scratch.
    fn request(&mut self) -> (Arc<Tensor>, f64) {
        let sp = self.plan.dpm_step(self.i);
        match (sp.order, self.st.stage) {
            (_, 0) => (Arc::clone(&self.x), self.plan.t(self.i)),
            (2, 1) | (3, 1) => {
                // u = a_s1 x + b_s1 e0 (order-1 transfer to the midpoint).
                let e0 = self.st.e0.as_ref().unwrap();
                let u = Arc::make_mut(&mut self.u);
                fused::affine_into(
                    u.as_mut_slice(),
                    sp.a_s1 as f32,
                    self.x.as_slice(),
                    sp.b_s1 as f32,
                    e0.as_slice(),
                );
                (Arc::clone(&self.u), sp.t_s1)
            }
            (3, 2) => {
                // u2 = a_s2 x + b_s2 e0 + c_s2 (e1 - e0).
                let e0 = self.st.e0.as_ref().unwrap();
                let e1 = self.st.e1.as_ref().unwrap();
                let u = Arc::make_mut(&mut self.u);
                fused::affine_into(
                    u.as_mut_slice(),
                    sp.a_s2 as f32,
                    self.x.as_slice(),
                    sp.b_s2 as f32,
                    e0.as_slice(),
                );
                let c = sp.c_s2 as f32;
                fused::axpy(u.as_mut_slice(), c, e1.as_slice());
                fused::axpy(u.as_mut_slice(), -c, e0.as_slice());
                (Arc::clone(&self.u), sp.t_s2)
            }
            _ => unreachable!("invalid dpm stage"),
        }
    }

    /// Complete the current step with its final evaluation `e_last`.
    fn finish_step(&mut self, e_last: Tensor) {
        let sp = self.plan.dpm_step(self.i);
        let x = Arc::make_mut(&mut self.x);
        match sp.order {
            1 | 2 => {
                // x_next = a x + b e_last (order 2's e_last sits at the
                // midpoint; same transfer shape).
                fused::affine_inplace(
                    x.as_mut_slice(),
                    sp.a_f as f32,
                    sp.b_f as f32,
                    e_last.as_slice(),
                );
            }
            3 => {
                // x_next = a x + b e0 + c (e_last - e0).
                let e0 = self.st.e0.as_ref().unwrap();
                fused::affine_inplace(
                    x.as_mut_slice(),
                    sp.a_f as f32,
                    sp.b_f as f32,
                    e0.as_slice(),
                );
                let c = sp.c_f as f32;
                fused::axpy(x.as_mut_slice(), c, e_last.as_slice());
                fused::axpy(x.as_mut_slice(), -c, e0.as_slice());
            }
            _ => unreachable!(),
        }
        self.st = StepState { e0: None, e1: None, stage: 0 };
        self.i += 1;
    }
}

impl Solver for DpmSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        self.pending = true;
        let (x, t) = self.request();
        Some(EvalRequest { x, t, cond: None })
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;
        let order = self.plan.dpm_step(self.i).order;
        match (order, self.st.stage) {
            (1, 0) => self.finish_step(eps),
            (2, 0) | (3, 0) => {
                self.st.e0 = Some(eps);
                self.st.stage = 1;
            }
            (2, 1) | (3, 2) => self.finish_step(eps),
            (3, 1) => {
                self.st.e1 = Some(eps);
                self.st.stage = 2;
            }
            _ => unreachable!(),
        }
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i >= self.plan.steps()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    #[test]
    fn fast_schedule_spends_budget_exactly() {
        for nfe in 1..60 {
            let sch = fast_order_schedule(nfe);
            assert_eq!(sch.iter().sum::<usize>(), nfe, "nfe {nfe}");
            assert!(sch.iter().all(|&o| (1..=3).contains(&o)));
        }
    }

    #[test]
    fn fixed_schedule_spends_budget_exactly() {
        for order in 1..=3 {
            for nfe in 1..40 {
                let sch = fixed_order_schedule(order, nfe);
                assert_eq!(sch.iter().sum::<usize>(), nfe, "order {order} nfe {nfe}");
            }
        }
    }

    #[test]
    fn dpm1_equals_ddim() {
        // DPM-Solver-1 is algebraically DDIM; verify numerically.
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::LogSnr, 12, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let x0 = rng.normal_tensor(64, 2);
        let m = AnalyticGmm::gmm8(sched);

        let mut dpm = DpmSolver::new(sched, grid.clone(), x0.clone(), 1);
        let out_dpm = sample_with(&mut dpm, &m);
        let mut ddim = crate::solvers::ddim::Ddim::new(sched, grid, x0);
        let out_ddim = sample_with(&mut ddim, &m);

        let d = out_dpm.mean_row_dist(&out_ddim);
        assert!(d < 1e-4, "dpm-1 vs ddim dist {d}");
    }

    #[test]
    fn nfe_accounting_order2_and_3() {
        let sched = VpSchedule::default();
        let m = AnalyticGmm::gmm8(sched);
        for (order, steps, want_nfe) in [(2usize, 5usize, 10usize), (3, 4, 12)] {
            let grid = make_grid(&sched, GridKind::LogSnr, steps, 1.0, 1e-3);
            let mut rng = Rng::new(1);
            let mut s = DpmSolver::new(sched, grid, rng.normal_tensor(8, 2), order);
            let _ = sample_with(&mut s, &m);
            assert_eq!(s.nfe(), want_nfe);
        }
    }

    #[test]
    fn converges_exact_model_order2() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::LogSnr, 10, 1.0, 1e-3);
        let mut rng = Rng::new(2);
        let mut s = DpmSolver::new(sched, grid, rng.normal_tensor(300, 2), 2);
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 280, "{on_ring}/300");
    }

    #[test]
    fn order3_at_least_as_good_as_order1_low_nfe() {
        // Equal NFE = 24: order 3 with 8 steps vs order 1 with 24 steps,
        // measured as endpoint distance to a fine-grid DDIM reference
        // (deterministic, unlike finite-sample FID with an exact model).
        // NFE must be high enough to reach the asymptotic regime: at
        // NFE 12 the logSNR step h ~ 3.4 and order 3 *loses* (mirroring
        // the paper's DPM-2 blowup at NFE 5).
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let mut rng = Rng::new(3);
        let x0 = rng.normal_tensor(256, 2);

        let fine = make_grid(&sched, GridKind::LogSnr, 400, 1.0, 1e-3);
        let mut reference = crate::solvers::ddim::Ddim::new(sched, fine, x0.clone());
        let truth = sample_with(&mut reference, &model);

        let err_for = |order: usize, steps: usize| {
            let grid = make_grid(&sched, GridKind::LogSnr, steps, 1.0, 1e-3);
            let mut s = DpmSolver::new(sched, grid, x0.clone(), order);
            sample_with(&mut s, &model).mean_row_dist(&truth)
        };
        let f3 = err_for(3, 8);
        let f1 = err_for(1, 24);
        assert!(f3 < f1, "dpm-3 {f3} vs dpm-1 {f1}");
    }

    #[test]
    fn fast_solver_runs() {
        let sched = VpSchedule::default();
        let nfe = 10;
        let orders = fast_order_schedule(nfe);
        let grid = make_grid(&sched, GridKind::LogSnr, orders.len(), 1.0, 1e-3);
        let mut rng = Rng::new(4);
        let mut s = DpmSolver::new_fast(sched, grid, rng.normal_tensor(32, 2), nfe);
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        assert!(out.all_finite());
        assert_eq!(s.nfe(), nfe);
    }
}
