//! DPM-Solver (Lu et al. 2022a): exponential-integrator solvers of order
//! 1/2/3 in the half-logSNR variable lambda = log(alpha/sigma), plus the
//! paper's "fast" order schedule that spends an NFE budget as mostly
//! third-order steps.
//!
//! Order 1 is algebraically identical to DDIM (a unit test pins this).
//! The singlestep formulas follow Lu et al. Algorithms 1 and 2 with
//! r1 = 1/3, r2 = 2/3.

use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// The DPM-Solver-fast order schedule for an NFE budget (Lu et al. §3.4):
/// as many order-3 steps as fit, with the remainder as one order-2 and/or
/// order-1 step.
pub fn fast_order_schedule(nfe: usize) -> Vec<usize> {
    assert!(nfe >= 1);
    match nfe {
        1 => vec![1],
        2 => vec![2],
        3 => vec![2, 1],
        _ => match nfe % 3 {
            0 => {
                let mut v = vec![3; nfe / 3 - 1];
                v.extend([2, 1]);
                v
            }
            1 => {
                let mut v = vec![3; nfe / 3];
                v.push(1);
                v
            }
            _ => {
                let mut v = vec![3; nfe / 3];
                v.push(2);
                v
            }
        },
    }
}

/// Fixed-order schedule that exactly spends `nfe` evaluations.
pub fn fixed_order_schedule(order: usize, nfe: usize) -> Vec<usize> {
    assert!((1..=3).contains(&order));
    assert!(nfe >= 1);
    let full = nfe / order;
    let rem = nfe % order;
    let mut v = vec![order; full];
    if rem > 0 {
        v.push(rem);
    }
    if v.is_empty() {
        v.push(nfe.min(order));
    }
    v
}

/// Progress inside one (possibly multi-eval) step.
struct StepState {
    /// eps(x, t_cur).
    e0: Option<Tensor>,
    /// eps at the first intermediate point (order 3).
    e1: Option<Tensor>,
    /// Evaluations consumed inside this step so far.
    stage: usize,
}

pub struct DpmSolver {
    sched: VpSchedule,
    grid: Vec<f64>,
    /// Per-step solver order; len == grid.len() - 1.
    orders: Vec<usize>,
    x: Tensor,
    i: usize,
    nfe: usize,
    st: StepState,
    pending: bool,
    label: String,
}

impl DpmSolver {
    /// Fixed-order solver spending exactly `nfe` evaluations across the
    /// grid (the grid must have `fixed_order_schedule(order, nfe).len()`
    /// transitions).
    pub fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, order: usize) -> Self {
        let orders = {
            // grid has K+1 points; distribute the order over K steps with
            // the final step possibly truncated by the caller's budget.
            let k = grid.len() - 1;
            vec![order; k]
        };
        Self::with_orders(sched, grid, x0, orders, format!("dpm-{order}"))
    }

    /// DPM-Solver-fast for an explicit NFE budget. The grid must have
    /// `fast_order_schedule(nfe).len()` transitions (the budget cannot be
    /// recovered from the grid alone: budgets 9/10/11 all take 4 steps).
    pub fn new_fast(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, nfe: usize) -> Self {
        let orders = fast_order_schedule(nfe);
        Self::with_orders(sched, grid, x0, orders, "dpm-fast".into())
    }

    pub fn with_orders(
        sched: VpSchedule,
        grid: Vec<f64>,
        x0: Tensor,
        orders: Vec<usize>,
        label: String,
    ) -> Self {
        assert_eq!(orders.len() + 1, grid.len(), "orders must match grid transitions");
        assert!(orders.iter().all(|&o| (1..=3).contains(&o)));
        DpmSolver {
            sched,
            grid,
            orders,
            x: x0,
            i: 0,
            nfe: 0,
            st: StepState { e0: None, e1: None, stage: 0 },
            pending: false,
            label,
        }
    }

    fn lam(&self, t: f64) -> f64 {
        self.sched.lambda(t)
    }

    fn alpha(&self, t: f64) -> f64 {
        self.sched.sqrt_alpha_bar(t)
    }

    /// Intermediate time at lambda(t_cur) + r*h.
    fn t_mid(&self, r: f64) -> f64 {
        let (tc, tn) = (self.grid[self.i], self.grid[self.i + 1]);
        let h = self.lam(tn) - self.lam(tc);
        self.sched.t_of_lambda(self.lam(tc) + r * h)
    }

    /// First-order transition from (x, t_from) to t_to with a given eps.
    fn order1(&self, x: &Tensor, eps: &Tensor, t_from: f64, t_to: f64) -> Tensor {
        let h = self.lam(t_to) - self.lam(t_from);
        let a = (self.alpha(t_to) / self.alpha(t_from)) as f32;
        let b = (-self.sched.sigma(t_to) * h.exp_m1()) as f32;
        x.affine(a as f32, b, eps)
    }

    /// The (x, t) this step needs at its current stage.
    fn request(&self) -> (Tensor, f64) {
        let order = self.orders[self.i];
        let (tc, tn) = (self.grid[self.i], self.grid[self.i + 1]);
        match (order, self.st.stage) {
            (_, 0) => (self.x.clone(), tc),
            (2, 1) => {
                let s = self.t_mid(0.5);
                (self.order1(&self.x, self.st.e0.as_ref().unwrap(), tc, s), s)
            }
            (3, 1) => {
                let s1 = self.t_mid(1.0 / 3.0);
                (self.order1(&self.x, self.st.e0.as_ref().unwrap(), tc, s1), s1)
            }
            (3, 2) => {
                // u2 = a x - sigma_s2 (e^{r2 h} - 1) e0
                //      - (sigma_s2 r2/r1)((e^{r2 h}-1)/(r2 h) - 1) D1
                let (r1, r2) = (1.0 / 3.0, 2.0 / 3.0);
                let h = self.lam(tn) - self.lam(tc);
                let s2 = self.t_mid(r2);
                let a = self.alpha(s2) / self.alpha(tc);
                let sig = self.sched.sigma(s2);
                let em = (r2 * h).exp_m1();
                let e0 = self.st.e0.as_ref().unwrap();
                let e1 = self.st.e1.as_ref().unwrap();
                let mut u2 = self.x.affine(a as f32, (-sig * em) as f32, e0);
                let c = -(sig * r2 / r1) * (em / (r2 * h) - 1.0);
                // D1 = e1 - e0.
                u2.axpy(c as f32, e1);
                u2.axpy(-c as f32, e0);
                (u2, s2)
            }
            _ => unreachable!("invalid dpm stage"),
        }
    }

    /// Complete the current step with its final evaluation `e_last`.
    fn finish_step(&mut self, e_last: Tensor) {
        let order = self.orders[self.i];
        let (tc, tn) = (self.grid[self.i], self.grid[self.i + 1]);
        match order {
            1 => {
                self.x = self.order1(&self.x, &e_last, tc, tn);
            }
            2 => {
                // x_next = a x - sigma_n (e^h - 1) eps(u, s).
                self.x = self.order1(&self.x, &e_last, tc, tn);
            }
            3 => {
                let r2 = 2.0 / 3.0;
                let h = self.lam(tn) - self.lam(tc);
                let a = self.alpha(tn) / self.alpha(tc);
                let sig = self.sched.sigma(tn);
                let em = h.exp_m1();
                let e0 = self.st.e0.as_ref().unwrap();
                let mut x = self.x.affine(a as f32, (-sig * em) as f32, e0);
                let c = -(sig / r2) * (em / h - 1.0);
                // D2 = e_last - e0.
                x.axpy(c as f32, &e_last);
                x.axpy(-c as f32, e0);
                self.x = x;
            }
            _ => unreachable!(),
        }
        self.st = StepState { e0: None, e1: None, stage: 0 };
        self.i += 1;
    }
}

impl Solver for DpmSolver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        self.pending = true;
        let (x, t) = self.request();
        Some(EvalRequest { x, t })
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;
        let order = self.orders[self.i];
        match (order, self.st.stage) {
            (1, 0) => self.finish_step(eps),
            (2, 0) | (3, 0) => {
                self.st.e0 = Some(eps);
                self.st.stage = 1;
            }
            (2, 1) | (3, 2) => self.finish_step(eps),
            (3, 1) => {
                self.st.e1 = Some(eps);
                self.st.stage = 2;
            }
            _ => unreachable!(),
        }
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i >= self.orders.len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    #[test]
    fn fast_schedule_spends_budget_exactly() {
        for nfe in 1..60 {
            let sch = fast_order_schedule(nfe);
            assert_eq!(sch.iter().sum::<usize>(), nfe, "nfe {nfe}");
            assert!(sch.iter().all(|&o| (1..=3).contains(&o)));
        }
    }

    #[test]
    fn fixed_schedule_spends_budget_exactly() {
        for order in 1..=3 {
            for nfe in 1..40 {
                let sch = fixed_order_schedule(order, nfe);
                assert_eq!(sch.iter().sum::<usize>(), nfe, "order {order} nfe {nfe}");
            }
        }
    }

    #[test]
    fn dpm1_equals_ddim() {
        // DPM-Solver-1 is algebraically DDIM; verify numerically.
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::LogSnr, 12, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let x0 = rng.normal_tensor(64, 2);
        let m = AnalyticGmm::gmm8(sched);

        let mut dpm = DpmSolver::new(sched, grid.clone(), x0.clone(), 1);
        let out_dpm = sample_with(&mut dpm, &m);
        let mut ddim = crate::solvers::ddim::Ddim::new(sched, grid, x0);
        let out_ddim = sample_with(&mut ddim, &m);

        let d = out_dpm.mean_row_dist(&out_ddim);
        assert!(d < 1e-4, "dpm-1 vs ddim dist {d}");
    }

    #[test]
    fn nfe_accounting_order2_and_3() {
        let sched = VpSchedule::default();
        let m = AnalyticGmm::gmm8(sched);
        for (order, steps, want_nfe) in [(2usize, 5usize, 10usize), (3, 4, 12)] {
            let grid = make_grid(&sched, GridKind::LogSnr, steps, 1.0, 1e-3);
            let mut rng = Rng::new(1);
            let mut s = DpmSolver::new(sched, grid, rng.normal_tensor(8, 2), order);
            let _ = sample_with(&mut s, &m);
            assert_eq!(s.nfe(), want_nfe);
        }
    }

    #[test]
    fn converges_exact_model_order2() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::LogSnr, 10, 1.0, 1e-3);
        let mut rng = Rng::new(2);
        let mut s = DpmSolver::new(sched, grid, rng.normal_tensor(300, 2), 2);
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 280, "{on_ring}/300");
    }

    #[test]
    fn order3_at_least_as_good_as_order1_low_nfe() {
        // Equal NFE = 24: order 3 with 8 steps vs order 1 with 24 steps,
        // measured as endpoint distance to a fine-grid DDIM reference
        // (deterministic, unlike finite-sample FID with an exact model).
        // NFE must be high enough to reach the asymptotic regime: at
        // NFE 12 the logSNR step h ~ 3.4 and order 3 *loses* (mirroring
        // the paper's DPM-2 blowup at NFE 5).
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let mut rng = Rng::new(3);
        let x0 = rng.normal_tensor(256, 2);

        let fine = make_grid(&sched, GridKind::LogSnr, 400, 1.0, 1e-3);
        let mut reference = crate::solvers::ddim::Ddim::new(sched, fine, x0.clone());
        let truth = sample_with(&mut reference, &model);

        let err_for = |order: usize, steps: usize| {
            let grid = make_grid(&sched, GridKind::LogSnr, steps, 1.0, 1e-3);
            let mut s = DpmSolver::new(sched, grid, x0.clone(), order);
            sample_with(&mut s, &model).mean_row_dist(&truth)
        };
        let f3 = err_for(3, 8);
        let f1 = err_for(1, 24);
        assert!(f3 < f1, "dpm-3 {f3} vs dpm-1 {f1}");
    }

    #[test]
    fn fast_solver_runs() {
        let sched = VpSchedule::default();
        let nfe = 10;
        let orders = fast_order_schedule(nfe);
        let grid = make_grid(&sched, GridKind::LogSnr, orders.len(), 1.0, 1e-3);
        let mut rng = Rng::new(4);
        let mut s = DpmSolver::new_fast(sched, grid, rng.normal_tensor(32, 2), nfe);
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        assert!(out.all_finite());
        assert_eq!(s.nfe(), nfe);
    }
}
