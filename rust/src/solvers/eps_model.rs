//! The noise-prediction model abstraction.
//!
//! Solvers never talk to PJRT directly; they see `EpsModel`. Three
//! implementations exist:
//!   * `runtime::PjRtEps` — the production path (AOT HLO artifacts),
//!   * `AnalyticGmm` — the *exact* eps for a Gaussian-mixture data
//! ```text
//!     distribution (closed-form score), used by convergence tests: with a
//!     perfect model every solver must drive samples onto the mixture,
//! ```
//!   * `NoisyEps` — wraps any model with a smooth, deterministic,
//! ```text
//!     t-dependent error field that *grows as t -> 0*, reproducing the
//!     paper's Fig. 1 premise in a controlled way for robustness tests.
//! ```

use crate::solvers::schedule::VpSchedule;
use crate::tensor::Tensor;

/// Per-row conditioning sentinel: any channel value `< 0` means "this
/// row is unconditional". The guided workload ships cond rows carrying a
/// class id and uncond rows carrying this value in one fused slab.
pub const UNCOND: f32 = -1.0;

/// A noise-prediction network eps_theta(x, t) with per-sample times.
pub trait EpsModel: Send + Sync {
    /// Evaluate the model. `x` is (batch, dim); `t` has length batch.
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor;

    /// Conditional evaluation with a per-row class channel `c` (length
    /// batch; rows with `c < 0` are unconditional — see [`UNCOND`]).
    /// Models without a conditional head ignore the channel, so plain
    /// workloads are unaffected; rows a conditional model *does* honour
    /// must produce the same values for unconditional rows as
    /// [`EpsModel::eval`] would (the guided golden tests pin this).
    fn eval_cond(&self, x: &Tensor, t: &[f32], c: &[f32]) -> Tensor {
        let _ = c;
        self.eval(x, t)
    }

    /// Data dimension.
    fn dim(&self) -> usize;

    /// Count of evaluations so far (for NFE accounting), if tracked.
    fn eval_count(&self) -> usize {
        0
    }
}

/// Exact eps for a GMM data distribution with isotropic component noise.
///
/// For data `x0 ~ (1/J) sum_j N(c_j, s^2 I)` the marginal at time t is
/// `q_t = (1/J) sum_j N(sqrt_ab c_j, (ab s^2 + 1 - ab) I)`, whose score is
/// available in closed form; `eps*(x, t) = -sigma_t * score(x, t)` is the
/// unique noise prediction that makes the probability-flow ODE exact.
pub struct AnalyticGmm {
    pub sched: VpSchedule,
    /// Component means, each of length `dim`.
    pub centers: Vec<Vec<f64>>,
    /// Component standard deviation (isotropic).
    pub std: f64,
    dim: usize,
    evals: std::sync::atomic::AtomicUsize,
}

impl AnalyticGmm {
    pub fn new(sched: VpSchedule, centers: Vec<Vec<f64>>, std: f64) -> Self {
        assert!(!centers.is_empty());
        let dim = centers[0].len();
        assert!(centers.iter().all(|c| c.len() == dim));
        AnalyticGmm { sched, centers, std, dim, evals: Default::default() }
    }

    /// The standard 8-mode ring used by tests (mirrors data::gmm8).
    pub fn gmm8(sched: VpSchedule) -> Self {
        AnalyticGmm::new(sched, crate::data::gmm8_modes(), 0.15)
    }

    /// One row of the exact eps. `class = None` is the full-mixture
    /// score (the original unconditional path, op-for-op); `Some(j)`
    /// conditions on component `j` (responsibilities collapse to that
    /// mode — the closed-form "class-conditional" denoiser the guided
    /// workload steers with). Both [`EpsModel::eval`] and
    /// [`EpsModel::eval_cond`] route through here, so an unconditional
    /// row is bitwise identical whichever entry point (and whatever
    /// batch mix) evaluated it.
    fn eps_row(&self, row: &[f32], tr: f64, orow: &mut [f32], class: Option<usize>) {
        let sab = self.sched.sqrt_alpha_bar(tr);
        let ab = sab * sab;
        let var = ab * self.std * self.std + (1.0 - ab);
        let sigma = self.sched.sigma(tr);
        match class {
            Some(j) => {
                // Single-component posterior: w_j = 1.
                let c = &self.centers[j % self.centers.len()];
                for (k, &cv) in c.iter().enumerate() {
                    let diff = sab * cv - row[k] as f64;
                    orow[k] += (diff / var) as f32;
                }
            }
            None => {
                // Log-sum-exp responsibilities over components.
                let mut logw: Vec<f64> = Vec::with_capacity(self.centers.len());
                for c in &self.centers {
                    let d2: f64 = row
                        .iter()
                        .zip(c)
                        .map(|(&xv, &cv)| {
                            let d = xv as f64 - sab * cv;
                            d * d
                        })
                        .sum();
                    logw.push(-0.5 * d2 / var);
                }
                let m = logw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut wsum = 0.0;
                let w: Vec<f64> = logw
                    .iter()
                    .map(|&l| {
                        let e = (l - m).exp();
                        wsum += e;
                        e
                    })
                    .collect();

                // score = sum_j w_j (m_j - x) / var;  eps = -sigma * score.
                for (j, c) in self.centers.iter().enumerate() {
                    let wj = w[j] / wsum;
                    for (k, &cv) in c.iter().enumerate() {
                        let diff = sab * cv - row[k] as f64;
                        orow[k] += (wj * diff / var) as f32;
                    }
                }
            }
        }
        for v in orow.iter_mut() {
            *v *= -(sigma as f32);
        }
    }
}

impl EpsModel for AnalyticGmm {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        assert_eq!(x.rows(), t.len());
        assert_eq!(x.cols(), self.dim);
        self.evals.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut out = Tensor::zeros(x.rows(), x.cols());
        for r in 0..x.rows() {
            self.eps_row(x.row(r), t[r] as f64, out.row_mut(r), None);
        }
        out
    }

    fn eval_cond(&self, x: &Tensor, t: &[f32], c: &[f32]) -> Tensor {
        assert_eq!(x.rows(), t.len());
        assert_eq!(x.rows(), c.len());
        assert_eq!(x.cols(), self.dim);
        self.evals.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut out = Tensor::zeros(x.rows(), x.cols());
        for r in 0..x.rows() {
            let class = if c[r] < 0.0 { None } else { Some(c[r] as usize) };
            self.eps_row(x.row(r), t[r] as f64, out.row_mut(r), class);
        }
        out
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_count(&self) -> usize {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Wraps an `EpsModel` with a smooth deterministic error field:
///
/// ```text
///     eps'(x, t) = eps(x, t) + amp(t) * sin(W x + phi)
///
/// ```
/// with `amp(t) = amp0 * (1 - t)^power`, so the error grows as t -> 0 the
/// way the measured curves in artifacts/<ds>/train_report.json do. The
/// field is smooth in x (fixed random W, phi), so it perturbs high-order
/// solvers the way a consistently-wrong network does, not like iid noise.
pub struct NoisyEps<M: EpsModel> {
    pub inner: M,
    pub amp0: f64,
    pub power: f64,
    w: Vec<f64>,
    phi: Vec<f64>,
}

impl<M: EpsModel> NoisyEps<M> {
    pub fn new(inner: M, amp0: f64, power: f64, seed: u64) -> Self {
        let dim = inner.dim();
        let mut rng = crate::rng::Rng::new(seed);
        let w: Vec<f64> = (0..dim * dim).map(|_| rng.normal() * 1.5).collect();
        let phi: Vec<f64> = (0..dim).map(|_| rng.uniform_in(0.0, 6.28)).collect();
        NoisyEps { inner, amp0, power, w, phi }
    }

    fn amp(&self, t: f64) -> f64 {
        self.amp0 * (1.0 - t).max(0.0).powf(self.power)
    }

    /// Add the smooth error field to `out` (independent of conditioning,
    /// so the guided cond/uncond halves see the *same* wrongness — the
    /// regime ERS is designed for).
    fn perturb(&self, x: &Tensor, t: &[f32], out: &mut Tensor) {
        let d = self.dim();
        for r in 0..x.rows() {
            let amp = self.amp(t[r] as f64);
            if amp == 0.0 {
                continue;
            }
            let row = x.row(r);
            let orow = out.row_mut(r);
            for k in 0..d {
                let mut arg = self.phi[k];
                for (j, &xv) in row.iter().enumerate() {
                    arg += self.w[k * d + j] * xv as f64;
                }
                orow[k] += (amp * arg.sin()) as f32;
            }
        }
    }
}

impl<M: EpsModel> EpsModel for NoisyEps<M> {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        let mut out = self.inner.eval(x, t);
        self.perturb(x, t, &mut out);
        out
    }

    fn eval_cond(&self, x: &Tensor, t: &[f32], c: &[f32]) -> Tensor {
        let mut out = self.inner.eval_cond(x, t, c);
        self.perturb(x, t, &mut out);
        out
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_count(&self) -> usize {
        self.inner.eval_count()
    }
}

/// Counts evaluations and rows through to an inner model; used by tests
/// and the NFE accounting assertions.
pub struct CountingEps<M: EpsModel> {
    pub inner: M,
    calls: std::sync::atomic::AtomicUsize,
    rows: std::sync::atomic::AtomicUsize,
}

impl<M: EpsModel> CountingEps<M> {
    pub fn new(inner: M) -> Self {
        CountingEps { inner, calls: Default::default(), rows: Default::default() }
    }

    pub fn calls(&self) -> usize {
        self.calls.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn rows_evaluated(&self) -> usize {
        self.rows.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<M: EpsModel> EpsModel for CountingEps<M> {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.rows.fetch_add(x.rows(), std::sync::atomic::Ordering::Relaxed);
        self.inner.eval(x, t)
    }

    fn eval_cond(&self, x: &Tensor, t: &[f32], c: &[f32]) -> Tensor {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.rows.fetch_add(x.rows(), std::sync::atomic::Ordering::Relaxed);
        self.inner.eval_cond(x, t, c)
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gmm() -> AnalyticGmm {
        AnalyticGmm::gmm8(VpSchedule::default())
    }

    #[test]
    fn analytic_eps_shape() {
        let m = gmm();
        let x = Tensor::zeros(5, 2);
        let out = m.eval(&x, &[0.5; 5]);
        assert_eq!((out.rows(), out.cols()), (5, 2));
        assert!(out.all_finite());
        assert_eq!(m.eval_count(), 1);
    }

    #[test]
    fn analytic_eps_points_away_from_modes() {
        // At a point displaced from a mode, eps ~ (x - sab*c)/sigma-ish:
        // the noise estimate should reconstruct the displacement direction.
        let m = gmm();
        let t = 0.3f64;
        let sab = m.sched.sqrt_alpha_bar(t) as f32;
        // x slightly right of mode (2, 0) scaled to time t.
        let x = Tensor::from_vec(vec![2.0 * sab + 0.1, 0.0], 1, 2);
        let eps = m.eval(&x, &[t as f32]);
        assert!(eps.as_slice()[0] > 0.0, "eps_x should be positive");
        assert!(eps.as_slice()[1].abs() < 0.2);
    }

    #[test]
    fn analytic_eps_is_gaussian_limit_at_t1() {
        // At t=1 alpha_bar ~ 0: q_1 ~ N(0, I) (std contributions vanish),
        // so eps(x, 1) ~ x for moderate x.
        let m = gmm();
        let x = Tensor::from_vec(vec![0.7, -0.4], 1, 2);
        let eps = m.eval(&x, &[1.0]);
        assert!((eps.as_slice()[0] - 0.7).abs() < 0.05, "{:?}", eps.as_slice());
        assert!((eps.as_slice()[1] + 0.4).abs() < 0.05);
    }

    #[test]
    fn noisy_eps_error_grows_toward_zero_t() {
        let noisy = NoisyEps::new(gmm(), 0.5, 2.0, 7);
        let clean = gmm();
        let x = Tensor::from_vec(vec![1.0, 1.0, -0.5, 0.3], 2, 2);
        let d_hi = {
            let a = noisy.eval(&x, &[0.9, 0.9]);
            let b = clean.eval(&x, &[0.9, 0.9]);
            a.mean_row_dist(&b)
        };
        let d_lo = {
            let a = noisy.eval(&x, &[0.05, 0.05]);
            let b = clean.eval(&x, &[0.05, 0.05]);
            a.mean_row_dist(&b)
        };
        assert!(d_lo > d_hi, "error should grow as t->0: {d_lo} vs {d_hi}");
    }

    #[test]
    fn noisy_eps_deterministic() {
        let noisy = NoisyEps::new(gmm(), 0.3, 1.0, 9);
        let x = Tensor::from_vec(vec![0.2, -0.8], 1, 2);
        let a = noisy.eval(&x, &[0.4]);
        let b = noisy.eval(&x, &[0.4]);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn eval_cond_uncond_rows_bitwise_match_eval() {
        // Unconditional rows must be identical whether they ride the
        // plain path or a mixed cond/uncond slab — the invariant that
        // lets guided requests batch with unconditional batch-mates.
        let m = gmm();
        let x = Tensor::from_vec(vec![0.3, -0.8, 1.2, 0.4, -1.5, 0.9], 3, 2);
        let t = [0.7f32, 0.4, 0.1];
        let plain = m.eval(&x, &t);
        let mixed = m.eval_cond(&x, &t, &[UNCOND, 2.0, UNCOND]);
        assert_eq!(plain.row(0), mixed.row(0));
        assert_eq!(plain.row(2), mixed.row(2));
        // The conditioned row genuinely differs.
        assert_ne!(plain.row(1), mixed.row(1));
        let all_uncond = m.eval_cond(&x, &t, &[UNCOND; 3]);
        assert_eq!(plain.as_slice(), all_uncond.as_slice());
    }

    #[test]
    fn eval_cond_points_toward_the_conditioned_mode() {
        // Conditioning on mode j collapses the score onto that single
        // component: from the origin at moderate t, eps should push x
        // opposite the mode direction (eps ~ -(sab*c - x)/... * -sigma).
        let m = gmm();
        let t = 0.3f32;
        let x = Tensor::zeros(1, 2);
        for j in 0..8usize {
            let e = m.eval_cond(&x, &[t], &[j as f32]);
            let c = &m.centers[j];
            // eps = -sigma * (sab*c - 0)/var: anti-parallel to the mode.
            let dot = e.as_slice()[0] as f64 * c[0] + e.as_slice()[1] as f64 * c[1];
            assert!(dot < 0.0, "mode {j}: eps should point away, dot {dot}");
        }
        // Class ids wrap modulo the component count.
        let a = m.eval_cond(&x, &[t], &[1.0]);
        let b = m.eval_cond(&x, &[t], &[9.0]);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn noisy_and_counting_wrappers_forward_cond() {
        let noisy = NoisyEps::new(gmm(), 0.3, 1.0, 9);
        let x = Tensor::from_vec(vec![0.2, -0.8], 1, 2);
        let t = [0.4f32];
        // Same perturbation field on both paths: the cond/uncond delta
        // survives the wrapper exactly.
        let d_inner = {
            let a = noisy.inner.eval_cond(&x, &t, &[3.0]);
            let b = noisy.inner.eval(&x, &t);
            a.as_slice()[0] - b.as_slice()[0]
        };
        let d_noisy = {
            let a = noisy.eval_cond(&x, &t, &[3.0]);
            let b = noisy.eval(&x, &t);
            a.as_slice()[0] - b.as_slice()[0]
        };
        assert!((d_inner - d_noisy).abs() < 1e-6);

        let counting = CountingEps::new(gmm());
        let _ = counting.eval_cond(&x, &t, &[UNCOND]);
        assert_eq!(counting.calls(), 1);
        assert_eq!(counting.rows_evaluated(), 1);
    }

    #[test]
    fn counting_wrapper() {
        let m = CountingEps::new(gmm());
        let x = Tensor::zeros(3, 2);
        let _ = m.eval(&x, &[0.5; 3]);
        let _ = m.eval(&x, &[0.2; 3]);
        assert_eq!(m.calls(), 2);
        assert_eq!(m.rows_evaluated(), 6);
    }
}
