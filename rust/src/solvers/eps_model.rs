//! The noise-prediction model abstraction.
//!
//! Solvers never talk to PJRT directly; they see `EpsModel`. Three
//! implementations exist:
//!   * `runtime::PjRtEps` — the production path (AOT HLO artifacts),
//!   * `AnalyticGmm` — the *exact* eps for a Gaussian-mixture data
//! ```text
//!     distribution (closed-form score), used by convergence tests: with a
//!     perfect model every solver must drive samples onto the mixture,
//! ```
//!   * `NoisyEps` — wraps any model with a smooth, deterministic,
//! ```text
//!     t-dependent error field that *grows as t -> 0*, reproducing the
//!     paper's Fig. 1 premise in a controlled way for robustness tests.
//! ```

use crate::solvers::schedule::VpSchedule;
use crate::tensor::Tensor;

/// A noise-prediction network eps_theta(x, t) with per-sample times.
pub trait EpsModel: Send + Sync {
    /// Evaluate the model. `x` is (batch, dim); `t` has length batch.
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor;

    /// Data dimension.
    fn dim(&self) -> usize;

    /// Count of evaluations so far (for NFE accounting), if tracked.
    fn eval_count(&self) -> usize {
        0
    }
}

/// Exact eps for a GMM data distribution with isotropic component noise.
///
/// For data `x0 ~ (1/J) sum_j N(c_j, s^2 I)` the marginal at time t is
/// `q_t = (1/J) sum_j N(sqrt_ab c_j, (ab s^2 + 1 - ab) I)`, whose score is
/// available in closed form; `eps*(x, t) = -sigma_t * score(x, t)` is the
/// unique noise prediction that makes the probability-flow ODE exact.
pub struct AnalyticGmm {
    pub sched: VpSchedule,
    /// Component means, each of length `dim`.
    pub centers: Vec<Vec<f64>>,
    /// Component standard deviation (isotropic).
    pub std: f64,
    dim: usize,
    evals: std::sync::atomic::AtomicUsize,
}

impl AnalyticGmm {
    pub fn new(sched: VpSchedule, centers: Vec<Vec<f64>>, std: f64) -> Self {
        assert!(!centers.is_empty());
        let dim = centers[0].len();
        assert!(centers.iter().all(|c| c.len() == dim));
        AnalyticGmm { sched, centers, std, dim, evals: Default::default() }
    }

    /// The standard 8-mode ring used by tests (mirrors data::gmm8).
    pub fn gmm8(sched: VpSchedule) -> Self {
        AnalyticGmm::new(sched, crate::data::gmm8_modes(), 0.15)
    }
}

impl EpsModel for AnalyticGmm {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        assert_eq!(x.rows(), t.len());
        assert_eq!(x.cols(), self.dim);
        self.evals.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut out = Tensor::zeros(x.rows(), x.cols());
        for r in 0..x.rows() {
            let tr = t[r] as f64;
            let sab = self.sched.sqrt_alpha_bar(tr);
            let ab = sab * sab;
            let var = ab * self.std * self.std + (1.0 - ab);
            let sigma = self.sched.sigma(tr);
            let row = x.row(r);

            // Log-sum-exp responsibilities over components.
            let mut logw: Vec<f64> = Vec::with_capacity(self.centers.len());
            for c in &self.centers {
                let d2: f64 = row
                    .iter()
                    .zip(c)
                    .map(|(&xv, &cv)| {
                        let d = xv as f64 - sab * cv;
                        d * d
                    })
                    .sum();
                logw.push(-0.5 * d2 / var);
            }
            let m = logw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut wsum = 0.0;
            let w: Vec<f64> = logw
                .iter()
                .map(|&l| {
                    let e = (l - m).exp();
                    wsum += e;
                    e
                })
                .collect();

            // score = sum_j w_j (m_j - x) / var;  eps = -sigma * score.
            let orow = out.row_mut(r);
            for (j, c) in self.centers.iter().enumerate() {
                let wj = w[j] / wsum;
                for (k, &cv) in c.iter().enumerate() {
                    let diff = sab * cv - row[k] as f64;
                    orow[k] += (wj * diff / var) as f32;
                }
            }
            for v in orow.iter_mut() {
                *v *= -(sigma as f32);
            }
        }
        out
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_count(&self) -> usize {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Wraps an `EpsModel` with a smooth deterministic error field:
///
/// ```text
///     eps'(x, t) = eps(x, t) + amp(t) * sin(W x + phi)
///
/// ```
/// with `amp(t) = amp0 * (1 - t)^power`, so the error grows as t -> 0 the
/// way the measured curves in artifacts/<ds>/train_report.json do. The
/// field is smooth in x (fixed random W, phi), so it perturbs high-order
/// solvers the way a consistently-wrong network does, not like iid noise.
pub struct NoisyEps<M: EpsModel> {
    pub inner: M,
    pub amp0: f64,
    pub power: f64,
    w: Vec<f64>,
    phi: Vec<f64>,
}

impl<M: EpsModel> NoisyEps<M> {
    pub fn new(inner: M, amp0: f64, power: f64, seed: u64) -> Self {
        let dim = inner.dim();
        let mut rng = crate::rng::Rng::new(seed);
        let w: Vec<f64> = (0..dim * dim).map(|_| rng.normal() * 1.5).collect();
        let phi: Vec<f64> = (0..dim).map(|_| rng.uniform_in(0.0, 6.28)).collect();
        NoisyEps { inner, amp0, power, w, phi }
    }

    fn amp(&self, t: f64) -> f64 {
        self.amp0 * (1.0 - t).max(0.0).powf(self.power)
    }
}

impl<M: EpsModel> EpsModel for NoisyEps<M> {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        let mut out = self.inner.eval(x, t);
        let d = self.dim();
        for r in 0..x.rows() {
            let amp = self.amp(t[r] as f64);
            if amp == 0.0 {
                continue;
            }
            let row = x.row(r);
            let orow = out.row_mut(r);
            for k in 0..d {
                let mut arg = self.phi[k];
                for (j, &xv) in row.iter().enumerate() {
                    arg += self.w[k * d + j] * xv as f64;
                }
                orow[k] += (amp * arg.sin()) as f32;
            }
        }
        out
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_count(&self) -> usize {
        self.inner.eval_count()
    }
}

/// Counts evaluations and rows through to an inner model; used by tests
/// and the NFE accounting assertions.
pub struct CountingEps<M: EpsModel> {
    pub inner: M,
    calls: std::sync::atomic::AtomicUsize,
    rows: std::sync::atomic::AtomicUsize,
}

impl<M: EpsModel> CountingEps<M> {
    pub fn new(inner: M) -> Self {
        CountingEps { inner, calls: Default::default(), rows: Default::default() }
    }

    pub fn calls(&self) -> usize {
        self.calls.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn rows_evaluated(&self) -> usize {
        self.rows.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<M: EpsModel> EpsModel for CountingEps<M> {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.rows.fetch_add(x.rows(), std::sync::atomic::Ordering::Relaxed);
        self.inner.eval(x, t)
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gmm() -> AnalyticGmm {
        AnalyticGmm::gmm8(VpSchedule::default())
    }

    #[test]
    fn analytic_eps_shape() {
        let m = gmm();
        let x = Tensor::zeros(5, 2);
        let out = m.eval(&x, &[0.5; 5]);
        assert_eq!((out.rows(), out.cols()), (5, 2));
        assert!(out.all_finite());
        assert_eq!(m.eval_count(), 1);
    }

    #[test]
    fn analytic_eps_points_away_from_modes() {
        // At a point displaced from a mode, eps ~ (x - sab*c)/sigma-ish:
        // the noise estimate should reconstruct the displacement direction.
        let m = gmm();
        let t = 0.3f64;
        let sab = m.sched.sqrt_alpha_bar(t) as f32;
        // x slightly right of mode (2, 0) scaled to time t.
        let x = Tensor::from_vec(vec![2.0 * sab + 0.1, 0.0], 1, 2);
        let eps = m.eval(&x, &[t as f32]);
        assert!(eps.as_slice()[0] > 0.0, "eps_x should be positive");
        assert!(eps.as_slice()[1].abs() < 0.2);
    }

    #[test]
    fn analytic_eps_is_gaussian_limit_at_t1() {
        // At t=1 alpha_bar ~ 0: q_1 ~ N(0, I) (std contributions vanish),
        // so eps(x, 1) ~ x for moderate x.
        let m = gmm();
        let x = Tensor::from_vec(vec![0.7, -0.4], 1, 2);
        let eps = m.eval(&x, &[1.0]);
        assert!((eps.as_slice()[0] - 0.7).abs() < 0.05, "{:?}", eps.as_slice());
        assert!((eps.as_slice()[1] + 0.4).abs() < 0.05);
    }

    #[test]
    fn noisy_eps_error_grows_toward_zero_t() {
        let noisy = NoisyEps::new(gmm(), 0.5, 2.0, 7);
        let clean = gmm();
        let x = Tensor::from_vec(vec![1.0, 1.0, -0.5, 0.3], 2, 2);
        let d_hi = {
            let a = noisy.eval(&x, &[0.9, 0.9]);
            let b = clean.eval(&x, &[0.9, 0.9]);
            a.mean_row_dist(&b)
        };
        let d_lo = {
            let a = noisy.eval(&x, &[0.05, 0.05]);
            let b = clean.eval(&x, &[0.05, 0.05]);
            a.mean_row_dist(&b)
        };
        assert!(d_lo > d_hi, "error should grow as t->0: {d_lo} vs {d_hi}");
    }

    #[test]
    fn noisy_eps_deterministic() {
        let noisy = NoisyEps::new(gmm(), 0.3, 1.0, 9);
        let x = Tensor::from_vec(vec![0.2, -0.8], 1, 2);
        let a = noisy.eval(&x, &[0.4]);
        let b = noisy.eval(&x, &[0.4]);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn counting_wrapper() {
        let m = CountingEps::new(gmm());
        let x = Tensor::zeros(3, 2);
        let _ = m.eval(&x, &[0.5; 3]);
        let _ = m.eval(&x, &[0.2; 3]);
        assert_eq!(m.calls(), 2);
        assert_eq!(m.rows_evaluated(), 6);
    }
}
