//! Traditional implicit-Adams predictor–corrector (the "Implicit Adams"
//! baseline of the paper's Fig. 1 / Fig. 7, after Diethelm et al. 2002).
//!
//! PECE scheme, one network evaluation per step:
//!   P: eps_P = AB4 combination of the noise history (Eq. 9)
//!      x_pred = phi(x_i, eps_P, t_i -> t_{i+1})
//!   E: eps_new = eps_theta(x_pred, t_{i+1})
//!   C: eps_C = AM combination (Eq. 11) using eps_new as the implicit term
//!      x_{i+1} = phi(x_i, eps_C, t_i -> t_{i+1})
//!   (the evaluation at the predicted point enters the history for the
//!    next step — the standard PECE convention)
//!
//! The corrector order ramps 2 -> 4 while the history fills; the first
//! step is plain DDIM. This gives the method the same 1-NFE/step budget
//! as DDIM and ERA, which is how the paper compares them.
//!
//! History lives in a preallocated [`HistoryRing`] that adopts each
//! model output by move; the predictor/corrector combinations and both
//! transfers run in place through the kernel layer with coefficients
//! from the shared [`TrajectoryPlan`] — zero allocations per steady
//! step.

use std::sync::Arc;

use crate::kernels::{fused, HistoryRing, PlanView, TrajectoryPlan};
use crate::solvers::adams_explicit::AB4;
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// Adams–Moulton weights by order; index 0 multiplies the *implicit*
/// (newest, predicted-point) evaluation. Orders 2..4. (The serving path
/// reads the same tables from the [`TrajectoryPlan`]; this free
/// function remains for tests and external callers.)
pub fn am_weights(order: usize) -> &'static [f64] {
    match order {
        2 => &[0.5, 0.5],
        3 => &[5.0 / 12.0, 8.0 / 12.0, -1.0 / 12.0],
        _ => &[9.0 / 24.0, 19.0 / 24.0, -5.0 / 24.0, 1.0 / 24.0],
    }
}

pub struct ImplicitAdamsPc {
    plan: PlanView,
    x: Arc<Tensor>,
    i: usize,
    nfe: usize,
    /// Newest-first eps history (ring adopts model outputs by move).
    hist: HistoryRing,
    /// Predictor/corrector combination scratch.
    comb: Tensor,
    /// Predicted evaluation point handed out through [`EvalRequest`].
    x_pred: Arc<Tensor>,
    pending: bool,
}

impl ImplicitAdamsPc {
    pub fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        assert!(grid.len() >= 2);
        ImplicitAdamsPc::with_plan(Arc::new(TrajectoryPlan::new(sched, grid)), x0)
    }

    /// Build over a shared precomputed plan (the serving path).
    pub fn with_plan(plan: Arc<TrajectoryPlan>, x0: Tensor) -> Self {
        ImplicitAdamsPc::with_view(PlanView::full(plan), x0)
    }

    /// Build over a (possibly suffix) window of a shared plan.
    pub fn with_view(plan: PlanView, x0: Tensor) -> Self {
        let (rows, cols) = (x0.rows(), x0.cols());
        ImplicitAdamsPc {
            plan,
            x: Arc::new(x0),
            i: 0,
            nfe: 0,
            hist: HistoryRing::new(4),
            comb: Tensor::zeros(rows, cols),
            x_pred: Arc::new(Tensor::zeros(rows, cols)),
            pending: false,
        }
    }

    /// AB predictor combination from history into `comb` (order adapts
    /// to fill level); accumulation order matches the allocating
    /// `weighted_sum` path exactly. The part list lives on the stack —
    /// the history ring never exceeds 4 slots.
    fn predict_eps(&mut self) {
        let n = self.hist.len();
        if n == 1 {
            self.comb.as_mut_slice().copy_from_slice(self.hist.get(0).as_slice());
            return;
        }
        let w: &[f64] = match n {
            2 => &[1.5, -0.5],
            3 => &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
            _ => &AB4,
        };
        let mut parts: [&[f32]; 4] = [&[]; 4];
        for (slot, h) in parts.iter_mut().zip(self.hist.iter()) {
            *slot = h.as_slice();
        }
        fused::weighted_sum_into(self.comb.as_mut_slice(), &parts[..w.len()], w);
    }
}

impl Solver for ImplicitAdamsPc {
    fn name(&self) -> String {
        "iadams".into()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        self.pending = true;
        if self.hist.is_empty() {
            // First step: evaluate at the current point (plain DDIM).
            Some(EvalRequest { x: Arc::clone(&self.x), t: self.plan.t(self.i), cond: None })
        } else {
            // Predict x at t_{i+1} with the explicit-Adams combination and
            // evaluate there (the single evaluation of this step).
            self.predict_eps();
            let (a, b) = self.plan.ddim_coeffs(self.i);
            let xp = Arc::make_mut(&mut self.x_pred);
            fused::affine_into(
                xp.as_mut_slice(),
                a as f32,
                self.x.as_slice(),
                b as f32,
                self.comb.as_slice(),
            );
            Some(EvalRequest {
                x: Arc::clone(&self.x_pred),
                t: self.plan.t(self.i + 1),
                cond: None,
            })
        }
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;
        let (a, b) = self.plan.ddim_coeffs(self.i);

        if self.hist.is_empty() {
            // DDIM bootstrap step; eps is at (x_i, t_i).
            let x = Arc::make_mut(&mut self.x);
            fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, eps.as_slice());
            self.hist.push(eps);
            self.i += 1;
            return;
        }

        // Corrector: AM mix of the predicted-point eval (implicit slot)
        // and the history; order ramps with available history.
        let order = (self.hist.len() + 1).min(4);
        let w = self.plan.am_weights(order);
        let out = self.comb.as_mut_slice();
        fused::zero(out);
        fused::axpy(out, w[0] as f32, eps.as_slice());
        for (h, &wm) in self.hist.iter().take(order - 1).zip(w[1..].iter()) {
            fused::axpy(out, wm as f32, h.as_slice());
        }
        let x = Arc::make_mut(&mut self.x);
        fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, self.comb.as_slice());

        // PECE: the predicted-point evaluation becomes history for t_{i+1}.
        self.hist.push(eps); // evicted oldest slot is simply dropped
        self.i += 1;
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.plan.grid().len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, NoisyEps};
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    #[test]
    fn am_weights_sum_to_one() {
        for order in 2..=4 {
            let s: f64 = am_weights(order).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "order {order}");
        }
    }

    #[test]
    fn one_nfe_per_step() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 15, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let mut s = ImplicitAdamsPc::new(sched, grid, rng.normal_tensor(16, 2));
        let m = AnalyticGmm::gmm8(sched);
        let _ = sample_with(&mut s, &m);
        assert_eq!(s.nfe(), 15);
    }

    #[test]
    fn converges_exact_model() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 30, 1.0, 1e-3);
        let mut rng = Rng::new(1);
        let mut s = ImplicitAdamsPc::new(sched, grid, rng.normal_tensor(200, 2));
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 185, "{on_ring}/200");
    }

    #[test]
    fn beats_ddim_with_exact_model() {
        // Higher order must pay off when the model is exact: compare the
        // endpoint against a fine-grid DDIM reference trajectory from the
        // same x0 (deterministic; FID would drown in finite-sample noise).
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        // NFE 20: well inside the asymptotic regime (at NFE <= 12 the GMM
        // score is stiff enough that multistep ringing can lose to DDIM,
        // the same regime where the paper's own Tab. 1 shows DPM-2
        // FID 310 at NFE 5).
        let nfe = 20;
        let mut rng = Rng::new(2);
        let x0 = rng.normal_tensor(256, 2);

        let fine = make_grid(&sched, GridKind::Uniform, 400, 1.0, 1e-3);
        let mut reference = crate::solvers::ddim::Ddim::new(sched, fine, x0.clone());
        let truth = sample_with(&mut reference, &model);

        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let mut ia = ImplicitAdamsPc::new(sched, grid.clone(), x0.clone());
        let err_ia = sample_with(&mut ia, &model).mean_row_dist(&truth);
        let mut dd = crate::solvers::ddim::Ddim::new(sched, grid, x0);
        let err_dd = sample_with(&mut dd, &model).mean_row_dist(&truth);
        assert!(err_ia < err_dd, "iadams {err_ia} vs ddim {err_dd}");
    }

    #[test]
    fn degrades_under_injected_error() {
        // The paper's premise: the fixed-coefficient PC is NOT robust to
        // estimation error. Sanity-check that injected error hurts.
        let sched = VpSchedule::default();
        let clean = AnalyticGmm::gmm8(sched);
        let noisy = NoisyEps::new(AnalyticGmm::gmm8(sched), 0.8, 2.0, 11);
        let reference =
            crate::metrics::Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225]);
        let run = |m: &dyn crate::solvers::EpsModel| {
            let grid = make_grid(&sched, GridKind::Uniform, 15, 1.0, 1e-3);
            let mut rng = Rng::new(4);
            let mut s = ImplicitAdamsPc::new(sched, grid, rng.normal_tensor(1500, 2));
            let out = sample_with(&mut s, m);
            crate::metrics::fid(&out, &reference)
        };
        assert!(run(&noisy) > run(&clean));
    }
}
