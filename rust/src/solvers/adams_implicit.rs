//! Traditional implicit-Adams predictor–corrector (the "Implicit Adams"
//! baseline of the paper's Fig. 1 / Fig. 7, after Diethelm et al. 2002).
//!
//! PECE scheme, one network evaluation per step:
//!   P: eps_P = AB4 combination of the noise history (Eq. 9)
//!      x_pred = phi(x_i, eps_P, t_i -> t_{i+1})
//!   E: eps_new = eps_theta(x_pred, t_{i+1})
//!   C: eps_C = AM combination (Eq. 11) using eps_new as the implicit term
//!      x_{i+1} = phi(x_i, eps_C, t_i -> t_{i+1})
//!   (the evaluation at the predicted point enters the history for the
//!    next step — the standard PECE convention)
//!
//! The corrector order ramps 2 -> 4 while the history fills; the first
//! step is plain DDIM. This gives the method the same 1-NFE/step budget
//! as DDIM and ERA, which is how the paper compares them.

use std::collections::VecDeque;

use crate::solvers::adams_explicit::AB4;
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// Adams–Moulton weights by order; index 0 multiplies the *implicit*
/// (newest, predicted-point) evaluation. Orders 2..4.
pub fn am_weights(order: usize) -> &'static [f64] {
    match order {
        2 => &[0.5, 0.5],
        3 => &[5.0 / 12.0, 8.0 / 12.0, -1.0 / 12.0],
        _ => &[9.0 / 24.0, 19.0 / 24.0, -5.0 / 24.0, 1.0 / 24.0],
    }
}

pub struct ImplicitAdamsPc {
    sched: VpSchedule,
    grid: Vec<f64>,
    x: Tensor,
    i: usize,
    nfe: usize,
    /// Newest-first eps history.
    hist: VecDeque<Tensor>,
    pending: bool,
}

impl ImplicitAdamsPc {
    pub fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        assert!(grid.len() >= 2);
        ImplicitAdamsPc {
            sched,
            grid,
            x: x0,
            i: 0,
            nfe: 0,
            hist: VecDeque::with_capacity(4),
            pending: false,
        }
    }

    fn phi(&self, x: &Tensor, eps: &Tensor, t_from: f64, t_to: f64) -> Tensor {
        let (a, b) = self.sched.ddim_coeffs(t_from, t_to);
        x.affine(a as f32, b as f32, eps)
    }

    /// AB predictor combination from history (order adapts to fill level).
    fn predict_eps(&self) -> Tensor {
        let n = self.hist.len();
        let refs: Vec<&Tensor> = self.hist.iter().collect();
        match n {
            1 => refs[0].clone(),
            2 => Tensor::weighted_sum(&refs[..2], &[1.5, -0.5]),
            3 => Tensor::weighted_sum(&refs[..3], &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0]),
            _ => Tensor::weighted_sum(&refs[..4], &AB4),
        }
    }
}

impl Solver for ImplicitAdamsPc {
    fn name(&self) -> String {
        "iadams".into()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        self.pending = true;
        let t_cur = self.grid[self.i];
        let t_next = self.grid[self.i + 1];
        if self.hist.is_empty() {
            // First step: evaluate at the current point (plain DDIM).
            Some(EvalRequest { x: self.x.clone(), t: t_cur })
        } else {
            // Predict x at t_{i+1} with the explicit-Adams combination and
            // evaluate there (the single evaluation of this step).
            let eps_p = self.predict_eps();
            let x_pred = self.phi(&self.x, &eps_p, t_cur, t_next);
            Some(EvalRequest { x: x_pred, t: t_next })
        }
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;
        let t_cur = self.grid[self.i];
        let t_next = self.grid[self.i + 1];

        if self.hist.is_empty() {
            // DDIM bootstrap step; eps is at (x_i, t_i).
            self.x = self.phi(&self.x, &eps, t_cur, t_next);
            self.hist.push_front(eps);
            self.i += 1;
            return;
        }

        // Corrector: AM mix of the predicted-point eval (implicit slot)
        // and the history; order ramps with available history.
        let order = (self.hist.len() + 1).min(4);
        let w = am_weights(order);
        let mut tensors: Vec<&Tensor> = vec![&eps];
        tensors.extend(self.hist.iter().take(order - 1));
        let eps_c = Tensor::weighted_sum(&tensors, w);
        self.x = self.phi(&self.x, &eps_c, t_cur, t_next);

        // PECE: the predicted-point evaluation becomes history for t_{i+1}.
        self.hist.push_front(eps);
        if self.hist.len() > 4 {
            self.hist.pop_back();
        }
        self.i += 1;
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.grid.len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, NoisyEps};
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    #[test]
    fn am_weights_sum_to_one() {
        for order in 2..=4 {
            let s: f64 = am_weights(order).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "order {order}");
        }
    }

    #[test]
    fn one_nfe_per_step() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 15, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let mut s = ImplicitAdamsPc::new(sched, grid, rng.normal_tensor(16, 2));
        let m = AnalyticGmm::gmm8(sched);
        let _ = sample_with(&mut s, &m);
        assert_eq!(s.nfe(), 15);
    }

    #[test]
    fn converges_exact_model() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 30, 1.0, 1e-3);
        let mut rng = Rng::new(1);
        let mut s = ImplicitAdamsPc::new(sched, grid, rng.normal_tensor(200, 2));
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 185, "{on_ring}/200");
    }

    #[test]
    fn beats_ddim_with_exact_model() {
        // Higher order must pay off when the model is exact: compare the
        // endpoint against a fine-grid DDIM reference trajectory from the
        // same x0 (deterministic; FID would drown in finite-sample noise).
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        // NFE 20: well inside the asymptotic regime (at NFE <= 12 the GMM
        // score is stiff enough that multistep ringing can lose to DDIM,
        // the same regime where the paper's own Tab. 1 shows DPM-2
        // FID 310 at NFE 5).
        let nfe = 20;
        let mut rng = Rng::new(2);
        let x0 = rng.normal_tensor(256, 2);

        let fine = make_grid(&sched, GridKind::Uniform, 400, 1.0, 1e-3);
        let mut reference = crate::solvers::ddim::Ddim::new(sched, fine, x0.clone());
        let truth = sample_with(&mut reference, &model);

        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let mut ia = ImplicitAdamsPc::new(sched, grid.clone(), x0.clone());
        let err_ia = sample_with(&mut ia, &model).mean_row_dist(&truth);
        let mut dd = crate::solvers::ddim::Ddim::new(sched, grid, x0);
        let err_dd = sample_with(&mut dd, &model).mean_row_dist(&truth);
        assert!(err_ia < err_dd, "iadams {err_ia} vs ddim {err_dd}");
    }

    #[test]
    fn degrades_under_injected_error() {
        // The paper's premise: the fixed-coefficient PC is NOT robust to
        // estimation error. Sanity-check that injected error hurts.
        let sched = VpSchedule::default();
        let clean = AnalyticGmm::gmm8(sched);
        let noisy = NoisyEps::new(AnalyticGmm::gmm8(sched), 0.8, 2.0, 11);
        let reference =
            crate::metrics::Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225]);
        let run = |m: &dyn crate::solvers::EpsModel| {
            let grid = make_grid(&sched, GridKind::Uniform, 15, 1.0, 1e-3);
            let mut rng = Rng::new(4);
            let mut s = ImplicitAdamsPc::new(sched, grid, rng.normal_tensor(1500, 2));
            let out = sample_with(&mut s, m);
            crate::metrics::fid(&out, &reference)
        };
        assert!(run(&noisy) > run(&clean));
    }
}
