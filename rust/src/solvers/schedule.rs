//! VP noise schedule + timestep grids (Rust mirror of python/compile/diffusion.py).
//!
//! The continuous-time variance-preserving schedule of Song et al. 2020b:
//!
//! ```text
//!     beta(t)      = beta_min + t (beta_max - beta_min)
//!     alpha_bar(t) = exp(-0.5 t^2 (beta_max - beta_min) - t beta_min)
//!
//! ```
//! `lambda(t) = log(alpha/sigma)` (half-logSNR) drives both the logSNR
//! timestep grid (used by DPM-Solver and by the paper on CIFAR-10) and the
//! DPM-Solver exponential-integrator steps. The artifact manifest carries
//! probe values from the Python side; integration tests assert this mirror
//! matches them to float precision.

/// Continuous-time VP schedule.
#[derive(Clone, Copy, Debug)]
pub struct VpSchedule {
    pub beta_min: f64,
    pub beta_max: f64,
}

impl Default for VpSchedule {
    fn default() -> Self {
        VpSchedule { beta_min: 0.1, beta_max: 20.0 }
    }
}

impl VpSchedule {
    pub fn new(beta_min: f64, beta_max: f64) -> Self {
        assert!(beta_max > beta_min && beta_min > 0.0);
        VpSchedule { beta_min, beta_max }
    }

    /// log sqrt(alpha_bar(t)) — the "log alpha" of the DPM-Solver papers.
    #[inline]
    pub fn log_alpha(&self, t: f64) -> f64 {
        -0.25 * t * t * (self.beta_max - self.beta_min) - 0.5 * t * self.beta_min
    }

    /// alpha_bar(t) in (0, 1].
    #[inline]
    pub fn alpha_bar(&self, t: f64) -> f64 {
        (2.0 * self.log_alpha(t)).exp()
    }

    /// sqrt(alpha_bar(t)).
    #[inline]
    pub fn sqrt_alpha_bar(&self, t: f64) -> f64 {
        self.log_alpha(t).exp()
    }

    /// sigma(t) = sqrt(1 - alpha_bar(t)).
    #[inline]
    pub fn sigma(&self, t: f64) -> f64 {
        (1.0 - self.alpha_bar(t)).max(0.0).sqrt()
    }

    /// Half-logSNR lambda(t) = log(alpha(t) / sigma(t)), monotone decreasing.
    #[inline]
    pub fn lambda(&self, t: f64) -> f64 {
        let log_ab = 2.0 * self.log_alpha(t);
        // log(alpha/sigma) = 0.5*(log ab - log(1-ab)); ln_1p for stability.
        0.5 * (log_ab - (-(log_ab).exp_m1()).ln())
    }

    /// Inverse of `lambda` by bisection on [t_lo, t_hi]. lambda is strictly
    /// decreasing, so this is well-posed; 80 iterations gives ~1e-24
    /// interval width, far below f64 noise.
    pub fn t_of_lambda(&self, lam: f64) -> f64 {
        let (mut lo, mut hi) = (1e-9, 1.0);
        // Clamp outside the representable range.
        if lam >= self.lambda(lo) {
            return lo;
        }
        if lam <= self.lambda(hi) {
            return hi;
        }
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.lambda(mid) > lam {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// DDIM transition coefficients (Eq. 8): `x' = a x + b eps`.
    #[inline]
    pub fn ddim_coeffs(&self, t_cur: f64, t_next: f64) -> (f64, f64) {
        let a = self.sqrt_alpha_bar(t_next) / self.sqrt_alpha_bar(t_cur);
        let b = self.sigma(t_next) - a * self.sigma(t_cur);
        (a, b)
    }
}

/// Timestep grid flavours from the paper's experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GridKind {
    /// Uniform in t (paper's LSUN setting).
    Uniform,
    /// Quadratic spacing, denser near t_end.
    Quadratic,
    /// Uniform in logSNR (paper's CIFAR-10 setting, after DPM-Solver).
    LogSnr,
}

impl GridKind {
    pub fn parse(s: &str) -> Option<GridKind> {
        match s {
            "uniform" => Some(GridKind::Uniform),
            "quadratic" => Some(GridKind::Quadratic),
            "logsnr" => Some(GridKind::LogSnr),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GridKind::Uniform => "uniform",
            GridKind::Quadratic => "quadratic",
            GridKind::LogSnr => "logsnr",
        }
    }
}

/// Build the decreasing timestep sequence {t_i}_{i=0}^{N}: t_0 = t_start
/// (max noise), t_N = t_end (the paper's 1e-3 / 1e-4). `n_steps = N` is
/// the number of solver transitions (== NFE for 1-eval/step solvers).
pub fn make_grid(
    sched: &VpSchedule,
    kind: GridKind,
    n_steps: usize,
    t_start: f64,
    t_end: f64,
) -> Vec<f64> {
    assert!(n_steps >= 1, "need at least one step");
    assert!(t_start > t_end && t_end > 0.0, "grid must decrease to t_end > 0");
    let n = n_steps;
    let mut ts = Vec::with_capacity(n + 1);
    match kind {
        GridKind::Uniform => {
            for i in 0..=n {
                let f = i as f64 / n as f64;
                ts.push(t_start + (t_end - t_start) * f);
            }
        }
        GridKind::Quadratic => {
            let (rs, re) = (t_start.sqrt(), t_end.sqrt());
            for i in 0..=n {
                let f = i as f64 / n as f64;
                let r = rs + (re - rs) * f;
                ts.push(r * r);
            }
        }
        GridKind::LogSnr => {
            let (ls, le) = (sched.lambda(t_start), sched.lambda(t_end));
            for i in 0..=n {
                let f = i as f64 / n as f64;
                ts.push(sched.t_of_lambda(ls + (le - ls) * f));
            }
        }
    }
    // Pin endpoints exactly regardless of inversion round-off.
    ts[0] = t_start;
    ts[n] = t_end;
    ts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_bar_bounds_and_endpoints() {
        let s = VpSchedule::default();
        assert!((s.alpha_bar(1e-6) - 1.0).abs() < 1e-4);
        assert!(s.alpha_bar(1.0) < 1e-4);
        for i in 1..100 {
            let t = i as f64 / 100.0;
            let ab = s.alpha_bar(t);
            assert!(ab > 0.0 && ab < 1.0);
        }
    }

    #[test]
    fn alpha_bar_monotone_decreasing() {
        let s = VpSchedule::default();
        let mut prev = s.alpha_bar(1e-5);
        for i in 1..=1000 {
            let ab = s.alpha_bar(i as f64 / 1000.0);
            assert!(ab < prev);
            prev = ab;
        }
    }

    #[test]
    fn matches_python_closed_form() {
        // Values computed from the python VpSchedule (test_diffusion.py's
        // quadrature check pins the same closed form).
        let s = VpSchedule::default();
        let t: f64 = 0.37;
        let expect = (-0.5 * t * t * (20.0 - 0.1) - t * 0.1f64).exp();
        assert!((s.alpha_bar(0.37) - expect).abs() < 1e-12);
    }

    #[test]
    fn lambda_monotone_and_inverts() {
        let s = VpSchedule::default();
        let mut prev = f64::INFINITY;
        for i in 1..=50 {
            let t = i as f64 / 50.0;
            let lam = s.lambda(t);
            assert!(lam < prev, "lambda must decrease");
            prev = lam;
            let t_back = s.t_of_lambda(lam);
            assert!((t_back - t).abs() < 1e-9, "t={t} back={t_back}");
        }
    }

    #[test]
    fn lambda_clamps_out_of_range() {
        let s = VpSchedule::default();
        assert!(s.t_of_lambda(1e9) <= 1e-8);
        assert!((s.t_of_lambda(-1e9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ddim_coeffs_identity_when_static() {
        let s = VpSchedule::default();
        let (a, b) = s.ddim_coeffs(0.5, 0.5);
        assert!((a - 1.0).abs() < 1e-12);
        assert!(b.abs() < 1e-12);
    }

    #[test]
    fn grids_shape_and_endpoints() {
        let s = VpSchedule::default();
        for kind in [GridKind::Uniform, GridKind::Quadratic, GridKind::LogSnr] {
            let ts = make_grid(&s, kind, 10, 1.0, 1e-3);
            assert_eq!(ts.len(), 11);
            assert_eq!(ts[0], 1.0);
            assert_eq!(ts[10], 1e-3);
            for w in ts.windows(2) {
                assert!(w[1] < w[0], "{kind:?} grid must strictly decrease");
            }
        }
    }

    #[test]
    fn logsnr_grid_uniform_in_lambda() {
        let s = VpSchedule::default();
        let ts = make_grid(&s, GridKind::LogSnr, 8, 1.0, 1e-3);
        let lams: Vec<f64> = ts.iter().map(|&t| s.lambda(t)).collect();
        let step = lams[1] - lams[0];
        for w in lams.windows(2) {
            assert!(((w[1] - w[0]) - step).abs() < 1e-6);
        }
    }

    #[test]
    fn quadratic_denser_near_end() {
        let s = VpSchedule::default();
        let ts = make_grid(&s, GridKind::Quadratic, 10, 1.0, 1e-3);
        let first = ts[0] - ts[1];
        let last = ts[9] - ts[10];
        assert!(last < first);
    }

    #[test]
    fn grid_kind_parse_roundtrip() {
        for k in [GridKind::Uniform, GridKind::Quadratic, GridKind::LogSnr] {
            assert_eq!(GridKind::parse(k.name()), Some(k));
        }
        assert_eq!(GridKind::parse("nope"), None);
    }
}
