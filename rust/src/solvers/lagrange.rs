//! Lagrange interpolation machinery (paper Eq. 13/14).
//!
//! The ERA predictor interpolates the buffered noise estimates
//! `{(t_{tau_m}, eps_{tau_m})}` with the classic Lagrange basis
//!
//! ```text
//!     l_m(t) = prod_{l != m} (t - t_{tau_l}) / (t_{tau_m} - t_{tau_l})
//!
//! ```
//! and evaluates `L_eps(t) = sum_m l_m(t) eps_{tau_m}` at the next grid
//! time. Weights are computed in f64 (nearby nodes at small t produce
//! large alternating-sign weights; f32 accumulation visibly degrades the
//! high-order ablations) and the tensor combination reuses the fused
//! weighted-sum path shared with the `solver_combine` artifact.

use crate::tensor::Tensor;

/// Lagrange basis weights `l_m(t)` for the given nodes at evaluation
/// point `t`. Panics if nodes are not pairwise distinct.
pub fn weights(nodes: &[f64], t: f64) -> Vec<f64> {
    assert!(!nodes.is_empty(), "lagrange::weights over no nodes");
    let k = nodes.len();
    let mut w = Vec::with_capacity(k);
    for m in 0..k {
        let mut lm = 1.0f64;
        for l in 0..k {
            if l == m {
                continue;
            }
            let denom = nodes[m] - nodes[l];
            assert!(
                denom != 0.0,
                "duplicate lagrange nodes at index {m}/{l}: t={}",
                nodes[m]
            );
            lm *= (t - nodes[l]) / denom;
        }
        w.push(lm);
    }
    w
}

/// Evaluate the interpolant `L_eps(t)` over tensor-valued samples
/// (Eq. 14). `values[m]` is the noise tensor observed at `nodes[m]`.
pub fn interpolate(nodes: &[f64], values: &[&Tensor], t: f64) -> Tensor {
    assert_eq!(nodes.len(), values.len(), "nodes/values length mismatch");
    Tensor::weighted_sum(values, &weights(nodes, t))
}

/// Scalar interpolation (used by tests and the selection diagnostics).
pub fn interpolate_scalar(nodes: &[f64], values: &[f64], t: f64) -> f64 {
    assert_eq!(nodes.len(), values.len());
    weights(nodes, t)
        .iter()
        .zip(values)
        .map(|(&w, &v)| w * v)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        // Interpolating the constant function 1 must be exact, i.e. the
        // basis is a partition of unity at every t.
        let nodes = [0.9, 0.6, 0.35, 0.1];
        for &t in &[0.05, 0.2, 0.5, 1.0, -0.3] {
            let s: f64 = weights(&nodes, t).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "t={t} sum={s}");
        }
    }

    #[test]
    fn weights_are_kronecker_at_nodes() {
        let nodes = [1.0, 0.7, 0.4, 0.2];
        for (m, &tm) in nodes.iter().enumerate() {
            let w = weights(&nodes, tm);
            for (l, &wl) in w.iter().enumerate() {
                let want = if l == m { 1.0 } else { 0.0 };
                assert!((wl - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn exact_on_polynomials_up_to_degree() {
        // k nodes reproduce polynomials of degree <= k-1 exactly.
        let nodes = [0.95, 0.7, 0.45, 0.15];
        let poly = |t: f64| 2.0 - 3.0 * t + 0.5 * t * t - 4.0 * t * t * t;
        let vals: Vec<f64> = nodes.iter().map(|&n| poly(n)).collect();
        for &t in &[0.05, 0.3, 0.6, 1.2] {
            let got = interpolate_scalar(&nodes, &vals, t);
            assert!((got - poly(t)).abs() < 1e-9, "t={t}: {got} vs {}", poly(t));
        }
    }

    #[test]
    fn tensor_interpolation_matches_scalar_per_element() {
        let nodes = [0.8, 0.5, 0.2];
        let a = Tensor::from_vec(vec![1.0, 2.0], 1, 2);
        let b = Tensor::from_vec(vec![0.0, -1.0], 1, 2);
        let c = Tensor::from_vec(vec![3.0, 0.5], 1, 2);
        let out = interpolate(&nodes, &[&a, &b, &c], 0.1);
        for j in 0..2 {
            let vals = [a.as_slice()[j] as f64, b.as_slice()[j] as f64, c.as_slice()[j] as f64];
            let want = interpolate_scalar(&nodes, &vals, 0.1);
            assert!((out.as_slice()[j] as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn single_node_is_constant() {
        let w = weights(&[0.4], 0.05);
        assert_eq!(w, vec![1.0]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_nodes_panic() {
        let _ = weights(&[0.5, 0.5], 0.1);
    }
}
