//! Ancestral DDPM sampling (Ho et al. 2020) — the stochastic baseline of
//! the paper's Tab. 3. One posterior-sampling transition per step:
//!
//! ```text
//!     alpha_i = alpha_bar(t_i) / alpha_bar(t_{i+1})        (t decreasing)
//!     mu      = (x - (1 - alpha_i)/sqrt(1 - ab(t_i)) eps) / sqrt(alpha_i)
//!     var     = (1 - ab(t_{i+1}))/(1 - ab(t_i)) (1 - alpha_i)
//!     x'      = mu + sqrt(var) z,  z ~ N(0, I)   (no noise on final step)
//! ```
//!
//! `alpha_bar` samples come from the shared [`TrajectoryPlan`]; the
//! posterior update runs in place and the ancestral noise fills a
//! preallocated scratch tensor, so steps are allocation-free.

use std::sync::Arc;

use crate::kernels::{fused, PlanView, TrajectoryPlan};
use crate::rng::Rng;
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

/// RNG stream id for the ancestral posterior noise. Per-request:
/// `Rng::for_stream(seed, ANCESTRAL_STREAM)` — shared with the lane
/// engine's stacked DDPM stepping so both paths replay the same
/// per-request noise sequence bit for bit.
pub const ANCESTRAL_STREAM: u64 = 0xD0;

pub struct Ddpm {
    plan: PlanView,
    x: Arc<Tensor>,
    i: usize,
    nfe: usize,
    pending: bool,
    rng: Rng,
    /// Ancestral-noise scratch, refilled in place each step.
    z: Tensor,
}

impl Ddpm {
    pub fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor, seed: u64) -> Self {
        assert!(grid.len() >= 2);
        Ddpm::with_plan(Arc::new(TrajectoryPlan::new(sched, grid)), x0, seed)
    }

    /// Build over a shared precomputed plan (the serving path).
    pub fn with_plan(plan: Arc<TrajectoryPlan>, x0: Tensor, seed: u64) -> Self {
        Ddpm::with_view(PlanView::full(plan), x0, seed)
    }

    /// Build over a (possibly suffix) window of a shared plan.
    pub fn with_view(plan: PlanView, x0: Tensor, seed: u64) -> Self {
        let z = Tensor::zeros(x0.rows(), x0.cols());
        Ddpm {
            plan,
            x: Arc::new(x0),
            i: 0,
            nfe: 0,
            pending: false,
            rng: Rng::for_stream(seed, ANCESTRAL_STREAM),
            z,
        }
    }
}

impl Solver for Ddpm {
    fn name(&self) -> String {
        "ddpm".into()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        self.pending = true;
        Some(EvalRequest { x: Arc::clone(&self.x), t: self.plan.t(self.i), cond: None })
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;

        let ab_cur = self.plan.alpha_bar_at(self.i);
        let ab_next = self.plan.alpha_bar_at(self.i + 1);
        let alpha = ab_cur / ab_next; // in (0, 1)

        // Posterior mean, in place.
        let coef = ((1.0 - alpha) / (1.0 - ab_cur).sqrt()) as f32;
        let inv_sqrt_alpha = (1.0 / alpha.sqrt()) as f32;
        let x = Arc::make_mut(&mut self.x);
        fused::axpy(x.as_mut_slice(), -coef, eps.as_slice());
        fused::scale(x.as_mut_slice(), inv_sqrt_alpha);

        // Posterior noise except on the last transition (the paper
        // withdraws the final-step denoising trick; deterministic output).
        let last = self.i + 2 == self.plan.grid().len();
        if !last {
            let var = (1.0 - ab_next) / (1.0 - ab_cur) * (1.0 - alpha);
            if var > 0.0 {
                self.rng.fill_normal(self.z.as_mut_slice());
                fused::axpy(x.as_mut_slice(), var.sqrt() as f32, self.z.as_slice());
            }
        }
        self.i += 1;
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.plan.grid().len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    #[test]
    fn runs_and_counts_nfe() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 20, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let mut s = Ddpm::new(sched, grid, rng.normal_tensor(64, 2), 1);
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        assert_eq!(s.nfe(), 20);
        assert!(out.all_finite());
    }

    #[test]
    fn many_steps_reach_ring() {
        // DDPM needs many steps (the paper's Tab. 3: terrible at low NFE,
        // decent at 100+); with the exact model 300 steps should do.
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 300, 1.0, 1e-3);
        let mut rng = Rng::new(2);
        let mut s = Ddpm::new(sched, grid, rng.normal_tensor(200, 2), 3);
        let m = AnalyticGmm::gmm8(sched);
        let out = sample_with(&mut s, &m);
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.6 {
                on_ring += 1;
            }
        }
        assert!(on_ring > 180, "{on_ring}/200");
    }

    #[test]
    fn stochastic_but_seed_deterministic() {
        let sched = VpSchedule::default();
        let m = AnalyticGmm::gmm8(sched);
        let run = |seed: u64| {
            let grid = make_grid(&sched, GridKind::Uniform, 10, 1.0, 1e-3);
            let mut rng = Rng::new(5);
            let mut s = Ddpm::new(sched, grid, rng.normal_tensor(8, 2), seed);
            sample_with(&mut s, &m)
        };
        let a = run(1);
        let b = run(1);
        let c = run(2);
        assert_eq!(a.as_slice(), b.as_slice());
        assert_ne!(a.as_slice(), c.as_slice());
    }
}
