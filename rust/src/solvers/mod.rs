//! Diffusion-ODE solvers: the paper's ERA-Solver plus every baseline the
//! evaluation section compares against.
//!
//! Solvers are *state machines* that alternate with the caller:
//! [`Solver::next_eval`] yields the next network evaluation the solver
//! needs; the caller (an in-process driver, or the serving coordinator,
//! which may batch evaluations across many concurrent requests) runs the
//! model and feeds the result back with [`Solver::on_eval`]. This pull
//! interface is what lets the L3 batcher mix requests sitting at
//! different timesteps into one PJRT call.
//!
//! Implemented solvers and their paper anchors:
//! * [`ddim`]      — DDIM, Eq. 8 (Song et al. 2020a)
//! * [`ddpm`]      — ancestral DDPM sampling (Ho et al. 2020)
//! * [`adams_explicit`] — PLMS/PNDM (pseudo linear multistep, Eq. 9) and
//!   FON (classic AB4 on the probability-flow ODE), both with
//!   pseudo-Runge–Kutta warmup (Liu et al. 2021)
//! * [`adams_implicit`] — the traditional implicit-Adams
//!   predictor–corrector (PECE), Eq. 10/11 with an explicit-Adams predictor
//! * [`dpm`]       — DPM-Solver-1/2/3 and DPM-Solver-fast (Lu et al. 2022a)
//! * [`era`]       — ERA-Solver, Alg. 1: Lagrange predictor (Eq. 13/14),
//!   error measure (Eq. 15), error-robust selection (Eq. 16/17),
//!   Adams–Moulton corrector (Eq. 11)

pub mod adams_explicit;
pub mod adams_implicit;
pub mod ddim;
pub mod ddpm;
pub mod dpm;
pub mod era;
pub mod eps_model;
pub mod guided;
pub mod lagrange;
pub mod lanes;
pub mod schedule;

use std::sync::Arc;

use crate::kernels::{PlanCache, PlanKey, PlanView, TrajectoryPlan};
use crate::tensor::Tensor;
pub use eps_model::{EpsModel, UNCOND};
pub use guided::Guided;
pub use schedule::{make_grid, GridKind, VpSchedule};

/// One pending network evaluation: run `eps_theta(x, t)` for every row.
///
/// `x` is a reference-counted view of the solver's iterate (or its
/// predicted evaluation point) — handing it out costs a refcount bump,
/// not a deep clone. Callers drop the request before `on_eval` so the
/// solver can update the buffer in place (a still-outstanding view is
/// safe but forces one copy-on-write).
///
/// `cond` is the optional per-row conditioning channel (class id per
/// row, [`UNCOND`] for unconditional rows). It is constant across a
/// trajectory, so guided solvers build it once and hand out refcounts;
/// the batcher threads it through fused slabs exactly like the per-row
/// times.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    pub x: Arc<Tensor>,
    /// Diffusion time shared by the whole tensor (one solver step).
    pub t: f64,
    /// Per-row conditioning channel; `None` = all rows unconditional.
    pub cond: Option<Arc<Vec<f32>>>,
}

/// A diffusion-ODE solver driving one batch of samples from noise to data.
///
/// Contract: call `next_eval`; if `Some`, evaluate and call `on_eval`
/// exactly once, then repeat. When `next_eval` returns `None` the sample
/// in [`Solver::current`] is final.
pub trait Solver: Send {
    /// Short name for tables/telemetry ("era", "ddim", ...).
    fn name(&self) -> String;

    /// The next evaluation this solver needs, or None when finished.
    fn next_eval(&mut self) -> Option<EvalRequest>;

    /// Feed the model output for the last `next_eval` request.
    fn on_eval(&mut self, eps: Tensor);

    /// Current iterate (the generated batch once finished).
    fn current(&self) -> &Tensor;

    /// True once the trajectory is complete.
    fn is_done(&self) -> bool;

    /// Network evaluations consumed so far.
    fn nfe(&self) -> usize;

    /// Latest error-robust error measure (Eq. 15), when this solver
    /// tracks one. `Some` only for ERA solvers (and wrappers around
    /// them); surfaced per request on the wire so clients can observe
    /// the error-robust selection working.
    fn delta_eps(&self) -> Option<f64> {
        None
    }
}

/// Drive a solver to completion against a model (in-process path used by
/// tests, examples and the benches; the serving path lives in
/// `coordinator`). Requests carrying a conditioning channel route
/// through [`EpsModel::eval_cond`]; plain requests keep the exact
/// pre-existing `eval` path.
pub fn sample_with(solver: &mut dyn Solver, model: &dyn EpsModel) -> Tensor {
    // One reusable time buffer for the whole trajectory instead of a
    // fresh `vec![t; rows]` per evaluation.
    let mut t_buf: Vec<f32> = Vec::new();
    while let Some(req) = solver.next_eval() {
        t_buf.clear();
        t_buf.resize(req.x.rows(), req.t as f32);
        let eps = match &req.cond {
            None => model.eval(&req.x, &t_buf),
            Some(c) => model.eval_cond(&req.x, &t_buf, c),
        };
        // Release the borrowed view before feeding the result back so
        // the solver's in-place update never pays copy-on-write.
        drop(req);
        solver.on_eval(eps);
    }
    solver.current().clone()
}

/// Per-request workload description, threaded from the wire protocol
/// through admission, the batcher and into the solver layer. The
/// default is the plain unconditional full trajectory, and every
/// default field is guaranteed not to change a request's numerics: the
/// golden tests pin `guidance_scale = 0` and `strength = 1.0` bitwise
/// against the pre-existing paths.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    /// Classifier-free guidance scale. `0` = unconditional (no paired
    /// rows, no extra evaluations); any other value evaluates paired
    /// cond/uncond rows each step and combines them as
    /// `uncond + scale * (cond - uncond)` ([`Guided`]).
    pub guidance_scale: f64,
    /// Class id the cond rows condition on (dataset-interpreted).
    pub guide_class: usize,
    /// img2img strength in `[0, 1]`. `1.0` = full trajectory from pure
    /// noise; smaller values enter the shared trajectory plan at an
    /// interior grid index (quantized to a transition — the "strength
    /// bucket") starting from `init` noised to that time; `0.0` runs no
    /// transitions and returns the re-noised init.
    pub strength: f64,
    /// Initial sample batch for img2img (required when the strength
    /// bucket is interior; shape must be `n_samples x dim`).
    pub init: Option<Tensor>,
    /// Stochastic-ERA churn level. `0` = deterministic; `> 0` injects
    /// ancestral-scale noise scaled by this factor after every interior
    /// transition, from a per-request RNG stream (stream-stable under
    /// batching and sharding). ERA solvers only.
    pub churn: f64,
}

impl Default for TaskSpec {
    fn default() -> Self {
        TaskSpec {
            guidance_scale: 0.0,
            guide_class: 0,
            strength: 1.0,
            init: None,
            churn: 0.0,
        }
    }
}

impl TaskSpec {
    /// True when this request evaluates paired cond/uncond rows.
    pub fn is_guided(&self) -> bool {
        self.guidance_scale != 0.0
    }

    /// True when the trajectory starts at an interior grid index.
    pub fn is_img2img(&self) -> bool {
        self.strength < 1.0
    }

    pub fn is_stochastic(&self) -> bool {
        self.churn > 0.0
    }

    /// Model-eval rows each requested sample costs per step — what
    /// admission control, the global row cap and the batcher see. A
    /// guided request is 2 rows per sample (cond + uncond).
    pub fn rows_per_sample(&self) -> usize {
        if self.is_guided() {
            2
        } else {
            1
        }
    }

    /// The "strength bucket": grid index a trajectory of `steps`
    /// transitions enters at. Continuous strengths quantize to the
    /// nearest transition; the mapping is injective over buckets
    /// (`strength = 1 - j/steps  <->  start = j`), `1.0` maps to 0
    /// (full trajectory) and `0.0` to `steps` (no transitions). Any
    /// strength `< 1` clamps to an *interior* start (>= 1) so an
    /// img2img request always consumes its init — a strength rounding
    /// to the full trajectory would otherwise silently ignore it.
    pub fn suffix_start(&self, steps: usize) -> usize {
        if self.strength >= 1.0 {
            return 0;
        }
        let start = ((1.0 - self.strength) * steps as f64).round() as usize;
        start.clamp(1, steps)
    }

    /// Cheap parameter validation (shape checks against the plan happen
    /// at build time in [`TaskSpec::start_state`]).
    pub fn validate(&self) -> Result<(), String> {
        if !self.guidance_scale.is_finite() || self.guidance_scale < 0.0 {
            return Err(format!("guidance_scale {} out of range", self.guidance_scale));
        }
        if !(0.0..=1.0).contains(&self.strength) {
            return Err(format!("strength {} out of [0, 1]", self.strength));
        }
        if !self.churn.is_finite() || self.churn < 0.0 {
            return Err(format!("churn {} out of range", self.churn));
        }
        Ok(())
    }

    /// Short label for stats/telemetry ("uncond", "guided@2", combos).
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if self.is_guided() {
            parts.push(format!("guided@{}", self.guidance_scale));
        }
        if self.is_img2img() {
            parts.push(format!("img2img@{}", self.strength));
        }
        if self.is_stochastic() {
            parts.push(format!("sde@{}", self.churn));
        }
        if parts.is_empty() {
            "uncond".into()
        } else {
            parts.join("+")
        }
    }

    /// Resolve the start grid index and start iterate for this task over
    /// `plan`, given the request's prior noise batch (the same noise the
    /// full trajectory would start from, so `strength = 1.0` is bitwise
    /// the pre-existing path). Interior starts forward-noise the init:
    /// `x = sqrt(alpha_bar(t_start)) * init + sigma(t_start) * noise`.
    pub fn start_state(
        &self,
        plan: &TrajectoryPlan,
        noise: Tensor,
    ) -> Result<(usize, Tensor), String> {
        let start = self.suffix_start(plan.steps());
        if start == 0 {
            return Ok((0, noise));
        }
        let init = self.init.as_ref().ok_or_else(|| {
            format!("strength {} needs an init batch (none provided)", self.strength)
        })?;
        if init.rows() != noise.rows() || init.cols() != noise.cols() {
            return Err(format!(
                "init shape {}x{} does not match request shape {}x{}",
                init.rows(),
                init.cols(),
                noise.rows(),
                noise.cols()
            ));
        }
        let t_start = plan.t(start);
        let sched = plan.sched();
        let a = sched.sqrt_alpha_bar(t_start) as f32;
        let b = sched.sigma(t_start) as f32;
        let mut x = Tensor::zeros(noise.rows(), noise.cols());
        crate::kernels::fused::affine_into(
            x.as_mut_slice(),
            a,
            init.as_slice(),
            b,
            noise.as_slice(),
        );
        Ok((start, x))
    }
}

/// Zero-transition solver: already done, `current` is the start state.
/// Backs the `strength = 0.0` img2img bucket (return the re-noised init
/// without consuming any evaluations).
struct Noop {
    x: Tensor,
}

impl Solver for Noop {
    fn name(&self) -> String {
        "noop".into()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        None
    }

    fn on_eval(&mut self, _eps: Tensor) {
        panic!("noop solver received an evaluation");
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        true
    }

    fn nfe(&self) -> usize {
        0
    }
}

/// Everything a [`TaskSpec`] resolves to before a solver (or lane) is
/// instantiated: the trajectory window, the start iterate, and the
/// workload wrappers to apply. Produced by [`SolverKind::resolve_task`]
/// and consumed by both the boxed-solver path and the lane engine.
pub struct TaskResolution {
    /// `None` = zero-transition request (`strength = 0`): `x` is final.
    pub view: Option<PlanView>,
    /// Start iterate (prior noise, or the init forward-noised to the
    /// suffix start time).
    pub x: Tensor,
    /// Stochastic-ERA churn level (0 = deterministic).
    pub churn: f64,
    /// Classifier-free guidance `(scale, class)` when requested.
    pub guided: Option<(f32, usize)>,
}

/// Which solver to build (the paper's comparison set).
#[derive(Clone, Debug, PartialEq)]
pub enum SolverKind {
    Ddpm,
    Ddim,
    /// PNDM pseudo linear multistep (PRK warmup + Eq. 9 combination).
    Pndm,
    /// Classic explicit Adams (AB4) on the probability-flow ODE (FON).
    Fon,
    /// Traditional implicit-Adams predictor–corrector (PECE).
    ImplicitAdams,
    /// DPM-Solver with fixed order 1, 2 or 3.
    Dpm { order: usize },
    /// DPM-Solver-fast order schedule for a given NFE budget.
    DpmFast,
    /// ERA-Solver (the paper's contribution).
    Era { k: usize, selection: era::Selection },
}

impl SolverKind {
    /// Parse CLI/protocol names: "era", "era-3", "era-fixed-5", "dpm-2",
    /// "dpm-fast", "pndm", "fon", "ddim", "ddpm", "iadams",
    /// "era-const-5@0.5", ...
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s {
            "ddpm" => return Some(SolverKind::Ddpm),
            "ddim" => return Some(SolverKind::Ddim),
            "pndm" => return Some(SolverKind::Pndm),
            "fon" => return Some(SolverKind::Fon),
            "iadams" => return Some(SolverKind::ImplicitAdams),
            "dpm-fast" => return Some(SolverKind::DpmFast),
            // Default lambda 0.3 — the paper's 5.0 rescaled to this
            // repo's delta_eps units (per-row mean norm instead of the
            // raw image-tensor L2 norm; see DESIGN.md §9).
            "era" => {
                return Some(SolverKind::Era {
                    k: 4,
                    selection: era::Selection::ErrorRobust { lambda: 0.3 },
                })
            }
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("dpm-") {
            let order: usize = rest.parse().ok()?;
            if (1..=3).contains(&order) {
                return Some(SolverKind::Dpm { order });
            }
            return None;
        }
        // All `era-*` variants: k = 0 would mean zero Lagrange basis
        // points and panics downstream in the predictor; reject at parse
        // so the error surfaces as an invalid request, not a dead loop
        // thread.
        if let Some(rest) = s.strip_prefix("era-fixed-") {
            let k: usize = rest.parse().ok()?;
            if k == 0 {
                return None;
            }
            return Some(SolverKind::Era { k, selection: era::Selection::FixedLast });
        }
        if let Some(rest) = s.strip_prefix("era-const-") {
            // era-const-<k>@<scale>
            let (k_str, c_str) = rest.split_once('@')?;
            let k: usize = k_str.parse().ok()?;
            if k == 0 {
                return None;
            }
            return Some(SolverKind::Era {
                k,
                selection: era::Selection::ConstantScale { scale: c_str.parse().ok()? },
            });
        }
        if let Some(rest) = s.strip_prefix("era-") {
            // era-<k> or era-<k>@<lambda>
            let (k_str, lam) = match rest.split_once('@') {
                Some((a, b)) => (a, b.parse().ok()?),
                None => (rest, 0.3),
            };
            let k: usize = k_str.parse().ok()?;
            if k == 0 {
                return None;
            }
            return Some(SolverKind::Era {
                k,
                selection: era::Selection::ErrorRobust { lambda: lam },
            });
        }
        None
    }

    pub fn label(&self) -> String {
        match self {
            SolverKind::Ddpm => "ddpm".into(),
            SolverKind::Ddim => "ddim".into(),
            SolverKind::Pndm => "pndm".into(),
            SolverKind::Fon => "fon".into(),
            SolverKind::ImplicitAdams => "iadams".into(),
            SolverKind::Dpm { order } => format!("dpm-{order}"),
            SolverKind::DpmFast => "dpm-fast".into(),
            SolverKind::Era { k, selection } => match selection {
                era::Selection::ErrorRobust { lambda } => format!("era-{k}@{lambda}"),
                era::Selection::FixedLast => format!("era-fixed-{k}"),
                era::Selection::ConstantScale { scale } => format!("era-const-{k}@{scale}"),
            },
        }
    }

    /// Minimum NFE budget this solver can run with.
    pub fn min_nfe(&self) -> usize {
        match self {
            // PRK warmup: 3 steps x 4 evals + at least 1 multistep step.
            SolverKind::Pndm | SolverKind::Fon => 13,
            SolverKind::Dpm { order } => *order,
            SolverKind::Era { k, .. } => (*k).max(3), // corrector wants history
            _ => 1,
        }
    }

    /// Enforce the [`SolverKind::min_nfe`] bound on a requested budget.
    /// Single validation point for the serving path
    /// (`coordinator/request.rs`) and the experiment sweep, so the
    /// per-request NFE floor cannot drift between the two.
    pub fn validate_nfe(&self, nfe: usize) -> Result<(), String> {
        if nfe < self.min_nfe() {
            return Err(format!(
                "nfe {} below minimum {} for solver '{}'",
                nfe,
                self.min_nfe(),
                self.label()
            ));
        }
        Ok(())
    }

    /// Effective early-stop floor for a request: the larger of the
    /// caller's `min_nfe` and this kind's structural minimum, never
    /// above the full budget. The convergence controller and QoS
    /// degradation both bottom out here.
    pub fn nfe_floor(&self, requested_min: usize, nfe: usize) -> usize {
        requested_min.max(self.min_nfe()).min(nfe)
    }

    /// Build a solver instance for one request.
    ///
    /// `x0` is the prior noise batch, `grid` the decreasing timestep
    /// sequence (sized via [`SolverKind::steps_for_nfe`]), `nfe_budget`
    /// the network-evaluation budget the grid was sized for (used by
    /// solvers whose step count != NFE, e.g. DPM-Solver-fast).
    ///
    /// Builds a private [`TrajectoryPlan`] for the grid; the serving
    /// path shares plans across requests via
    /// [`SolverKind::build_with_plan`] and a [`PlanCache`] instead.
    pub fn build(
        &self,
        sched: VpSchedule,
        grid: Vec<f64>,
        x0: Tensor,
        seed: u64,
        nfe_budget: usize,
    ) -> Box<dyn Solver> {
        let plan = Arc::new(self.make_plan(sched, grid, nfe_budget));
        self.build_with_plan(plan, x0, seed)
    }

    /// Precompute the trajectory plan for this solver kind over an
    /// explicit grid (schedule samples, DDIM/AM/DPM coefficients,
    /// Lagrange memo storage).
    pub fn make_plan(
        &self,
        sched: VpSchedule,
        grid: Vec<f64>,
        nfe_budget: usize,
    ) -> TrajectoryPlan {
        let base = TrajectoryPlan::new(sched, grid);
        match self {
            SolverKind::Dpm { order } => {
                // Spend the budget exactly (the last step may drop order).
                let orders = dpm::fixed_order_schedule(*order, nfe_budget);
                if orders.len() + 1 == base.grid().len() {
                    base.with_dpm_orders(&orders)
                } else {
                    let orders = vec![*order; base.steps()];
                    base.with_dpm_orders(&orders)
                }
            }
            SolverKind::DpmFast => {
                let orders = dpm::fast_order_schedule(nfe_budget);
                base.with_dpm_orders(&orders)
            }
            _ => base,
        }
    }

    /// Cache key for this kind's plan — everything
    /// [`SolverKind::make_plan`] depends on besides the grid values
    /// themselves (which `(grid kind, steps, t-range, schedule)`
    /// determine).
    pub fn plan_key(
        &self,
        sched: &VpSchedule,
        grid: GridKind,
        nfe: usize,
        t_start: f64,
        t_end: f64,
    ) -> PlanKey {
        PlanKey::new(self.label(), nfe, grid, sched, t_start, t_end)
    }

    /// Fetch-or-build this kind's plan from a shared cache.
    pub fn plan_from_cache(
        &self,
        cache: &PlanCache,
        sched: VpSchedule,
        grid_kind: GridKind,
        nfe: usize,
        t_start: f64,
        t_end: f64,
    ) -> Arc<TrajectoryPlan> {
        let key = self.plan_key(&sched, grid_kind, nfe, t_start, t_end);
        cache.get_or_build(key, || {
            let steps = self.steps_for_nfe(nfe);
            let grid = make_grid(&sched, grid_kind, steps, t_start, t_end);
            self.make_plan(sched, grid, nfe)
        })
    }

    /// Build a solver over a precomputed (typically cached and shared)
    /// plan. The plan must come from [`SolverKind::make_plan`] for the
    /// same kind — DPM kinds require their per-step coefficients.
    pub fn build_with_plan(
        &self,
        plan: Arc<TrajectoryPlan>,
        x0: Tensor,
        seed: u64,
    ) -> Box<dyn Solver> {
        self.build_with_view(PlanView::full(plan), x0, seed, 0.0)
    }

    /// Build over an explicit [`PlanView`] (full or suffix window into a
    /// shared plan). `churn > 0` selects the stochastic-ERA variant and
    /// is only meaningful for ERA kinds ([`SolverKind::build_task`]
    /// rejects it elsewhere before reaching here).
    pub fn build_with_view(
        &self,
        view: PlanView,
        x0: Tensor,
        seed: u64,
        churn: f64,
    ) -> Box<dyn Solver> {
        match self {
            SolverKind::Ddpm => Box::new(ddpm::Ddpm::with_view(view, x0, seed)),
            SolverKind::Ddim => Box::new(ddim::Ddim::with_view(view, x0)),
            SolverKind::Pndm => {
                Box::new(adams_explicit::ExplicitAdams::with_view_pndm(view, x0))
            }
            SolverKind::Fon => Box::new(adams_explicit::ExplicitAdams::with_view_fon(view, x0)),
            SolverKind::ImplicitAdams => {
                Box::new(adams_implicit::ImplicitAdamsPc::with_view(view, x0))
            }
            SolverKind::Dpm { order } => {
                Box::new(dpm::DpmSolver::with_view(view, x0, format!("dpm-{order}")))
            }
            SolverKind::DpmFast => Box::new(dpm::DpmSolver::with_view(view, x0, "dpm-fast".into())),
            SolverKind::Era { k, selection } => Box::new(era::EraSolver::with_view(
                view,
                x0,
                *k,
                selection.clone(),
                churn,
                seed,
            )),
        }
    }

    /// Minimum *visible* transitions a (suffix) trajectory needs for
    /// this kind to run — the img2img counterpart of
    /// [`SolverKind::min_nfe`].
    fn min_steps(&self) -> usize {
        match self {
            SolverKind::Pndm | SolverKind::Fon => 4,
            SolverKind::Era { k, .. } => (*k).max(3),
            _ => 1,
        }
    }

    /// Resolve a [`TaskSpec`] against a shared `plan` without building
    /// a solver: validate the workload, quantize the strength bucket
    /// into a (possibly suffix) [`PlanView`], noise the init into the
    /// start iterate, and report the wrappers to apply. Both
    /// [`SolverKind::build_task`] (the boxed per-request path) and the
    /// lane engine ([`lanes::LaneEngine`]) admit through this one
    /// resolution, so their validation and start states can never
    /// drift apart.
    pub fn resolve_task(
        &self,
        plan: Arc<TrajectoryPlan>,
        x0_noise: Tensor,
        task: &TaskSpec,
    ) -> Result<TaskResolution, String> {
        task.validate()?;
        if task.is_stochastic() && !matches!(self, SolverKind::Era { .. }) {
            return Err(format!(
                "churn {} requires an era solver, got '{}'",
                task.churn,
                self.label()
            ));
        }
        let (start, x_start) = task.start_state(&plan, x0_noise)?;
        let steps = plan.steps();
        let view = if start == steps {
            // Zero-transition bucket: the start iterate is final.
            None
        } else {
            let remaining = steps - start;
            if remaining < self.min_steps() {
                return Err(format!(
                    "strength {} leaves {remaining} transitions, below minimum {} for '{}'",
                    task.strength,
                    self.min_steps(),
                    self.label()
                ));
            }
            Some(if start == 0 {
                PlanView::full(plan)
            } else {
                PlanView::suffix(plan, start)
            })
        };
        let guided = if task.is_guided() {
            Some((task.guidance_scale as f32, task.guide_class))
        } else {
            None
        };
        Ok(TaskResolution { view, x: x_start, churn: task.churn, guided })
    }

    /// Build the full workload-aware solver stack for one request:
    /// resolve the task's strength bucket into a suffix [`PlanView`] of
    /// the shared `plan` (noising `task.init` to the start time),
    /// instantiate this kind over it (stochastic churn for ERA), and
    /// wrap with classifier-free guidance when requested. `x0_noise` is
    /// the request's prior noise batch; with a default task this is
    /// behaviourally identical to [`SolverKind::build_with_plan`].
    pub fn build_task(
        &self,
        plan: Arc<TrajectoryPlan>,
        x0_noise: Tensor,
        seed: u64,
        task: &TaskSpec,
    ) -> Result<Box<dyn Solver>, String> {
        let res = self.resolve_task(plan, x0_noise, task)?;
        let inner: Box<dyn Solver> = match res.view {
            None => Box::new(Noop { x: res.x }),
            Some(view) => self.build_with_view(view, res.x, seed, res.churn),
        };
        match res.guided {
            Some((scale, class)) => Ok(Box::new(Guided::new(inner, scale, class))),
            None => Ok(inner),
        }
    }

    /// Number of grid transitions to request so the solver consumes
    /// (close to) `nfe` network evaluations — the paper compares solvers
    /// at equal NFE, not equal step count.
    pub fn steps_for_nfe(&self, nfe: usize) -> usize {
        match self {
            SolverKind::Ddpm
            | SolverKind::Ddim
            | SolverKind::ImplicitAdams
            | SolverKind::Era { .. } => nfe,
            // PRK warmup: first 3 steps cost 4 NFE each.
            SolverKind::Pndm | SolverKind::Fon => nfe.saturating_sub(9).max(4),
            SolverKind::Dpm { order: 1 } => nfe,
            SolverKind::Dpm { order: 2 } => nfe.div_ceil(2),
            SolverKind::Dpm { order: 3 } => nfe.div_ceil(3),
            SolverKind::Dpm { .. } => nfe,
            // dpm-fast sizes its own order schedule from the grid length;
            // grid steps == number of solver steps K below.
            SolverKind::DpmFast => dpm::fast_order_schedule(nfe).len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [
            "ddpm", "ddim", "pndm", "fon", "iadams", "dpm-1", "dpm-2", "dpm-3", "dpm-fast",
            "era", "era-3", "era-5@15", "era-fixed-4", "era-const-3@0.5",
        ] {
            let k = SolverKind::parse(s).unwrap_or_else(|| panic!("parse {s}"));
            // label -> parse -> label must be stable
            let l1 = k.label();
            let k2 = SolverKind::parse(&l1).unwrap_or_else(|| panic!("reparse {l1}"));
            assert_eq!(k2.label(), l1);
        }
        assert!(SolverKind::parse("dpm-4").is_none());
        assert!(SolverKind::parse("dpm-0").is_none());
        assert!(SolverKind::parse("wat").is_none());
        assert!(SolverKind::parse("era-x").is_none());
        // k = 0 means zero Lagrange bases — must be rejected for every
        // era variant, not panic downstream.
        assert!(SolverKind::parse("era-0").is_none());
        assert!(SolverKind::parse("era-0@0.3").is_none());
        assert!(SolverKind::parse("era-fixed-0").is_none());
        assert!(SolverKind::parse("era-const-0@0.5").is_none());
        // Malformed suffixes stay rejected.
        assert!(SolverKind::parse("era-fixed-").is_none());
        assert!(SolverKind::parse("era-const-3").is_none());
        assert!(SolverKind::parse("era-const-3@").is_none());
        assert!(SolverKind::parse("era-3@").is_none());
        assert!(SolverKind::parse("era-").is_none());
    }

    #[test]
    fn steps_for_nfe_accounting() {
        assert_eq!(SolverKind::Ddim.steps_for_nfe(10), 10);
        assert_eq!(SolverKind::Pndm.steps_for_nfe(15), 6); // 12 warmup + 3 plms... 15-9
        assert_eq!(SolverKind::Dpm { order: 2 }.steps_for_nfe(10), 5);
        assert_eq!(SolverKind::Dpm { order: 3 }.steps_for_nfe(10), 4);
    }

    #[test]
    fn task_spec_defaults_and_buckets() {
        let d = TaskSpec::default();
        assert!(!d.is_guided() && !d.is_img2img() && !d.is_stochastic());
        assert_eq!(d.rows_per_sample(), 1);
        assert_eq!(d.suffix_start(10), 0);
        assert_eq!(d.label(), "uncond");
        // Buckets: strength 1 - j/steps -> start j, injective, clamped.
        for steps in [4usize, 10, 17] {
            for j in 0..=steps {
                let t = TaskSpec {
                    strength: 1.0 - j as f64 / steps as f64,
                    ..Default::default()
                };
                assert_eq!(t.suffix_start(steps), j, "steps {steps} bucket {j}");
            }
        }
        let g = TaskSpec { guidance_scale: 2.0, ..Default::default() };
        assert_eq!(g.rows_per_sample(), 2);
        assert!(g.label().contains("guided@2"));
    }

    #[test]
    fn task_spec_validation_and_build_rejections() {
        assert!(TaskSpec { guidance_scale: -1.0, ..Default::default() }.validate().is_err());
        assert!(TaskSpec { strength: 1.5, ..Default::default() }.validate().is_err());
        assert!(TaskSpec { strength: -0.1, ..Default::default() }.validate().is_err());
        assert!(TaskSpec { churn: f64::NAN, ..Default::default() }.validate().is_err());

        let sched = VpSchedule::default();
        let kind = SolverKind::Ddim;
        let grid = make_grid(&sched, GridKind::Uniform, 10, 1.0, 1e-3);
        let plan = Arc::new(kind.make_plan(sched, grid, 10));
        let noise = Tensor::zeros(4, 2);
        // Churn on a non-ERA solver is rejected.
        let churn = TaskSpec { churn: 0.5, ..Default::default() };
        assert!(kind.build_task(plan.clone(), noise.clone(), 0, &churn).is_err());
        // Interior strength without an init is rejected.
        let no_init = TaskSpec { strength: 0.5, ..Default::default() };
        assert!(kind.build_task(plan.clone(), noise.clone(), 0, &no_init).is_err());
        // Mismatched init shape is rejected.
        let bad_init = TaskSpec {
            strength: 0.5,
            init: Some(Tensor::zeros(3, 2)),
            ..Default::default()
        };
        assert!(kind.build_task(plan.clone(), noise.clone(), 0, &bad_init).is_err());
        // A suffix too short for the solver order is rejected, not a panic.
        let era = SolverKind::parse("era").unwrap();
        let era_plan = Arc::new(era.make_plan(
            sched,
            make_grid(&sched, GridKind::Uniform, 10, 1.0, 1e-3),
            10,
        ));
        let tight = TaskSpec {
            strength: 0.2,
            init: Some(Tensor::zeros(4, 2)),
            ..Default::default()
        };
        assert!(era.build_task(era_plan, noise, 0, &tight).is_err());
    }

    #[test]
    fn task_strength_zero_returns_renoised_init() {
        // strength 0 runs no transitions: the result is the init noised
        // to t_end, which at t_end ~ 1e-3 is the init to ~1e-2.
        let sched = VpSchedule::default();
        let kind = SolverKind::Ddim;
        let grid = make_grid(&sched, GridKind::Uniform, 8, 1.0, 1e-3);
        let plan = Arc::new(kind.make_plan(sched, grid, 8));
        let init = Tensor::from_vec(vec![2.0, 0.0, 0.0, -2.0], 2, 2);
        let task = TaskSpec { strength: 0.0, init: Some(init.clone()), ..Default::default() };
        let mut rng = crate::rng::Rng::new(3);
        let noise = rng.normal_tensor(2, 2);
        let solver = kind.build_task(plan, noise, 3, &task).unwrap();
        assert!(solver.is_done());
        assert_eq!(solver.nfe(), 0);
        for (got, want) in solver.current().as_slice().iter().zip(init.as_slice()) {
            assert!((got - want).abs() < 0.05, "{got} vs {want}");
        }
    }
}
