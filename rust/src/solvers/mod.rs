//! Diffusion-ODE solvers: the paper's ERA-Solver plus every baseline the
//! evaluation section compares against.
//!
//! Solvers are *state machines* that alternate with the caller:
//! [`Solver::next_eval`] yields the next network evaluation the solver
//! needs; the caller (an in-process driver, or the serving coordinator,
//! which may batch evaluations across many concurrent requests) runs the
//! model and feeds the result back with [`Solver::on_eval`]. This pull
//! interface is what lets the L3 batcher mix requests sitting at
//! different timesteps into one PJRT call.
//!
//! Implemented solvers and their paper anchors:
//! * [`ddim`]      — DDIM, Eq. 8 (Song et al. 2020a)
//! * [`ddpm`]      — ancestral DDPM sampling (Ho et al. 2020)
//! * [`adams_explicit`] — PLMS/PNDM (pseudo linear multistep, Eq. 9) and
//!   FON (classic AB4 on the probability-flow ODE), both with
//!   pseudo-Runge–Kutta warmup (Liu et al. 2021)
//! * [`adams_implicit`] — the traditional implicit-Adams
//!   predictor–corrector (PECE), Eq. 10/11 with an explicit-Adams predictor
//! * [`dpm`]       — DPM-Solver-1/2/3 and DPM-Solver-fast (Lu et al. 2022a)
//! * [`era`]       — ERA-Solver, Alg. 1: Lagrange predictor (Eq. 13/14),
//!   error measure (Eq. 15), error-robust selection (Eq. 16/17),
//!   Adams–Moulton corrector (Eq. 11)

pub mod adams_explicit;
pub mod adams_implicit;
pub mod ddim;
pub mod ddpm;
pub mod dpm;
pub mod era;
pub mod eps_model;
pub mod lagrange;
pub mod schedule;

use std::sync::Arc;

use crate::kernels::{PlanCache, PlanKey, TrajectoryPlan};
use crate::tensor::Tensor;
pub use eps_model::EpsModel;
pub use schedule::{make_grid, GridKind, VpSchedule};

/// One pending network evaluation: run `eps_theta(x, t)` for every row.
///
/// `x` is a reference-counted view of the solver's iterate (or its
/// predicted evaluation point) — handing it out costs a refcount bump,
/// not a deep clone. Callers drop the request before `on_eval` so the
/// solver can update the buffer in place (a still-outstanding view is
/// safe but forces one copy-on-write).
#[derive(Clone, Debug)]
pub struct EvalRequest {
    pub x: Arc<Tensor>,
    /// Diffusion time shared by the whole tensor (one solver step).
    pub t: f64,
}

/// A diffusion-ODE solver driving one batch of samples from noise to data.
///
/// Contract: call `next_eval`; if `Some`, evaluate and call `on_eval`
/// exactly once, then repeat. When `next_eval` returns `None` the sample
/// in [`Solver::current`] is final.
pub trait Solver: Send {
    /// Short name for tables/telemetry ("era", "ddim", ...).
    fn name(&self) -> String;

    /// The next evaluation this solver needs, or None when finished.
    fn next_eval(&mut self) -> Option<EvalRequest>;

    /// Feed the model output for the last `next_eval` request.
    fn on_eval(&mut self, eps: Tensor);

    /// Current iterate (the generated batch once finished).
    fn current(&self) -> &Tensor;

    /// True once the trajectory is complete.
    fn is_done(&self) -> bool;

    /// Network evaluations consumed so far.
    fn nfe(&self) -> usize;
}

/// Drive a solver to completion against a model (in-process path used by
/// tests, examples and the benches; the serving path lives in
/// `coordinator`).
pub fn sample_with(solver: &mut dyn Solver, model: &dyn EpsModel) -> Tensor {
    // One reusable time buffer for the whole trajectory instead of a
    // fresh `vec![t; rows]` per evaluation.
    let mut t_buf: Vec<f32> = Vec::new();
    while let Some(req) = solver.next_eval() {
        t_buf.clear();
        t_buf.resize(req.x.rows(), req.t as f32);
        let eps = model.eval(&req.x, &t_buf);
        // Release the borrowed view before feeding the result back so
        // the solver's in-place update never pays copy-on-write.
        drop(req);
        solver.on_eval(eps);
    }
    solver.current().clone()
}

/// Which solver to build (the paper's comparison set).
#[derive(Clone, Debug, PartialEq)]
pub enum SolverKind {
    Ddpm,
    Ddim,
    /// PNDM pseudo linear multistep (PRK warmup + Eq. 9 combination).
    Pndm,
    /// Classic explicit Adams (AB4) on the probability-flow ODE (FON).
    Fon,
    /// Traditional implicit-Adams predictor–corrector (PECE).
    ImplicitAdams,
    /// DPM-Solver with fixed order 1, 2 or 3.
    Dpm { order: usize },
    /// DPM-Solver-fast order schedule for a given NFE budget.
    DpmFast,
    /// ERA-Solver (the paper's contribution).
    Era { k: usize, selection: era::Selection },
}

impl SolverKind {
    /// Parse CLI/protocol names: "era", "era-3", "era-fixed-5", "dpm-2",
    /// "dpm-fast", "pndm", "fon", "ddim", "ddpm", "iadams",
    /// "era-const-5@0.5", ...
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s {
            "ddpm" => return Some(SolverKind::Ddpm),
            "ddim" => return Some(SolverKind::Ddim),
            "pndm" => return Some(SolverKind::Pndm),
            "fon" => return Some(SolverKind::Fon),
            "iadams" => return Some(SolverKind::ImplicitAdams),
            "dpm-fast" => return Some(SolverKind::DpmFast),
            // Default lambda 0.3 — the paper's 5.0 rescaled to this
            // repo's delta_eps units (per-row mean norm instead of the
            // raw image-tensor L2 norm; see DESIGN.md §8).
            "era" => {
                return Some(SolverKind::Era {
                    k: 4,
                    selection: era::Selection::ErrorRobust { lambda: 0.3 },
                })
            }
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("dpm-") {
            let order: usize = rest.parse().ok()?;
            if (1..=3).contains(&order) {
                return Some(SolverKind::Dpm { order });
            }
            return None;
        }
        // All `era-*` variants: k = 0 would mean zero Lagrange basis
        // points and panics downstream in the predictor; reject at parse
        // so the error surfaces as an invalid request, not a dead loop
        // thread.
        if let Some(rest) = s.strip_prefix("era-fixed-") {
            let k: usize = rest.parse().ok()?;
            if k == 0 {
                return None;
            }
            return Some(SolverKind::Era { k, selection: era::Selection::FixedLast });
        }
        if let Some(rest) = s.strip_prefix("era-const-") {
            // era-const-<k>@<scale>
            let (k_str, c_str) = rest.split_once('@')?;
            let k: usize = k_str.parse().ok()?;
            if k == 0 {
                return None;
            }
            return Some(SolverKind::Era {
                k,
                selection: era::Selection::ConstantScale { scale: c_str.parse().ok()? },
            });
        }
        if let Some(rest) = s.strip_prefix("era-") {
            // era-<k> or era-<k>@<lambda>
            let (k_str, lam) = match rest.split_once('@') {
                Some((a, b)) => (a, b.parse().ok()?),
                None => (rest, 0.3),
            };
            let k: usize = k_str.parse().ok()?;
            if k == 0 {
                return None;
            }
            return Some(SolverKind::Era {
                k,
                selection: era::Selection::ErrorRobust { lambda: lam },
            });
        }
        None
    }

    pub fn label(&self) -> String {
        match self {
            SolverKind::Ddpm => "ddpm".into(),
            SolverKind::Ddim => "ddim".into(),
            SolverKind::Pndm => "pndm".into(),
            SolverKind::Fon => "fon".into(),
            SolverKind::ImplicitAdams => "iadams".into(),
            SolverKind::Dpm { order } => format!("dpm-{order}"),
            SolverKind::DpmFast => "dpm-fast".into(),
            SolverKind::Era { k, selection } => match selection {
                era::Selection::ErrorRobust { lambda } => format!("era-{k}@{lambda}"),
                era::Selection::FixedLast => format!("era-fixed-{k}"),
                era::Selection::ConstantScale { scale } => format!("era-const-{k}@{scale}"),
            },
        }
    }

    /// Minimum NFE budget this solver can run with.
    pub fn min_nfe(&self) -> usize {
        match self {
            // PRK warmup: 3 steps x 4 evals + at least 1 multistep step.
            SolverKind::Pndm | SolverKind::Fon => 13,
            SolverKind::Dpm { order } => *order,
            SolverKind::Era { k, .. } => (*k).max(3), // corrector wants history
            _ => 1,
        }
    }

    /// Build a solver instance for one request.
    ///
    /// `x0` is the prior noise batch, `grid` the decreasing timestep
    /// sequence (sized via [`SolverKind::steps_for_nfe`]), `nfe_budget`
    /// the network-evaluation budget the grid was sized for (used by
    /// solvers whose step count != NFE, e.g. DPM-Solver-fast).
    ///
    /// Builds a private [`TrajectoryPlan`] for the grid; the serving
    /// path shares plans across requests via
    /// [`SolverKind::build_with_plan`] and a [`PlanCache`] instead.
    pub fn build(
        &self,
        sched: VpSchedule,
        grid: Vec<f64>,
        x0: Tensor,
        seed: u64,
        nfe_budget: usize,
    ) -> Box<dyn Solver> {
        let plan = Arc::new(self.make_plan(sched, grid, nfe_budget));
        self.build_with_plan(plan, x0, seed)
    }

    /// Precompute the trajectory plan for this solver kind over an
    /// explicit grid (schedule samples, DDIM/AM/DPM coefficients,
    /// Lagrange memo storage).
    pub fn make_plan(
        &self,
        sched: VpSchedule,
        grid: Vec<f64>,
        nfe_budget: usize,
    ) -> TrajectoryPlan {
        let base = TrajectoryPlan::new(sched, grid);
        match self {
            SolverKind::Dpm { order } => {
                // Spend the budget exactly (the last step may drop order).
                let orders = dpm::fixed_order_schedule(*order, nfe_budget);
                if orders.len() + 1 == base.grid().len() {
                    base.with_dpm_orders(&orders)
                } else {
                    let orders = vec![*order; base.steps()];
                    base.with_dpm_orders(&orders)
                }
            }
            SolverKind::DpmFast => {
                let orders = dpm::fast_order_schedule(nfe_budget);
                base.with_dpm_orders(&orders)
            }
            _ => base,
        }
    }

    /// Cache key for this kind's plan — everything
    /// [`SolverKind::make_plan`] depends on besides the grid values
    /// themselves (which `(grid kind, steps, t-range, schedule)`
    /// determine).
    pub fn plan_key(
        &self,
        sched: &VpSchedule,
        grid: GridKind,
        nfe: usize,
        t_start: f64,
        t_end: f64,
    ) -> PlanKey {
        PlanKey::new(self.label(), nfe, grid, sched, t_start, t_end)
    }

    /// Fetch-or-build this kind's plan from a shared cache.
    pub fn plan_from_cache(
        &self,
        cache: &PlanCache,
        sched: VpSchedule,
        grid_kind: GridKind,
        nfe: usize,
        t_start: f64,
        t_end: f64,
    ) -> Arc<TrajectoryPlan> {
        let key = self.plan_key(&sched, grid_kind, nfe, t_start, t_end);
        cache.get_or_build(key, || {
            let steps = self.steps_for_nfe(nfe);
            let grid = make_grid(&sched, grid_kind, steps, t_start, t_end);
            self.make_plan(sched, grid, nfe)
        })
    }

    /// Build a solver over a precomputed (typically cached and shared)
    /// plan. The plan must come from [`SolverKind::make_plan`] for the
    /// same kind — DPM kinds require their per-step coefficients.
    pub fn build_with_plan(
        &self,
        plan: Arc<TrajectoryPlan>,
        x0: Tensor,
        seed: u64,
    ) -> Box<dyn Solver> {
        match self {
            SolverKind::Ddpm => Box::new(ddpm::Ddpm::with_plan(plan, x0, seed)),
            SolverKind::Ddim => Box::new(ddim::Ddim::with_plan(plan, x0)),
            SolverKind::Pndm => {
                Box::new(adams_explicit::ExplicitAdams::with_plan_pndm(plan, x0))
            }
            SolverKind::Fon => Box::new(adams_explicit::ExplicitAdams::with_plan_fon(plan, x0)),
            SolverKind::ImplicitAdams => {
                Box::new(adams_implicit::ImplicitAdamsPc::with_plan(plan, x0))
            }
            SolverKind::Dpm { order } => {
                Box::new(dpm::DpmSolver::with_plan(plan, x0, format!("dpm-{order}")))
            }
            SolverKind::DpmFast => Box::new(dpm::DpmSolver::with_plan(plan, x0, "dpm-fast".into())),
            SolverKind::Era { k, selection } => {
                Box::new(era::EraSolver::with_plan(plan, x0, *k, selection.clone()))
            }
        }
    }

    /// Number of grid transitions to request so the solver consumes
    /// (close to) `nfe` network evaluations — the paper compares solvers
    /// at equal NFE, not equal step count.
    pub fn steps_for_nfe(&self, nfe: usize) -> usize {
        match self {
            SolverKind::Ddpm
            | SolverKind::Ddim
            | SolverKind::ImplicitAdams
            | SolverKind::Era { .. } => nfe,
            // PRK warmup: first 3 steps cost 4 NFE each.
            SolverKind::Pndm | SolverKind::Fon => nfe.saturating_sub(9).max(4),
            SolverKind::Dpm { order: 1 } => nfe,
            SolverKind::Dpm { order: 2 } => nfe.div_ceil(2),
            SolverKind::Dpm { order: 3 } => nfe.div_ceil(3),
            SolverKind::Dpm { .. } => nfe,
            // dpm-fast sizes its own order schedule from the grid length;
            // grid steps == number of solver steps K below.
            SolverKind::DpmFast => dpm::fast_order_schedule(nfe).len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [
            "ddpm", "ddim", "pndm", "fon", "iadams", "dpm-1", "dpm-2", "dpm-3", "dpm-fast",
            "era", "era-3", "era-5@15", "era-fixed-4", "era-const-3@0.5",
        ] {
            let k = SolverKind::parse(s).unwrap_or_else(|| panic!("parse {s}"));
            // label -> parse -> label must be stable
            let l1 = k.label();
            let k2 = SolverKind::parse(&l1).unwrap_or_else(|| panic!("reparse {l1}"));
            assert_eq!(k2.label(), l1);
        }
        assert!(SolverKind::parse("dpm-4").is_none());
        assert!(SolverKind::parse("dpm-0").is_none());
        assert!(SolverKind::parse("wat").is_none());
        assert!(SolverKind::parse("era-x").is_none());
        // k = 0 means zero Lagrange bases — must be rejected for every
        // era variant, not panic downstream.
        assert!(SolverKind::parse("era-0").is_none());
        assert!(SolverKind::parse("era-0@0.3").is_none());
        assert!(SolverKind::parse("era-fixed-0").is_none());
        assert!(SolverKind::parse("era-const-0@0.5").is_none());
        // Malformed suffixes stay rejected.
        assert!(SolverKind::parse("era-fixed-").is_none());
        assert!(SolverKind::parse("era-const-3").is_none());
        assert!(SolverKind::parse("era-const-3@").is_none());
        assert!(SolverKind::parse("era-3@").is_none());
        assert!(SolverKind::parse("era-").is_none());
    }

    #[test]
    fn steps_for_nfe_accounting() {
        assert_eq!(SolverKind::Ddim.steps_for_nfe(10), 10);
        assert_eq!(SolverKind::Pndm.steps_for_nfe(15), 6); // 12 warmup + 3 plms... 15-9
        assert_eq!(SolverKind::Dpm { order: 2 }.steps_for_nfe(10), 5);
        assert_eq!(SolverKind::Dpm { order: 3 }.steps_for_nfe(10), 4);
    }
}
