//! DDIM (Song et al. 2020a), the deterministic baseline and Eq. 8 of the
//! paper: every other solver in this crate reuses its transition
//!
//! ```text
//!     x_{i+1} = a_i x_i + b_i eps,   a_i = sab(t_{i+1})/sab(t_i),
//!                                    b_i = sigma(t_{i+1}) - a_i sigma(t_i)
//!
//! ```
//! with its own choice of `eps`. The `(a_i, b_i)` pairs come precomputed
//! from the [`TrajectoryPlan`]; the transition runs in place through the
//! kernel layer, so a step is one fused pass and zero allocations.

use std::sync::Arc;

use crate::kernels::{fused, PlanView, TrajectoryPlan};
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EvalRequest, Solver};
use crate::tensor::Tensor;

pub struct Ddim {
    plan: PlanView,
    x: Arc<Tensor>,
    /// Index of the *next transition* (x at grid[i] currently).
    i: usize,
    nfe: usize,
    pending: bool,
}

impl Ddim {
    pub fn new(sched: VpSchedule, grid: Vec<f64>, x0: Tensor) -> Self {
        assert!(grid.len() >= 2, "grid needs at least one transition");
        Ddim::with_plan(Arc::new(TrajectoryPlan::new(sched, grid)), x0)
    }

    /// Build over a shared precomputed plan (the serving path).
    pub fn with_plan(plan: Arc<TrajectoryPlan>, x0: Tensor) -> Self {
        Ddim::with_view(PlanView::full(plan), x0)
    }

    /// Build over a (possibly suffix) window of a shared plan — the
    /// img2img path enters the trajectory at an interior grid index.
    pub fn with_view(plan: PlanView, x0: Tensor) -> Self {
        Ddim { plan, x: Arc::new(x0), i: 0, nfe: 0, pending: false }
    }
}

impl Solver for Ddim {
    fn name(&self) -> String {
        "ddim".into()
    }

    fn next_eval(&mut self) -> Option<EvalRequest> {
        if self.is_done() {
            return None;
        }
        assert!(!self.pending, "next_eval called with an eval outstanding");
        self.pending = true;
        Some(EvalRequest { x: Arc::clone(&self.x), t: self.plan.t(self.i), cond: None })
    }

    fn on_eval(&mut self, eps: Tensor) {
        assert!(self.pending, "on_eval without a pending request");
        self.pending = false;
        self.nfe += 1;
        let (a, b) = self.plan.ddim_coeffs(self.i);
        let x = Arc::make_mut(&mut self.x);
        debug_assert_eq!(x.len(), eps.len());
        fused::affine_inplace(x.as_mut_slice(), a as f32, b as f32, eps.as_slice());
        self.i += 1;
    }

    fn current(&self) -> &Tensor {
        &self.x
    }

    fn is_done(&self) -> bool {
        self.i + 1 >= self.plan.grid().len()
    }

    fn nfe(&self) -> usize {
        self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::solvers::eps_model::{AnalyticGmm, CountingEps, EpsModel};
    use crate::solvers::sample_with;
    use crate::solvers::schedule::{make_grid, GridKind};

    fn setup(n_steps: usize, batch: usize) -> (Ddim, CountingEps<AnalyticGmm>) {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, n_steps, 1.0, 1e-3);
        let mut rng = Rng::new(0);
        let x0 = rng.normal_tensor(batch, 2);
        (Ddim::new(sched, grid, x0), CountingEps::new(AnalyticGmm::gmm8(sched)))
    }

    #[test]
    fn nfe_equals_steps() {
        let (mut s, m) = setup(10, 32);
        let _ = sample_with(&mut s, &m);
        assert_eq!(s.nfe(), 10);
        assert_eq!(m.calls(), 10);
        assert!(s.is_done());
        assert!(s.next_eval().is_none());
    }

    #[test]
    fn converges_to_modes_with_exact_model() {
        // With the exact eps, 100 DDIM steps must land essentially every
        // sample on the gmm8 ring.
        let (mut s, m) = setup(100, 256);
        let out = sample_with(&mut s, &m);
        assert!(out.all_finite());
        let mut on_ring = 0;
        for r in 0..out.rows() {
            let row = out.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.5 {
                on_ring += 1;
            }
        }
        assert!(on_ring as f64 / 256.0 > 0.95, "{on_ring}/256 on ring");
    }

    #[test]
    fn more_steps_better_fid() {
        let sched = VpSchedule::default();
        let model = AnalyticGmm::gmm8(sched);
        let reference = crate::metrics::Moments::new(
            vec![0.0, 0.0],
            vec![2.0225, 0.0, 0.0, 2.0225],
        );
        let mut fids = Vec::new();
        for n in [5usize, 20, 80] {
            let grid = make_grid(&sched, GridKind::Uniform, n, 1.0, 1e-3);
            let mut rng = Rng::new(1);
            let x0 = rng.normal_tensor(2000, 2);
            let mut s = Ddim::new(sched, grid, x0);
            let out = sample_with(&mut s, &model);
            fids.push(crate::metrics::fid(&out, &reference));
        }
        assert!(fids[2] < fids[0], "fid must improve with steps: {fids:?}");
    }

    #[test]
    fn outstanding_view_forces_copy_not_corruption() {
        // Holding the EvalRequest across on_eval is legal: the solver
        // copies on write and the held view keeps its pre-step contents.
        let (mut s, m) = setup(5, 4);
        let req = s.next_eval().unwrap();
        let before = req.x.as_slice().to_vec();
        let t = vec![req.t as f32; 4];
        let eps = m.eval(&req.x, &t);
        s.on_eval(eps); // req still alive here
        assert_eq!(req.x.as_slice(), before.as_slice(), "held view mutated");
        assert_ne!(s.current().as_slice(), before.as_slice(), "step had no effect");
    }

    #[test]
    #[should_panic(expected = "outstanding")]
    fn double_next_eval_panics() {
        let (mut s, _) = setup(5, 2);
        let _ = s.next_eval();
        let _ = s.next_eval();
    }

    #[test]
    #[should_panic(expected = "pending")]
    fn on_eval_without_request_panics() {
        let (mut s, _) = setup(5, 2);
        s.on_eval(Tensor::zeros(2, 2));
    }
}
