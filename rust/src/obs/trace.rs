//! Request-lifecycle flight recorder.
//!
//! One [`FlightRecorder`] per shard. The trace id of a request is its
//! shard-local request id (the `Envelope`/`Active` id the coordinator
//! already assigns); the pool maps client tags to `(shard, id)` so a
//! request is addressable end to end. Events are fixed-size `Copy`
//! values written into a preallocated ring under a mutex — recording
//! performs **zero heap allocations**, so the scheduler can record from
//! inside the zero-alloc-gated stepping path. The ring keeps the newest
//! `capacity` events; readers get a request's events oldest→newest.

use std::sync::Mutex;
use std::time::Instant;

use crate::json::Json;

/// Inline storage for ERA's selected Lagrange basis indices. The paper
/// uses k ≤ 5; the solver parser accepts a little more headroom.
pub const MAX_BASES: usize = 8;

/// One typed span event in a request's lifecycle. Everything is inline
/// (`Copy`, no heap) so recording can never allocate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpanKind {
    /// Request admitted into the shard scheduler with `rows` sample rows.
    Admitted { rows: u32 },
    /// Request became a member of lane `lane` at admission.
    LaneAttach { lane: u32 },
    /// Time spent queued before the first solver step.
    QueueWait { nanos: u64 },
    /// One solver step advanced the request's lane (`step` = NFE so far).
    SolverStep { lane: u32, step: u32 },
    /// ERA diagnostics for one corrected step: the error-robust error
    /// measure (Eq. 15) and the Lagrange basis indices the selection
    /// chose (Eq. 16/17). `k` of the `bases` slots are meaningful.
    EraStep { lane: u32, step: u32, delta_eps: f64, k: u8, bases: [u16; MAX_BASES] },
    /// ERA selection divergence split this request off into lane `to`.
    LaneSplit { from: u32, to: u32 },
    /// This request's rows were compacted out of lane `lane` (cancel or
    /// deadline retirement of a lane member).
    LaneCompact { lane: u32 },
    /// The request's lane evaluation went out in slab `seq` of dispatch
    /// round `round`.
    SlabDispatch { seq: u64, round: u64, lane: u32, rows: u32 },
    /// The slab came back from executor `executor` after `eval_nanos`
    /// of engine time.
    SlabComplete { seq: u64, round: u64, executor: u16, eval_nanos: u64 },
    /// Request finished normally after `nfe` network evaluations.
    Finalize { nfe: u32 },
    /// Request was cancelled (client cancel or deadline) after `nfe`
    /// evaluations. Terminal: no spans follow it for this trace.
    Cancelled { nfe: u32 },
}

/// A recorded event: which request, when (nanos since the recorder was
/// created — one clock per shard), and what happened.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub trace: u64,
    pub at_nanos: u64,
    pub kind: SpanKind,
}

struct Ring {
    slots: Vec<SpanEvent>,
    /// Monotonic write cursor; `head % capacity` is the next slot.
    head: u64,
}

/// Fixed-capacity ring of span events for one shard. `record` is
/// allocation-free; `snapshot_trace` (a debug/wire read) may allocate.
pub struct FlightRecorder {
    ring: Mutex<Ring>,
    capacity: usize,
    epoch: Instant,
}

impl FlightRecorder {
    /// Default per-shard capacity: enough for several hundred requests'
    /// full lifecycles before wraparound.
    pub const DEFAULT_CAPACITY: usize = 8192;

    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let filler = SpanEvent { trace: 0, at_nanos: 0, kind: SpanKind::Admitted { rows: 0 } };
        FlightRecorder {
            ring: Mutex::new(Ring { slots: vec![filler; capacity], head: 0 }),
            capacity,
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Nanoseconds since this recorder's epoch (the shard's trace clock).
    pub fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record one event for `trace`. Allocation-free: a `Copy` write
    /// into a preallocated slot plus a cursor bump.
    pub fn record(&self, trace: u64, kind: SpanKind) {
        let at_nanos = self.now_nanos();
        let mut ring = self.ring.lock().unwrap();
        let slot = (ring.head % self.capacity as u64) as usize;
        ring.slots[slot] = SpanEvent { trace, at_nanos, kind };
        ring.head += 1;
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.ring.lock().unwrap().head
    }

    /// All retained events, oldest→newest. The ring keeps the newest
    /// `capacity` events; older ones are overwritten.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let ring = self.ring.lock().unwrap();
        let cap = self.capacity as u64;
        let start = ring.head.saturating_sub(cap);
        (start..ring.head)
            .map(|i| ring.slots[(i % cap) as usize])
            .collect()
    }

    /// Retained events for one trace, oldest→newest.
    pub fn snapshot_trace(&self, trace: u64) -> Vec<SpanEvent> {
        self.snapshot().into_iter().filter(|e| e.trace == trace).collect()
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl SpanKind {
    /// Stable wire name for the event type.
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Admitted { .. } => "admitted",
            SpanKind::LaneAttach { .. } => "lane_attach",
            SpanKind::QueueWait { .. } => "queue_wait",
            SpanKind::SolverStep { .. } => "solver_step",
            SpanKind::EraStep { .. } => "era_step",
            SpanKind::LaneSplit { .. } => "lane_split",
            SpanKind::LaneCompact { .. } => "lane_compact",
            SpanKind::SlabDispatch { .. } => "slab_dispatch",
            SpanKind::SlabComplete { .. } => "slab_complete",
            SpanKind::Finalize { .. } => "finalize",
            SpanKind::Cancelled { .. } => "cancelled",
        }
    }

    /// True for the events that end a trace (nothing may follow them).
    pub fn is_terminal(&self) -> bool {
        matches!(self, SpanKind::Finalize { .. } | SpanKind::Cancelled { .. })
    }
}

/// Serialise one event for the `trace` wire op.
pub fn event_to_json(e: &SpanEvent) -> Json {
    let mut obj = Json::obj(vec![
        ("kind", Json::Str(e.kind.name().into())),
        ("at_ns", Json::Num(e.at_nanos as f64)),
    ]);
    match e.kind {
        SpanKind::Admitted { rows } => obj.set("rows", Json::Num(rows as f64)),
        SpanKind::LaneAttach { lane } => obj.set("lane", Json::Num(lane as f64)),
        SpanKind::QueueWait { nanos } => obj.set("wait_ns", Json::Num(nanos as f64)),
        SpanKind::SolverStep { lane, step } => {
            obj.set("lane", Json::Num(lane as f64));
            obj.set("step", Json::Num(step as f64));
        }
        SpanKind::EraStep { lane, step, delta_eps, k, bases } => {
            obj.set("lane", Json::Num(lane as f64));
            obj.set("step", Json::Num(step as f64));
            obj.set("delta_eps", Json::Num(delta_eps));
            let idx: Vec<Json> =
                bases[..k as usize].iter().map(|&b| Json::Num(b as f64)).collect();
            obj.set("bases", Json::Arr(idx));
        }
        SpanKind::LaneSplit { from, to } => {
            obj.set("from", Json::Num(from as f64));
            obj.set("to", Json::Num(to as f64));
        }
        SpanKind::LaneCompact { lane } => obj.set("lane", Json::Num(lane as f64)),
        SpanKind::SlabDispatch { seq, round, lane, rows } => {
            obj.set("seq", Json::Num(seq as f64));
            obj.set("round", Json::Num(round as f64));
            obj.set("lane", Json::Num(lane as f64));
            obj.set("rows", Json::Num(rows as f64));
        }
        SpanKind::SlabComplete { seq, round, executor, eval_nanos } => {
            obj.set("seq", Json::Num(seq as f64));
            obj.set("round", Json::Num(round as f64));
            obj.set("executor", Json::Num(executor as f64));
            obj.set("eval_ns", Json::Num(eval_nanos as f64));
        }
        SpanKind::Finalize { nfe } => obj.set("nfe", Json::Num(nfe as f64)),
        SpanKind::Cancelled { nfe } => obj.set("nfe", Json::Num(nfe as f64)),
    }
    obj
}

/// Pack a selected-indices slice into the inline basis array (clamped
/// to [`MAX_BASES`]).
pub fn pack_bases(idx: &[usize]) -> (u8, [u16; MAX_BASES]) {
    let mut bases = [0u16; MAX_BASES];
    let k = idx.len().min(MAX_BASES);
    for (slot, &b) in bases.iter_mut().zip(idx.iter()) {
        *slot = b.min(u16::MAX as usize) as u16;
    }
    (k as u8, bases)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraparound_keeps_newest_in_order() {
        let rec = FlightRecorder::with_capacity(8);
        for step in 0..20u32 {
            rec.record(1, SpanKind::SolverStep { lane: 0, step });
        }
        let events = rec.snapshot();
        assert_eq!(events.len(), 8, "ring retains exactly its capacity");
        // The newest 8 events (steps 12..20) survive, oldest→newest.
        for (i, e) in events.iter().enumerate() {
            match e.kind {
                SpanKind::SolverStep { step, .. } => assert_eq!(step, 12 + i as u32),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(
            events.windows(2).all(|w| w[0].at_nanos <= w[1].at_nanos),
            "timestamps monotone oldest→newest"
        );
        assert_eq!(rec.recorded(), 20);
    }

    #[test]
    fn snapshot_trace_filters_and_preserves_order() {
        let rec = FlightRecorder::with_capacity(64);
        rec.record(7, SpanKind::Admitted { rows: 4 });
        rec.record(9, SpanKind::Admitted { rows: 2 });
        rec.record(7, SpanKind::LaneAttach { lane: 3 });
        rec.record(9, SpanKind::Cancelled { nfe: 0 });
        rec.record(7, SpanKind::Finalize { nfe: 10 });
        let t7 = rec.snapshot_trace(7);
        assert_eq!(t7.len(), 3);
        assert_eq!(t7[0].kind, SpanKind::Admitted { rows: 4 });
        assert_eq!(t7[1].kind, SpanKind::LaneAttach { lane: 3 });
        assert_eq!(t7[2].kind, SpanKind::Finalize { nfe: 10 });
        let t9 = rec.snapshot_trace(9);
        assert_eq!(t9.len(), 2);
        assert!(t9[1].kind.is_terminal());
        assert!(rec.snapshot_trace(42).is_empty());
    }

    #[test]
    fn cancelled_trace_is_terminal_after_wrap() {
        // A cancelled trace's terminal event survives wraparound as long
        // as it is among the newest `capacity` events, and nothing for
        // that trace follows it.
        let rec = FlightRecorder::with_capacity(16);
        rec.record(5, SpanKind::Admitted { rows: 1 });
        rec.record(5, SpanKind::Cancelled { nfe: 2 });
        for step in 0..10 {
            rec.record(6, SpanKind::SolverStep { lane: 0, step });
        }
        let t5 = rec.snapshot_trace(5);
        assert_eq!(t5.last().map(|e| e.kind), Some(SpanKind::Cancelled { nfe: 2 }));
        assert!(t5[..t5.len() - 1].iter().all(|e| !e.kind.is_terminal()));
    }

    #[test]
    fn event_json_carries_typed_fields() {
        let (k, bases) = pack_bases(&[2, 5, 9]);
        let e = SpanEvent {
            trace: 3,
            at_nanos: 1234,
            kind: SpanKind::EraStep { lane: 1, step: 4, delta_eps: 0.125, k, bases },
        };
        let j = event_to_json(&e);
        assert_eq!(j.get("kind").as_str(), Some("era_step"));
        assert_eq!(j.get("at_ns").as_usize(), Some(1234));
        assert_eq!(j.get("delta_eps").as_f64(), Some(0.125));
        let b = j.get("bases").as_f64_vec().unwrap();
        assert_eq!(b, vec![2.0, 5.0, 9.0]);
    }

    #[test]
    fn pack_bases_clamps() {
        let (k, bases) = pack_bases(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(k as usize, MAX_BASES);
        assert_eq!(bases[MAX_BASES - 1], 8);
    }
}
