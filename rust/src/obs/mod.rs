//! Observability: request-lifecycle tracing, Prometheus-style metrics
//! exposition, and persistent bench artifacts.
//!
//! Three layers, all zero-dependency:
//!
//! * [`trace`] — a per-shard **flight recorder**: every admitted request
//!   is identified by its shard-local request id (the trace id), and the
//!   scheduler/executor pipeline records typed [`trace::SpanEvent`]s
//!   (admission, queue wait, lane attach/split/compact, slab
//!   dispatch/completion, per-step ERA `delta_eps` + selected Lagrange
//!   bases, finalize/cancel) into a fixed-capacity preallocated ring.
//!   Recording is allocation-free at steady state — events are `Copy`
//!   with inline basis-index storage — so it stays under the
//!   `bench_step_overhead` zero-alloc gates with recording enabled.
//! * [`prometheus`] — a tiny Prometheus text-exposition builder used by
//!   `PoolStats::prometheus()` to render every counter/gauge/histogram
//!   (including the per-stage latency histograms) for the `metrics`
//!   wire op and the `era-serve --metrics` textfile.
//! * [`bench_json`] — the `BENCH_*.json` artifact schema: benches emit
//!   structured metric reports (`{"name", "value", "direction",
//!   "tolerance"}`), committed baselines live under `benchmarks/`, and
//!   the `bench_gate` example compares a fresh run against them so the
//!   perf trajectory is durable and CI fails on regression.

pub mod bench_json;
pub mod prometheus;
pub mod trace;

pub use bench_json::{BenchMetric, BenchReport, Direction};
pub use prometheus::PromText;
pub use trace::{FlightRecorder, SpanEvent, SpanKind, MAX_BASES};
