//! Persistent perf trajectory: the `BENCH_*.json` artifact schema.
//!
//! Benches build a [`BenchReport`] and call [`BenchReport::write_if_env`]
//! — when `ERA_BENCH_JSON_DIR` is set the report lands there as
//! `BENCH_<suite>.json`. Committed baselines live in `benchmarks/`; the
//! `bench_gate` example loads a fresh report and a baseline and fails
//! naming every regressed metric.
//!
//! Schema:
//!
//! ```json
//! {"suite": "step_overhead",
//!  "metrics": [{"name": "era4_allocs_per_step", "value": 0.0,
//!               "direction": "lower", "tolerance": 0.0}]}
//! ```
//!
//! `direction` says which way is better; `tolerance` is the fractional
//! band around the *baseline* value before a worse reading counts as a
//! regression (0.0 = any worsening fails — used for allocation counts,
//! which are machine-independent; timing metrics carry generous bands).

use std::io;
use std::path::Path;

use crate::json::{self, Json};

/// Which direction of change is an improvement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    LowerIsBetter,
    HigherIsBetter,
}

impl Direction {
    fn as_str(&self) -> &'static str {
        match self {
            Direction::LowerIsBetter => "lower",
            Direction::HigherIsBetter => "higher",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "lower" => Some(Direction::LowerIsBetter),
            "higher" => Some(Direction::HigherIsBetter),
            _ => None,
        }
    }
}

/// One tracked metric.
#[derive(Clone, Debug)]
pub struct BenchMetric {
    pub name: String,
    pub value: f64,
    pub direction: Direction,
    /// Fractional tolerance band around the baseline value.
    pub tolerance: f64,
}

/// One bench suite's emitted report.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub suite: String,
    pub metrics: Vec<BenchMetric>,
}

impl BenchReport {
    pub fn new(suite: &str) -> Self {
        BenchReport { suite: suite.into(), metrics: Vec::new() }
    }

    pub fn push(&mut self, name: &str, value: f64, direction: Direction, tolerance: f64) {
        self.metrics.push(BenchMetric { name: name.into(), value, direction, tolerance });
    }

    pub fn get(&self, name: &str) -> Option<&BenchMetric> {
        self.metrics.iter().find(|m| m.name == name)
    }

    pub fn to_json(&self) -> Json {
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::Str(m.name.clone())),
                    ("value", Json::Num(m.value)),
                    ("direction", Json::Str(m.direction.as_str().into())),
                    ("tolerance", Json::Num(m.tolerance)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("suite", Json::Str(self.suite.clone())),
            ("metrics", Json::Arr(metrics)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let suite = j.get("suite").as_str().ok_or("missing suite")?.to_string();
        let arr = j.get("metrics").as_arr().ok_or("missing metrics")?;
        let mut metrics = Vec::with_capacity(arr.len());
        for m in arr {
            let name = m.get("name").as_str().ok_or("metric missing name")?.to_string();
            let value = m.get("value").as_f64().ok_or("metric missing value")?;
            let direction = Direction::parse(m.get("direction").as_str().unwrap_or("lower"))
                .ok_or("bad direction")?;
            let tolerance = m.get("tolerance").as_f64().unwrap_or(0.0);
            metrics.push(BenchMetric { name, value, direction, tolerance });
        }
        Ok(BenchReport { suite, metrics })
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        let j = json::parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn write_to(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    /// Write `BENCH_<suite>.json` into `$ERA_BENCH_JSON_DIR` when the
    /// env var is set; a silent no-op otherwise (local bench runs).
    pub fn write_if_env(&self) {
        if let Ok(dir) = std::env::var("ERA_BENCH_JSON_DIR") {
            if dir.is_empty() {
                return;
            }
            let path = Path::new(&dir).join(format!("BENCH_{}.json", self.suite));
            if let Err(e) = self.write_to(&path) {
                eprintln!("[bench-json] failed to write {path:?}: {e}");
            } else {
                println!("[bench-json] wrote {}", path.display());
            }
        }
    }

    /// Compare this (fresh) report against a committed baseline. Returns
    /// one human-readable message per regression; empty = gate passes.
    /// The baseline's direction/tolerance are authoritative; a metric
    /// present in the baseline but missing here is itself a regression.
    pub fn regressions_against(&self, baseline: &BenchReport) -> Vec<String> {
        let mut out = Vec::new();
        for base in &baseline.metrics {
            let Some(cur) = self.get(&base.name) else {
                out.push(format!(
                    "{}/{}: metric missing from the fresh run (baseline {})",
                    baseline.suite, base.name, base.value
                ));
                continue;
            };
            let tol = base.tolerance.max(0.0);
            let (limit, bad) = match base.direction {
                Direction::LowerIsBetter => {
                    let limit = base.value * (1.0 + tol) + f64::EPSILON;
                    (limit, cur.value > limit)
                }
                Direction::HigherIsBetter => {
                    let limit = base.value * (1.0 - tol) - f64::EPSILON;
                    (limit, cur.value < limit)
                }
            };
            if bad {
                out.push(format!(
                    "{}/{}: REGRESSED — current {:.6} vs baseline {:.6} \
                     (allowed {} {:.6}, direction {}, tolerance {})",
                    baseline.suite,
                    base.name,
                    cur.value,
                    base.value,
                    match base.direction {
                        Direction::LowerIsBetter => "<=",
                        Direction::HigherIsBetter => ">=",
                    },
                    limit,
                    base.direction.as_str(),
                    tol
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> BenchReport {
        let mut r = BenchReport::new("step_overhead");
        r.push("era4_allocs_per_step", 0.0, Direction::LowerIsBetter, 0.0);
        r.push("era4_ns_per_step", 1000.0, Direction::LowerIsBetter, 0.5);
        r.push("lane_vs_boxed_ratio", 2.0, Direction::HigherIsBetter, 0.25);
        r
    }

    #[test]
    fn json_roundtrip() {
        let r = report();
        let back = BenchReport::from_json(&json::parse(&r.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.suite, "step_overhead");
        assert_eq!(back.metrics.len(), 3);
        let m = back.get("era4_ns_per_step").unwrap();
        assert_eq!(m.value, 1000.0);
        assert_eq!(m.direction, Direction::LowerIsBetter);
        assert_eq!(m.tolerance, 0.5);
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = report();
        let mut fresh = BenchReport::new("step_overhead");
        fresh.push("era4_allocs_per_step", 0.0, Direction::LowerIsBetter, 0.0);
        fresh.push("era4_ns_per_step", 1400.0, Direction::LowerIsBetter, 0.5);
        fresh.push("lane_vs_boxed_ratio", 1.6, Direction::HigherIsBetter, 0.25);
        assert!(fresh.regressions_against(&base).is_empty());
    }

    #[test]
    fn gate_names_the_regressed_metric() {
        let base = report();
        let mut fresh = BenchReport::new("step_overhead");
        fresh.push("era4_allocs_per_step", 1.0, Direction::LowerIsBetter, 0.0);
        fresh.push("era4_ns_per_step", 1600.0, Direction::LowerIsBetter, 0.5);
        fresh.push("lane_vs_boxed_ratio", 1.0, Direction::HigherIsBetter, 0.25);
        let msgs = fresh.regressions_against(&base);
        assert_eq!(msgs.len(), 3, "{msgs:?}");
        assert!(msgs[0].contains("era4_allocs_per_step"), "{}", msgs[0]);
        assert!(msgs[1].contains("era4_ns_per_step"), "{}", msgs[1]);
        assert!(msgs[2].contains("lane_vs_boxed_ratio"), "{}", msgs[2]);
        assert!(msgs.iter().all(|m| m.contains("REGRESSED")));
    }

    #[test]
    fn missing_metric_is_a_regression() {
        let base = report();
        let fresh = BenchReport::new("step_overhead");
        let msgs = fresh.regressions_against(&base);
        assert_eq!(msgs.len(), 3);
        assert!(msgs[0].contains("missing"));
    }

    #[test]
    fn improvements_never_fail_the_gate() {
        let base = report();
        let mut fresh = BenchReport::new("step_overhead");
        fresh.push("era4_allocs_per_step", 0.0, Direction::LowerIsBetter, 0.0);
        fresh.push("era4_ns_per_step", 10.0, Direction::LowerIsBetter, 0.5);
        fresh.push("lane_vs_boxed_ratio", 50.0, Direction::HigherIsBetter, 0.25);
        assert!(fresh.regressions_against(&base).is_empty());
    }
}
