//! Minimal Prometheus text-exposition builder (format version 0.0.4).
//!
//! Naming scheme: every metric is prefixed `era_`; counters end in
//! `_total`, gauges are bare, histograms render the conventional
//! `_bucket{le="..."}` / `_sum` / `_count` triplet with a final
//! `le="+Inf"` bucket. Labels are caller-supplied `(key, value)` pairs;
//! values are escaped per the exposition spec.

/// Incremental builder for one exposition payload.
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> Self {
        PromText { out: String::new() }
    }

    /// Emit the `# HELP` / `# TYPE` header for a metric family.
    /// `kind` is one of `counter`, `gauge`, `histogram`.
    pub fn family(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Emit one sample line `name{labels} value`.
    pub fn value(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.out.push_str(name);
        self.push_labels(labels);
        // Prometheus accepts scientific notation; render integers bare.
        if v.fract() == 0.0 && v.abs() < 1e15 {
            self.out.push_str(&format!(" {}\n", v as i64));
        } else {
            self.out.push_str(&format!(" {v}\n"));
        }
    }

    /// Emit a full histogram: cumulative `_bucket` lines over `bounds`
    /// (upper edges in seconds) plus the implicit `+Inf` bucket, then
    /// `_sum` and `_count`. `buckets` holds per-bucket (non-cumulative)
    /// counts, one per bound plus one overflow slot.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
        buckets: &[u64],
        sum: f64,
        count: u64,
    ) {
        debug_assert_eq!(buckets.len(), bounds.len() + 1, "one overflow bucket");
        let mut cum = 0u64;
        let bucket_name = format!("{name}_bucket");
        for (i, &bound) in bounds.iter().enumerate() {
            cum += buckets[i];
            let le = format!("{bound}");
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", &le));
            self.value(&bucket_name, &ls, cum as f64);
        }
        cum += buckets[bounds.len()];
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.value(&bucket_name, &ls, cum as f64);
        self.value(&format!("{name}_sum"), labels, sum);
        self.value(&format!("{name}_count"), labels, count as f64);
    }

    fn push_labels(&mut self, labels: &[(&str, &str)]) {
        if labels.is_empty() {
            return;
        }
        self.out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                self.out.push(',');
            }
            let escaped = v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
            self.out.push_str(&format!("{k}=\"{escaped}\""));
        }
        self.out.push('}');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for PromText {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_counter_and_gauge_lines() {
        let mut p = PromText::new();
        p.family("era_requests_finished_total", "Finished requests.", "counter");
        p.value("era_requests_finished_total", &[], 42.0);
        p.family("era_inflight_rows", "Rows in flight.", "gauge");
        p.value("era_inflight_rows", &[("shard", "0")], 128.0);
        let text = p.finish();
        assert!(text.contains("# HELP era_requests_finished_total Finished requests.\n"));
        assert!(text.contains("# TYPE era_requests_finished_total counter\n"));
        assert!(text.contains("era_requests_finished_total 42\n"));
        assert!(text.contains("era_inflight_rows{shard=\"0\"} 128\n"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let mut p = PromText::new();
        p.histogram(
            "era_stage_latency_seconds",
            &[("stage", "queue")],
            &[0.001, 0.01],
            &[3, 2, 1],
            0.025,
            6,
        );
        let text = p.finish();
        assert!(text.contains("era_stage_latency_seconds_bucket{stage=\"queue\",le=\"0.001\"} 3\n"));
        assert!(text.contains("era_stage_latency_seconds_bucket{stage=\"queue\",le=\"0.01\"} 5\n"));
        assert!(text.contains("era_stage_latency_seconds_bucket{stage=\"queue\",le=\"+Inf\"} 6\n"));
        assert!(text.contains("era_stage_latency_seconds_sum{stage=\"queue\"} 0.025\n"));
        assert!(text.contains("era_stage_latency_seconds_count{stage=\"queue\"} 6\n"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = PromText::new();
        p.value("era_x", &[("d", "a\"b")], 1.0);
        assert!(p.finish().contains("era_x{d=\"a\\\"b\"} 1\n"));
    }
}
