//! Deterministic PRNG substrate (the offline registry ships no `rand`).
//!
//! SplitMix64 for the integer stream — tiny state, passes BigCrush for the
//! purposes of a sampling workload, and trivially splittable so concurrent
//! requests get independent streams from a request id. Gaussians via
//! Box–Muller in f64, cast to f32.

use crate::tensor::Tensor;

/// SplitMix64 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Independent stream derived from this seed and a stream id; used by
    /// the coordinator to give each request its own generator.
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        let mut r = Rng::new(seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        r.next_u64(); // decorrelate trivially related seeds
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Multiply-shift; bias is negligible for the n used here (<=2^32).
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Standard normal via Box–Muller (one of the pair is discarded for
    /// simplicity — generation is not a hot path relative to PJRT calls).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill `out` with iid standard normals, consuming the stream in
    /// exactly the pattern of [`Rng::normal_tensor`] (Box–Muller pairs,
    /// odd tail via [`Rng::normal`]) — the allocation-free form the
    /// solvers' preallocated noise scratch uses; per-seed trajectories
    /// are identical either way.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        let n = out.len();
        let mut i = 0;
        // Consume Box–Muller pairs to halve the transcendental count.
        while i + 2 <= n {
            let u1 = self.uniform().max(1e-300);
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            out[i] = (r * c) as f32;
            out[i + 1] = (r * s) as f32;
            i += 2;
        }
        while i < n {
            out[i] = self.normal() as f32;
            i += 1;
        }
    }

    /// (rows x cols) tensor of iid standard normals.
    pub fn normal_tensor(&mut self, rows: usize, cols: usize) -> Tensor {
        let mut data = vec![0.0f32; rows * cols];
        self.fill_normal(&mut data);
        Tensor::from_vec(data, rows, cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::for_stream(7, 0);
        let mut b = Rng::for_stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fill_normal_matches_normal_tensor_stream() {
        // Same seed, same stream consumption: the in-place fill and the
        // allocating constructor must produce identical values (odd
        // lengths exercise the Box–Muller tail).
        for n in [1usize, 2, 5, 8, 33] {
            let mut a = Rng::new(77);
            let mut b = Rng::new(77);
            let t = a.normal_tensor(n, 1);
            let mut buf = vec![0.0f32; n];
            b.fill_normal(&mut buf);
            assert_eq!(t.as_slice(), buf.as_slice(), "n={n}");
            assert_eq!(a.next_u64(), b.next_u64(), "stream position diverged at n={n}");
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        const N: usize = 20_000;
        for _ in 0..N {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / N as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let t = r.normal_tensor(1000, 16);
        let n = t.len() as f64;
        let mean: f64 = t.as_slice().iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = t.as_slice().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        assert!(t.all_finite());
    }

    #[test]
    fn normal_tensor_odd_len() {
        let mut r = Rng::new(6);
        let t = r.normal_tensor(3, 3); // odd element count hits the tail path
        assert_eq!(t.len(), 9);
        assert!(t.all_finite());
    }
}
