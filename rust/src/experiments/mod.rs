//! Experiment machinery shared by the `examples/` drivers and the bench
//! targets: solver sweeps, figure/table assembly, and report writing.
//!
//! Each paper table/figure has one driver binary (see DESIGN.md §5);
//! they all call into here so the sweep logic — equal-NFE accounting,
//! seeding, FID evaluation against the manifest's reference moments —
//! is written (and tested) once.

pub mod report;
pub mod sweep;

pub use report::{write_markdown_table, Table};
pub use sweep::{EvalBackend, SweepConfig, SweepResult};
