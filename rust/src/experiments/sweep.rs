//! Solver × NFE sweeps with FID evaluation (the engine behind every
//! table/figure reproduction).

use std::sync::Arc;

use crate::metrics::{self, Moments};
use crate::rng::Rng;
use crate::runtime::{PjRtEngine, PjRtEps};
use crate::solvers::eps_model::EpsModel;
use crate::solvers::schedule::{make_grid, GridKind, VpSchedule};
use crate::solvers::{sample_with, Solver, SolverKind};
use crate::tensor::Tensor;

/// Where network evaluations come from during a sweep.
pub enum EvalBackend {
    /// Production path: AOT artifacts through PJRT.
    Pjrt { engine: Arc<PjRtEngine>, dataset: String },
    /// In-process analytic/mock model (tests, micro benches).
    InProcess { model: Box<dyn EpsModel>, reference: Moments },
}

impl EvalBackend {
    pub fn pjrt(engine: Arc<PjRtEngine>, dataset: &str) -> Result<EvalBackend, String> {
        engine.dataset(dataset)?;
        Ok(EvalBackend::Pjrt { engine, dataset: dataset.to_string() })
    }

    pub fn dim(&self) -> usize {
        match self {
            EvalBackend::Pjrt { engine, dataset } => engine.dataset(dataset).unwrap().dim,
            EvalBackend::InProcess { model, .. } => model.dim(),
        }
    }

    pub fn schedule(&self) -> VpSchedule {
        match self {
            EvalBackend::Pjrt { engine, .. } => engine.manifest().schedule,
            EvalBackend::InProcess { .. } => VpSchedule::default(),
        }
    }

    pub fn reference(&self) -> Moments {
        match self {
            EvalBackend::Pjrt { engine, dataset } => {
                engine.dataset(dataset).unwrap().ref_stats.clone()
            }
            EvalBackend::InProcess { reference, .. } => reference.clone(),
        }
    }

    fn run(&self, solver: &mut dyn Solver) -> Tensor {
        match self {
            EvalBackend::Pjrt { engine, dataset } => {
                let eps = PjRtEps::new(engine, dataset).expect("dataset checked at build");
                sample_with(solver, &eps)
            }
            EvalBackend::InProcess { model, .. } => sample_with(solver, model.as_ref()),
        }
    }
}

/// One sweep's parameters (defaults mirror the paper's LSUN settings).
pub struct SweepConfig {
    /// Solver names, [`SolverKind::parse`] syntax.
    pub solvers: Vec<String>,
    pub nfes: Vec<usize>,
    pub grid: GridKind,
    pub t_end: f64,
    /// Samples generated per (solver, NFE) cell.
    pub n_samples: usize,
    /// Generation happens in batches of this many rows.
    pub batch: usize,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            solvers: ["ddim", "pndm", "fon", "iadams", "dpm-2", "dpm-fast", "era"]
                .map(String::from)
                .to_vec(),
            nfes: vec![5, 10, 12, 15, 20, 40, 50, 100],
            grid: GridKind::Uniform,
            t_end: 1e-3,
            n_samples: 4096,
            batch: 256,
            seed: 0,
        }
    }
}

/// One (solver, NFE) cell outcome.
#[derive(Clone, Debug)]
pub struct Cell {
    pub solver: String,
    pub nfe: usize,
    /// `None` when the solver cannot run at this budget (paper's "\"
    /// cells: PNDM/FON below the RK warmup minimum).
    pub fid: Option<f64>,
    pub mode_coverage: Option<f64>,
    pub wall_seconds: f64,
    pub actual_nfe: usize,
}

/// Full sweep outcome.
pub struct SweepResult {
    pub cells: Vec<Cell>,
    pub config_label: String,
}

impl SweepResult {
    pub fn cell(&self, solver: &str, nfe: usize) -> Option<&Cell> {
        self.cells.iter().find(|c| c.solver == solver && c.nfe == nfe)
    }

    pub fn fid(&self, solver: &str, nfe: usize) -> Option<f64> {
        self.cell(solver, nfe).and_then(|c| c.fid)
    }
}

/// Generate `n_samples` from one solver at one NFE budget, in batches.
pub fn generate(
    backend: &EvalBackend,
    kind: &SolverKind,
    nfe: usize,
    grid_kind: GridKind,
    t_end: f64,
    n_samples: usize,
    batch: usize,
    seed: u64,
) -> (Tensor, usize) {
    let sched = backend.schedule();
    let dim = backend.dim();
    let steps = kind.steps_for_nfe(nfe);
    // One trajectory plan for every chunk of this cell (all chunks share
    // the same (solver, grid, schedule) configuration).
    let grid = make_grid(&sched, grid_kind, steps, 1.0, t_end);
    let plan = Arc::new(kind.make_plan(sched, grid, nfe));
    let mut parts = Vec::new();
    let mut consumed_nfe = 0;
    let mut produced = 0usize;
    let mut chunk_idx = 0u64;
    while produced < n_samples {
        let rows = batch.min(n_samples - produced);
        let mut rng = Rng::for_stream(seed, 0xc0ffee ^ chunk_idx);
        let x0 = rng.normal_tensor(rows, dim);
        let mut solver = kind.build_with_plan(plan.clone(), x0, seed ^ chunk_idx);
        parts.push(backend.run(&mut *solver));
        consumed_nfe = solver.nfe();
        produced += rows;
        chunk_idx += 1;
    }
    let refs: Vec<&Tensor> = parts.iter().collect();
    (Tensor::vstack(&refs), consumed_nfe)
}

/// Run the full sweep, printing progress to stderr.
pub fn run_sweep(backend: &EvalBackend, cfg: &SweepConfig) -> SweepResult {
    let reference = backend.reference();
    let modes = crate::data::gmm8_modes();
    let is_gmm8 = backend.dim() == 2 && reference.dim == 2;
    let mut cells = Vec::new();
    for solver_name in &cfg.solvers {
        let kind = SolverKind::parse(solver_name)
            .unwrap_or_else(|| panic!("unknown solver '{solver_name}'"));
        for &nfe in &cfg.nfes {
            if kind.validate_nfe(nfe).is_err() {
                cells.push(Cell {
                    solver: solver_name.clone(),
                    nfe,
                    fid: None,
                    mode_coverage: None,
                    wall_seconds: 0.0,
                    actual_nfe: 0,
                });
                continue;
            }
            let t0 = std::time::Instant::now();
            let (samples, actual_nfe) = generate(
                backend,
                &kind,
                nfe,
                cfg.grid,
                cfg.t_end,
                cfg.n_samples,
                cfg.batch,
                cfg.seed,
            );
            let wall = t0.elapsed().as_secs_f64();
            let fid = metrics::fid(&samples, &reference);
            let cov = if is_gmm8 {
                Some(metrics::mode_coverage(&samples, &modes, 0.5))
            } else {
                None
            };
            eprintln!(
                "  {solver_name:<14} nfe={nfe:<4} fid={fid:<9.4} ({wall:.1}s, actual nfe {actual_nfe})"
            );
            cells.push(Cell {
                solver: solver_name.clone(),
                nfe,
                fid: Some(fid),
                mode_coverage: cov,
                wall_seconds: wall,
                actual_nfe,
            });
        }
    }
    SweepResult {
        cells,
        config_label: format!(
            "grid={:?} t_end={} n={} seed={}",
            cfg.grid, cfg.t_end, cfg.n_samples, cfg.seed
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::eps_model::AnalyticGmm;

    fn backend() -> EvalBackend {
        let sched = VpSchedule::default();
        EvalBackend::InProcess {
            model: Box::new(AnalyticGmm::gmm8(sched)),
            reference: Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225]),
        }
    }

    #[test]
    fn sweep_produces_all_cells() {
        let cfg = SweepConfig {
            solvers: vec!["ddim".into(), "era".into(), "pndm".into()],
            nfes: vec![5, 15],
            n_samples: 128,
            batch: 64,
            ..Default::default()
        };
        let res = run_sweep(&backend(), &cfg);
        assert_eq!(res.cells.len(), 6);
        // PNDM at NFE 5 is below its warmup minimum -> empty cell.
        assert!(res.fid("pndm", 5).is_none());
        assert!(res.fid("pndm", 15).is_some());
        assert!(res.fid("era", 15).unwrap().is_finite());
    }

    #[test]
    fn generate_respects_sample_count_and_batches() {
        let b = backend();
        let kind = SolverKind::parse("ddim").unwrap();
        let (samples, nfe) =
            generate(&b, &kind, 8, GridKind::Uniform, 1e-3, 100, 32, 7);
        assert_eq!(samples.rows(), 100);
        assert_eq!(nfe, 8);
    }

    #[test]
    fn equal_nfe_accounting_dpm() {
        // dpm-2 at budget 10 must actually consume 10 evals.
        let b = backend();
        let kind = SolverKind::parse("dpm-2").unwrap();
        let (_, nfe) = generate(&b, &kind, 10, GridKind::LogSnr, 1e-3, 32, 32, 1);
        assert_eq!(nfe, 10);
        let fast = SolverKind::parse("dpm-fast").unwrap();
        let (_, nfe) = generate(&b, &fast, 10, GridKind::LogSnr, 1e-3, 32, 32, 1);
        assert_eq!(nfe, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let b = backend();
        let kind = SolverKind::parse("era").unwrap();
        let (a, _) = generate(&b, &kind, 10, GridKind::Uniform, 1e-3, 64, 32, 3);
        let (c, _) = generate(&b, &kind, 10, GridKind::Uniform, 1e-3, 64, 32, 3);
        assert_eq!(a.as_slice(), c.as_slice());
    }
}
