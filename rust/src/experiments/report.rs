//! Report writing: markdown tables (matching the paper's row/column
//! layout), CSV series for the figures, and ASCII density plots for the
//! qualitative comparisons.

use std::io::Write;
use std::path::Path;

use crate::experiments::sweep::SweepResult;
use crate::tensor::Tensor;

/// A generic table: header row + body rows.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnote: String,
}

impl Table {
    /// Paper-style layout from a sweep: one row per solver, one column
    /// per NFE, `\` for cells the solver cannot fill.
    pub fn from_sweep(title: &str, sweep: &SweepResult, solvers: &[String], nfes: &[usize]) -> Table {
        let mut header = vec!["Sampling method \\ NFE".to_string()];
        header.extend(nfes.iter().map(|n| n.to_string()));
        let rows = solvers
            .iter()
            .map(|s| {
                let mut row = vec![s.clone()];
                for &nfe in nfes {
                    row.push(match sweep.fid(s, nfe) {
                        Some(f) => format!("{f:.3}"),
                        None => "\\".to_string(),
                    });
                }
                row
            })
            .collect();
        Table {
            title: title.to_string(),
            header,
            rows,
            footnote: sweep.config_label.clone(),
        }
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        let widths: Vec<usize> = (0..self.header.len())
            .map(|c| {
                self.rows
                    .iter()
                    .map(|r| r.get(c).map_or(0, |v| v.len()))
                    .chain(std::iter::once(self.header[c].len()))
                    .max()
                    .unwrap_or(1)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.header));
        s.push('|');
        for w in &widths {
            s.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row));
        }
        if !self.footnote.is_empty() {
            s.push_str(&format!("\n*{}*\n", self.footnote));
        }
        s
    }
}

/// Write a table to `path` (creating parent dirs) and echo it to stdout.
pub fn write_markdown_table(path: &str, table: &Table) -> std::io::Result<()> {
    let md = table.to_markdown();
    print!("{md}");
    if let Some(parent) = Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(md.as_bytes())
}

/// Write (x, series...) columns as CSV — the figure format.
pub fn write_csv(
    path: &str,
    header: &[&str],
    columns: &[Vec<f64>],
) -> std::io::Result<()> {
    assert_eq!(header.len(), columns.len(), "header/columns mismatch");
    let rows = columns.first().map_or(0, |c| c.len());
    assert!(columns.iter().all(|c| c.len() == rows), "ragged columns");
    if let Some(parent) = Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in 0..rows {
        let line: Vec<String> = columns.iter().map(|c| format!("{}", c[r])).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// ASCII density plot of 2-D samples over [-lim, lim]^2 (the qualitative
/// "sample grid" stand-in; intensity ramp " .:-=+*#%@").
pub fn ascii_density(samples: &Tensor, grid: usize, lim: f64) -> String {
    assert_eq!(samples.cols(), 2, "ascii_density wants 2-D samples");
    let mut counts = vec![0usize; grid * grid];
    for r in 0..samples.rows() {
        let row = samples.row(r);
        let fx = ((row[0] as f64 + lim) / (2.0 * lim) * grid as f64).floor();
        let fy = ((row[1] as f64 + lim) / (2.0 * lim) * grid as f64).floor();
        if fx >= 0.0 && fy >= 0.0 && (fx as usize) < grid && (fy as usize) < grid {
            counts[(grid - 1 - fy as usize) * grid + fx as usize] += 1;
        }
    }
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    let ramp: Vec<char> = " .:-=+*#%@".chars().collect();
    let mut out = String::with_capacity(grid * (grid + 1));
    for y in 0..grid {
        for x in 0..grid {
            let v = counts[y * grid + x];
            let idx = if v == 0 {
                0
            } else {
                1 + (v * (ramp.len() - 2)) / max
            };
            out.push(ramp[idx.min(ramp.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sweep::{Cell, SweepResult};

    fn sweep() -> SweepResult {
        SweepResult {
            cells: vec![
                Cell {
                    solver: "era".into(),
                    nfe: 10,
                    fid: Some(1.234567),
                    mode_coverage: None,
                    wall_seconds: 0.1,
                    actual_nfe: 10,
                },
                Cell {
                    solver: "pndm".into(),
                    nfe: 10,
                    fid: None,
                    mode_coverage: None,
                    wall_seconds: 0.0,
                    actual_nfe: 0,
                },
            ],
            config_label: "test".into(),
        }
    }

    #[test]
    fn table_layout_matches_paper() {
        let t = Table::from_sweep(
            "Tab. X",
            &sweep(),
            &["era".to_string(), "pndm".to_string()],
            &[10],
        );
        let md = t.to_markdown();
        assert!(md.contains("### Tab. X"));
        assert!(md.contains("| era"));
        assert!(md.contains("1.235"));
        assert!(md.contains("\\")); // missing cell marker
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("era_test_csv");
        let path = dir.join("fig.csv");
        write_csv(
            path.to_str().unwrap(),
            &["nfe", "fid"],
            &[vec![5.0, 10.0], vec![30.0, 9.0]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("nfe,fid"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn density_plot_shape() {
        let samples = Tensor::from_vec(vec![0.0, 0.0, 2.0, 2.0, -2.0, -2.0], 3, 2);
        let art = ascii_density(&samples, 8, 3.0);
        assert_eq!(art.lines().count(), 8);
        assert!(art.lines().all(|l| l.chars().count() == 8));
        assert!(art.chars().any(|c| c != ' ' && c != '\n'));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn csv_rejects_ragged() {
        let _ = write_csv("/tmp/x.csv", &["a", "b"], &[vec![1.0], vec![1.0, 2.0]]);
    }
}
