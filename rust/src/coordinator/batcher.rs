//! Batch assembly: fuse the pending evaluations of many requests —
//! sitting at *different* diffusion timesteps — into bucket-sized slabs
//! with per-row times, and route the model output back.
//!
//! Pure data-plumbing (no PJRT, no threads) so the packing policy is
//! unit- and property-testable: every row must come back to its request
//! exactly once, in order, regardless of how requests were split across
//! slabs.
//!
//! Zero-copy fast path: a slab whose rows are exactly one whole request
//! ships the request's own `Arc<Tensor>` ([`SlabX::Shared`]) — no row
//! copies at all, which is the common serving case at low concurrency.
//! Mixed slabs gather segments through the kernel layer: one contiguous
//! memcpy per request segment instead of one per row.

use std::sync::Arc;

use crate::kernels::fused;
use crate::solvers::{EvalRequest, UNCOND};
use crate::tensor::Tensor;

/// Dispatch policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard cap on rows per fused evaluation (≈ the top compiled batch
    /// bucket; bigger slabs would be split by the engine anyway and
    /// would blur the telemetry).
    pub max_rows: usize,
    /// Don't dispatch fewer than this many rows while more work may
    /// arrive within `max_wait` (latency/throughput trade-off).
    pub min_rows: usize,
    /// Longest a pending evaluation may wait for batch-mates.
    pub max_wait: std::time::Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_rows: 256,
            min_rows: 1,
            max_wait: std::time::Duration::from_millis(2),
        }
    }
}

/// One row-range of a slab belonging to one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SlabSegment {
    /// Index into the batcher's input list.
    pub source: usize,
    /// Row range inside the slab.
    pub start: usize,
    /// Absolute row offset of this segment inside the source request's
    /// pending evaluation. Reassembly scatters to this offset, which is
    /// what makes stitching independent of slab *completion* order — the
    /// pipelined scheduler may route a split request's slabs in any
    /// order (pinned by `prop_slab_completion_order_immaterial`).
    pub src_start: usize,
    pub rows: usize,
}

/// Slab input: shared view of a single request's tensor, or rows
/// gathered from several requests.
pub enum SlabX {
    /// A single whole request: the request's own iterate by refcount.
    Shared(Arc<Tensor>),
    /// Rows gathered (copied) from multiple requests / split requests.
    Packed(Tensor),
}

/// Per-row conditioning channel of a slab: a whole guided request ships
/// its trajectory-constant channel by refcount (the [`SlabX::Shared`]
/// twin — no per-step copy); mixed/split slabs gather a fresh vector.
pub enum SlabC {
    Shared(Arc<Vec<f32>>),
    Packed(Vec<f32>),
}

/// A fused evaluation: concatenated inputs plus per-row times and the
/// per-row conditioning channel (guided requests contribute paired
/// cond/uncond rows; unconditional rows carry [`UNCOND`]).
pub struct Slab {
    x: SlabX,
    pub t: Vec<f32>,
    /// Per-row conditioning channel, same length as `t`.
    c: SlabC,
    pub segments: Vec<SlabSegment>,
}

/// Reusable backing storage of one slab (and of the scheduler's
/// assembly tensors): the executor hands these back with every
/// completion and the scheduler's [`SlabRecycler`] feeds them into the
/// next `pack`, so the steady-state pipelined loop stops touching the
/// allocator once the free list is warm.
#[derive(Default)]
pub struct SlabBuffers {
    pub x: Vec<f32>,
    pub t: Vec<f32>,
    pub c: Vec<f32>,
    pub segments: Vec<SlabSegment>,
}

/// Bounded free lists for slab backing buffers and split-request
/// assembly tensors (shape-keyed). Owned by the scheduler thread — no
/// locking; buffers travel to executors inside jobs and come back
/// inside completions.
pub struct SlabRecycler {
    free: Vec<SlabBuffers>,
    assemblies: std::collections::BTreeMap<(usize, usize), Vec<Tensor>>,
    /// Tensors currently retained across all `assemblies` lists — the
    /// per-shape cap alone would let a workload cycling through many
    /// request shapes pin 16 tensors per shape forever.
    assembly_count: usize,
    buffer_allocs: usize,
}

/// Keep the lists bounded so a load spike cannot pin memory forever.
const MAX_FREE_BUFFERS: usize = 64;
const MAX_FREE_ASSEMBLIES_PER_SHAPE: usize = 16;
const MAX_FREE_ASSEMBLIES_TOTAL: usize = 64;

impl SlabRecycler {
    pub fn new() -> SlabRecycler {
        SlabRecycler {
            free: Vec::new(),
            assemblies: std::collections::BTreeMap::new(),
            assembly_count: 0,
            buffer_allocs: 0,
        }
    }

    /// Buffer sets handed out that required fresh allocation (steady
    /// state: stops growing once the pipeline's working set is warm).
    pub fn buffer_allocs(&self) -> usize {
        self.buffer_allocs
    }

    pub fn take_buffers(&mut self) -> SlabBuffers {
        match self.free.pop() {
            Some(b) => b,
            None => {
                self.buffer_allocs += 1;
                SlabBuffers::default()
            }
        }
    }

    pub fn give_buffers(&mut self, mut b: SlabBuffers) {
        if self.free.len() >= MAX_FREE_BUFFERS {
            return;
        }
        b.x.clear();
        b.t.clear();
        b.c.clear();
        b.segments.clear();
        self.free.push(b);
    }

    /// Assembly tensor for a split request's eps. Contents are
    /// unspecified — every row is scattered exactly once before the
    /// tensor is delivered (the scheduler asserts `filled == rows`).
    pub fn take_assembly(&mut self, rows: usize, cols: usize) -> Tensor {
        match self.assemblies.get_mut(&(rows, cols)).and_then(|v| v.pop()) {
            Some(t) => {
                self.assembly_count -= 1;
                t
            }
            None => Tensor::zeros(rows, cols),
        }
    }

    /// Return an assembly tensor that was never delivered (failed or
    /// cancelled request) for reuse.
    pub fn give_assembly(&mut self, t: Tensor) {
        if self.assembly_count >= MAX_FREE_ASSEMBLIES_TOTAL {
            return;
        }
        let key = (t.rows(), t.cols());
        let list = self.assemblies.entry(key).or_default();
        if list.len() < MAX_FREE_ASSEMBLIES_PER_SHAPE {
            list.push(t);
            self.assembly_count += 1;
        }
    }
}

impl Default for SlabRecycler {
    fn default() -> Self {
        SlabRecycler::new()
    }
}

impl Slab {
    /// The fused input tensor (either view resolves to `&Tensor`).
    pub fn x(&self) -> &Tensor {
        match &self.x {
            SlabX::Shared(arc) => arc,
            SlabX::Packed(t) => t,
        }
    }

    /// The per-row conditioning channel (either representation resolves
    /// to a slice aligned with `t`).
    pub fn c(&self) -> &[f32] {
        match &self.c {
            SlabC::Shared(arc) => arc,
            SlabC::Packed(v) => v,
        }
    }

    pub fn rows(&self) -> usize {
        self.x().rows()
    }

    /// True when this slab shipped a request tensor without copying.
    pub fn is_shared(&self) -> bool {
        matches!(self.x, SlabX::Shared(_))
    }

    /// Decompose a spent slab: the segments (for completion routing)
    /// and the recyclable backing buffers. Dropping the `Shared` arcs
    /// here — on the executor thread, *before* the completion is sent —
    /// is what keeps the solver's copy-on-write iterate refcount at one
    /// when the scheduler delivers, preserving the zero-alloc step.
    pub fn into_recycle(self) -> (Vec<SlabSegment>, SlabBuffers) {
        let x = match self.x {
            SlabX::Shared(_) => Vec::new(),
            SlabX::Packed(t) => t.into_vec(),
        };
        let c = match self.c {
            SlabC::Shared(_) => Vec::new(),
            SlabC::Packed(v) => v,
        };
        (self.segments, SlabBuffers { x, t: self.t, c, segments: Vec::new() })
    }
}

/// The full dispatch plan for one round.
pub struct BatchPlan {
    pub slabs: Vec<Slab>,
    /// Total rows packed this round.
    pub rows: usize,
}

/// Stateless batcher (state lives in the service loop; this is the
/// packing algorithm).
pub struct Batcher {
    pub policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy }
    }

    /// Pack pending evaluations (one per request, identified by index)
    /// into slabs of at most `max_rows` rows. Requests larger than
    /// `max_rows` are split across consecutive slabs. First-come
    /// first-packed; no reordering within a request.
    pub fn pack(&self, pending: &[(usize, &EvalRequest)]) -> BatchPlan {
        self.pack_recycled(pending, &mut SlabRecycler::new())
    }

    /// Like [`Batcher::pack`] but drawing slab backing buffers from a
    /// [`SlabRecycler`] — the pipelined scheduler's steady-state path,
    /// where every packed slab reuses the storage of a completed one.
    pub fn pack_recycled(
        &self,
        pending: &[(usize, &EvalRequest)],
        rec: &mut SlabRecycler,
    ) -> BatchPlan {
        let mut slabs: Vec<Slab> = Vec::new();
        let mut cur_rows: Vec<(usize, usize, usize)> = Vec::new(); // (source, row_off, n)
        let mut cur_count = 0usize;
        let mut total = 0usize;

        for &(idx, req) in pending {
            let mut off = 0;
            let rows = req.x.rows();
            while off < rows {
                let space = self.policy.max_rows - cur_count;
                if space == 0 {
                    flush_slab(pending, &mut cur_rows, &mut cur_count, &mut slabs, rec);
                    continue;
                }
                let take = space.min(rows - off);
                cur_rows.push((idx, off, take));
                cur_count += take;
                total += take;
                off += take;
            }
        }
        flush_slab(pending, &mut cur_rows, &mut cur_count, &mut slabs, rec);
        BatchPlan { slabs, rows: total }
    }

    /// Split one slab's model output back into per-source pieces,
    /// returned as `(source, eps_rows)` in segment order. Pieces of a
    /// split request arrive in row order and are stitched by the caller.
    /// (The service loop scatters directly into per-request buffers via
    /// [`fused::scatter_rows`]; this allocating form serves tests and
    /// external callers.)
    pub fn unpack(slab: &Slab, out: &Tensor) -> Vec<(usize, Tensor)> {
        assert_eq!(out.rows(), slab.rows(), "model output rows mismatch");
        slab.segments
            .iter()
            .map(|seg| (seg.source, out.slice_rows(seg.start, seg.rows)))
            .collect()
    }
}

/// Close out the accumulated `(source, row_off, n)` ranges as one slab.
fn flush_slab(
    pending: &[(usize, &EvalRequest)],
    cur: &mut Vec<(usize, usize, usize)>,
    count: &mut usize,
    slabs: &mut Vec<Slab>,
    rec: &mut SlabRecycler,
) {
    if cur.is_empty() {
        return;
    }
    let find = |src: usize| pending.iter().find(|(i, _)| *i == src).map(|(_, r)| *r).unwrap();
    // Zero-copy fast path: one segment covering one whole request ships
    // the request's Arc directly.
    if cur.len() == 1 {
        let (src, off, n) = cur[0];
        let req = find(src);
        if off == 0 && n == req.x.rows() {
            let mut b = rec.take_buffers();
            let mut t = std::mem::take(&mut b.t);
            t.resize(n, req.t as f32);
            let mut segments = std::mem::take(&mut b.segments);
            segments.push(SlabSegment { source: src, start: 0, src_start: 0, rows: n });
            let c = match &req.cond {
                // Trajectory-constant channel: refcount, not copy.
                Some(cond) => SlabC::Shared(Arc::clone(cond)),
                None => {
                    let mut c = std::mem::take(&mut b.c);
                    c.resize(n, UNCOND);
                    SlabC::Packed(c)
                }
            };
            // The unused members keep their capacity for the next slab.
            rec.give_buffers(b);
            slabs.push(Slab { x: SlabX::Shared(Arc::clone(&req.x)), t, c, segments });
            cur.clear();
            *count = 0;
            return;
        }
    }
    let dim = find(cur[0].0).x.cols();
    let mut b = rec.take_buffers();
    let mut x = std::mem::take(&mut b.x);
    let mut t = std::mem::take(&mut b.t);
    let mut c = std::mem::take(&mut b.c);
    let mut segments = std::mem::take(&mut b.segments);
    x.reserve(*count * dim);
    let mut at = 0usize;
    for &(src, off, n) in cur.iter() {
        let req = find(src);
        // One contiguous copy per segment (rows are adjacent in the
        // row-major layout).
        fused::gather_rows(&mut x, &req.x, off, n);
        t.resize(t.len() + n, req.t as f32);
        // The conditioning channel follows the same row split as the
        // tensor, so cond/uncond pairing is a pure function of row
        // order and survives any slab mix (pinned by the pairing
        // proptest).
        match &req.cond {
            Some(cond) => c.extend_from_slice(&cond[off..off + n]),
            None => c.resize(c.len() + n, UNCOND),
        }
        segments.push(SlabSegment { source: src, start: at, src_start: off, rows: n });
        at += n;
    }
    slabs.push(Slab {
        x: SlabX::Packed(Tensor::from_vec(x, *count, dim)),
        t,
        c: SlabC::Packed(c),
        segments,
    });
    cur.clear();
    *count = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(rows: usize, dim: usize, t: f64, fill: f32) -> EvalRequest {
        EvalRequest {
            x: Arc::new(Tensor::from_vec(vec![fill; rows * dim], rows, dim)),
            t,
            cond: None,
        }
    }

    /// A guided-style request: first half cond rows (class), second half
    /// uncond rows.
    fn paired_req(pairs: usize, dim: usize, t: f64, class: f32) -> EvalRequest {
        let rows = pairs * 2;
        let mut cond = vec![class; pairs];
        cond.resize(rows, crate::solvers::UNCOND);
        EvalRequest {
            x: Arc::new(Tensor::from_vec(vec![class; rows * dim], rows, dim)),
            t,
            cond: Some(Arc::new(cond)),
        }
    }

    fn batcher(max_rows: usize) -> Batcher {
        Batcher::new(BatchPolicy { max_rows, ..Default::default() })
    }

    #[test]
    fn packs_multiple_requests_into_one_slab() {
        let a = req(3, 2, 0.9, 1.0);
        let b = req(4, 2, 0.4, 2.0);
        let plan = batcher(16).pack(&[(0, &a), (1, &b)]);
        assert_eq!(plan.slabs.len(), 1);
        assert_eq!(plan.rows, 7);
        let slab = &plan.slabs[0];
        assert_eq!(slab.rows(), 7);
        assert!(!slab.is_shared(), "mixed slab must be packed");
        // Per-row times follow the owning request.
        assert_eq!(&slab.t[..3], &[0.9f32; 3]);
        assert_eq!(&slab.t[3..], &[0.4f32; 4]);
        assert_eq!(
            slab.segments,
            vec![
                SlabSegment { source: 0, start: 0, src_start: 0, rows: 3 },
                SlabSegment { source: 1, start: 3, src_start: 0, rows: 4 }
            ]
        );
    }

    #[test]
    fn single_whole_request_ships_shared_zero_copy() {
        let a = req(5, 3, 0.7, 1.5);
        let plan = batcher(16).pack(&[(3, &a)]);
        assert_eq!(plan.slabs.len(), 1);
        let slab = &plan.slabs[0];
        assert!(slab.is_shared(), "whole-request slab must not copy");
        // Same allocation, not an equal copy.
        assert!(std::ptr::eq(slab.x().as_slice().as_ptr(), a.x.as_slice().as_ptr()));
        assert_eq!(slab.t, vec![0.7f32; 5]);
        assert_eq!(
            slab.segments,
            vec![SlabSegment { source: 3, start: 0, src_start: 0, rows: 5 }]
        );
    }

    #[test]
    fn splits_at_max_rows() {
        let a = req(5, 2, 0.5, 1.0);
        let b = req(5, 2, 0.2, 2.0);
        let plan = batcher(6).pack(&[(0, &a), (1, &b)]);
        assert_eq!(plan.slabs.len(), 2);
        assert_eq!(plan.slabs[0].rows(), 6);
        assert_eq!(plan.slabs[1].rows(), 4);
        // b is split 1 + 4 across the slabs; neither slab is a single
        // whole request, so both gather.
        assert!(!plan.slabs[0].is_shared());
        assert!(!plan.slabs[1].is_shared());
        assert_eq!(
            plan.slabs[0].segments[1],
            SlabSegment { source: 1, start: 5, src_start: 0, rows: 1 }
        );
        assert_eq!(
            plan.slabs[1].segments[0],
            SlabSegment { source: 1, start: 0, src_start: 1, rows: 4 }
        );
    }

    #[test]
    fn giant_request_spans_slabs() {
        let a = req(20, 3, 0.7, 1.0);
        let plan = batcher(8).pack(&[(0, &a)]);
        assert_eq!(plan.slabs.len(), 3);
        let rows: usize = plan.slabs.iter().map(|s| s.rows()).sum();
        assert_eq!(rows, 20);
    }

    #[test]
    fn exactly_full_request_stays_shared() {
        // A request that exactly fills max_rows alone in its slab still
        // takes the zero-copy path.
        let a = req(8, 2, 0.6, 1.0);
        let plan = batcher(8).pack(&[(0, &a)]);
        assert_eq!(plan.slabs.len(), 1);
        assert!(plan.slabs[0].is_shared());
    }

    #[test]
    fn unpack_routes_rows_back() {
        let a = req(2, 2, 0.9, 1.0);
        let b = req(3, 2, 0.4, 2.0);
        let plan = batcher(16).pack(&[(7, &a), (9, &b)]);
        let slab = &plan.slabs[0];
        // Identity "model": eps = x.
        let outs = Batcher::unpack(slab, slab.x());
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].0, 7);
        assert_eq!(outs[0].1.as_slice(), a.x.as_slice());
        assert_eq!(outs[1].0, 9);
        assert_eq!(outs[1].1.as_slice(), b.x.as_slice());
    }

    #[test]
    fn cond_channel_routes_like_times() {
        // Mixed slab: an unconditional request and a paired request; the
        // per-row conditioning channel must follow each row exactly as
        // the per-row times do, across slab splits.
        let a = req(3, 2, 0.9, 1.0);
        let b = paired_req(2, 2, 0.4, 5.0);
        let plan = batcher(16).pack(&[(0, &a), (1, &b)]);
        assert_eq!(plan.slabs.len(), 1);
        let slab = &plan.slabs[0];
        assert_eq!(slab.c().len(), slab.t.len());
        assert_eq!(&slab.c()[..3], &[crate::solvers::UNCOND; 3]);
        assert_eq!(&slab.c()[3..5], &[5.0, 5.0]);
        assert_eq!(&slab.c()[5..], &[crate::solvers::UNCOND; 2]);

        // Shared fast path: a lone paired request ships its own channel
        // by refcount (same allocation, not an equal copy).
        let plan = batcher(16).pack(&[(0, &b)]);
        assert!(plan.slabs[0].is_shared());
        let cond = b.cond.as_ref().unwrap();
        assert!(std::ptr::eq(plan.slabs[0].c().as_ptr(), cond.as_ptr()));
        assert_eq!(
            plan.slabs[0].c(),
            &[5.0, 5.0, crate::solvers::UNCOND, crate::solvers::UNCOND]
        );

        // Split across slabs: the channel splits at the same rows.
        let plan = batcher(3).pack(&[(0, &b)]);
        assert_eq!(plan.slabs.len(), 2);
        assert_eq!(plan.slabs[0].c(), &[5.0, 5.0, crate::solvers::UNCOND]);
        assert_eq!(plan.slabs[1].c(), &[crate::solvers::UNCOND]);
    }

    #[test]
    fn src_start_walks_the_source_request() {
        // A request split across slabs carries its absolute row offset
        // in every segment, so reassembly needs no completion order.
        let a = req(20, 3, 0.7, 1.0);
        let plan = batcher(8).pack(&[(0, &a)]);
        let offs: Vec<usize> = plan
            .slabs
            .iter()
            .flat_map(|s| s.segments.iter().map(|seg| seg.src_start))
            .collect();
        assert_eq!(offs, vec![0, 8, 16]);
    }

    #[test]
    fn recycler_stops_allocating_once_warm() {
        let a = req(5, 2, 0.5, 1.0);
        let b = req(5, 2, 0.2, 2.0);
        let mut rec = SlabRecycler::new();
        let mut warm_allocs = 0;
        for round in 0..4 {
            let plan = batcher(6).pack_recycled(&[(0, &a), (1, &b)], &mut rec);
            assert_eq!(plan.slabs.len(), 2);
            for slab in plan.slabs {
                let (_segments, bufs) = slab.into_recycle();
                rec.give_buffers(bufs);
            }
            if round == 0 {
                warm_allocs = rec.buffer_allocs();
            }
        }
        assert_eq!(
            rec.buffer_allocs(),
            warm_allocs,
            "steady-state packing must reuse the free list"
        );
    }

    #[test]
    fn recycler_assemblies_are_shape_keyed() {
        let mut rec = SlabRecycler::new();
        let t = rec.take_assembly(4, 2);
        assert_eq!((t.rows(), t.cols()), (4, 2));
        rec.give_assembly(t);
        let again = rec.take_assembly(4, 2);
        assert_eq!((again.rows(), again.cols()), (4, 2));
        let other = rec.take_assembly(3, 5);
        assert_eq!((other.rows(), other.cols()), (3, 5));
    }

    #[test]
    fn recycler_assembly_retention_is_bounded_across_shapes() {
        // A workload cycling through many request shapes must not pin
        // tensors without bound: the total cap holds across shapes.
        let mut rec = SlabRecycler::new();
        for shape in 0..200usize {
            rec.give_assembly(Tensor::zeros(shape + 1, 2));
        }
        assert_eq!(rec.assembly_count, super::MAX_FREE_ASSEMBLIES_TOTAL);
        // Takes release budget for later gives.
        let _ = rec.take_assembly(1, 2);
        rec.give_assembly(Tensor::zeros(500, 2));
        assert_eq!(rec.assembly_count, super::MAX_FREE_ASSEMBLIES_TOTAL);
    }

    #[test]
    fn into_recycle_returns_packed_backing() {
        let a = req(3, 2, 0.9, 1.0);
        let b = req(4, 2, 0.4, 2.0);
        let plan = batcher(16).pack(&[(0, &a), (1, &b)]);
        let slab = plan.slabs.into_iter().next().unwrap();
        assert!(!slab.is_shared());
        let (segments, bufs) = slab.into_recycle();
        assert_eq!(segments.len(), 2);
        assert_eq!(bufs.x.len(), 7 * 2);
        assert_eq!(bufs.t.len(), 7);
        assert_eq!(bufs.c.len(), 7);

        // A shared slab surrenders its refcounts and keeps the t buffer.
        let plan = batcher(16).pack(&[(0, &a)]);
        let slab = plan.slabs.into_iter().next().unwrap();
        assert!(slab.is_shared());
        let (segments, bufs) = slab.into_recycle();
        assert_eq!(segments[0].src_start, 0);
        assert!(bufs.x.is_empty());
        assert_eq!(bufs.t.len(), 3);
    }

    #[test]
    fn empty_pack_is_empty() {
        let plan = batcher(8).pack(&[]);
        assert_eq!(plan.slabs.len(), 0);
        assert_eq!(plan.rows, 0);
    }

    #[test]
    fn rows_conserved_many_shapes() {
        // Property-style sweep: total packed rows always equals input
        // rows and every segment maps to exactly one source range.
        for max_rows in [1usize, 3, 7, 16, 64] {
            let reqs: Vec<EvalRequest> = (1..8).map(|i| req(i * 2 + 1, 2, 0.5, i as f32)).collect();
            let pending: Vec<(usize, &EvalRequest)> = reqs.iter().enumerate().collect();
            let plan = batcher(max_rows).pack(&pending);
            let want: usize = reqs.iter().map(|r| r.x.rows()).sum();
            assert_eq!(plan.rows, want);
            let mut per_source = vec![0usize; reqs.len()];
            for slab in &plan.slabs {
                assert!(slab.rows() <= max_rows);
                let seg_rows: usize = slab.segments.iter().map(|s| s.rows).sum();
                assert_eq!(seg_rows, slab.rows());
                for seg in &slab.segments {
                    per_source[seg.source] += seg.rows;
                }
            }
            for (i, r) in reqs.iter().enumerate() {
                assert_eq!(per_source[i], r.x.rows(), "source {i} at max_rows {max_rows}");
            }
        }
    }
}
