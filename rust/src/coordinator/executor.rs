//! The engine-executor pool behind one coordinator shard.
//!
//! The pipelined shard splits the old single loop thread into an
//! event-driven **scheduler** (`service::run_loop`) and `E` **executor**
//! threads spawned here. Executors pull packed [`Slab`]s from a bounded
//! job queue, run them through their own [`ModelBank`] handle (a
//! [`BankSet`] replica), and send sequence-numbered [`SlabCompletion`]s
//! back — so the scheduler keeps admitting, sweeping cancellations,
//! stepping solvers, and packing the next slabs while evaluations are
//! in flight, and one shard can drive several engine replicas at once.
//!
//! Two contracts matter for correctness:
//!
//! * the executor drops the slab's input buffers (including any
//!   zero-copy `Arc<Tensor>` of a request iterate) **before** sending
//!   the completion, so by the time the scheduler delivers the eps the
//!   solver's copy-on-write refcount is back to one — the zero-alloc
//!   steady state of `bench_step_overhead` survives pipelining;
//! * a model output whose row count does not match the slab is a
//!   **per-slab error**, not a panic: it fails only that slab's
//!   requests through the scheduler's failure path and the shard keeps
//!   serving (previously an `assert_eq!` poisoned the whole loop
//!   thread and every batch-mate with it).

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::batcher::{Slab, SlabBuffers, SlabSegment};
use crate::coordinator::service::ModelBank;
use crate::coordinator::telemetry::Telemetry;
use crate::runtime::resident::{ResidentOp, ResidentOutcome};
use crate::tensor::Tensor;

/// The model-bank replicas available to one shard's executors.
///
/// Generalizes `WorkerPool::start_with_banks`: engine replicas can now
/// live *within* a shard (one per executor thread) as well as across
/// shards. A set of one shared handle is the common case — `MockBank`
/// is stateless and `PjRtEngine` serialises internally — while
/// per-executor replicas let E executors drive E devices.
#[derive(Clone)]
pub struct BankSet {
    banks: Vec<Arc<dyn ModelBank>>,
}

impl BankSet {
    /// A set over explicit replicas (one per executor; executors beyond
    /// `banks.len()` share, round-robin).
    pub fn new(banks: Vec<Arc<dyn ModelBank>>) -> BankSet {
        assert!(!banks.is_empty(), "bank set needs at least one bank");
        BankSet { banks }
    }

    /// The common case: every executor shares one bank handle.
    pub fn shared(bank: Arc<dyn ModelBank>) -> BankSet {
        BankSet { banks: vec![bank] }
    }

    pub fn len(&self) -> usize {
        self.banks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.banks.is_empty() // construction forbids it; here for clippy symmetry
    }

    /// The bank the scheduler consults for admission-time metadata
    /// (schedule, dims, conditional support). All replicas of a set
    /// must agree on these.
    pub fn primary(&self) -> &Arc<dyn ModelBank> {
        &self.banks[0]
    }

    /// The bank executor `i` owns (round-robin over replicas).
    pub fn for_executor(&self, i: usize) -> Arc<dyn ModelBank> {
        self.banks[i % self.banks.len()].clone()
    }
}

/// What one executor job carries: a packed slab for the classic
/// ship-the-tensors path, or a resident-lane op (coefficients only —
/// the iterate and eps history stay engine-side; see
/// [`crate::runtime::resident`]).
pub enum JobPayload {
    Eval(Slab),
    Resident {
        /// Scheduler lane index the op belongs to (routing key — the
        /// completion's synthetic segment points back at it).
        lane: usize,
        /// Engine-side resident-lane handle.
        handle: u64,
        /// Rows the lane holds (for telemetry and flight bookkeeping).
        rows: usize,
        op: ResidentOp,
    },
}

/// One job on its way to an executor.
pub struct SlabJob {
    /// Monotone per-shard dispatch sequence number.
    pub seq: u64,
    /// Dispatch round (one scheduler pack cycle) this slab belongs to;
    /// the scheduler caps in-flight rounds at `pipeline_depth`.
    pub round: u64,
    /// Shared dataset-name handle (one allocation per dataset group
    /// per round; per-slab copies are refcount bumps).
    pub dataset: Arc<str>,
    pub payload: JobPayload,
}

/// A completed job's output.
pub enum SlabOutput {
    /// Full eps tensor of an evaluated slab.
    Eps(Tensor),
    /// Scalars of a resident-lane op (row distances; final iterate
    /// only on finish).
    Resident(ResidentOutcome),
}

/// An executed job on its way back to the scheduler. Carries
/// everything routing needs so the scheduler never touches the bank.
pub struct SlabCompletion {
    pub seq: u64,
    pub round: u64,
    /// Index of the executor thread that evaluated the slab (the `i` of
    /// `era-executor-{i}`) — surfaced in the flight recorder's
    /// slab-completion spans.
    pub executor: usize,
    /// The slab's segments (with absolute `src_start` offsets), moved
    /// out of the slab so reassembly survives out-of-order delivery.
    /// A resident op completes with one synthetic whole-lane segment.
    pub segments: Vec<SlabSegment>,
    /// Rows the slab carried.
    pub rows: usize,
    /// Rows the engine actually executed (bucket padding telemetry).
    pub executed_rows: usize,
    /// Wall nanoseconds inside the model evaluation.
    pub eval_nanos: u64,
    /// The job's output (eps row count already validated), or the
    /// per-slab error that fails only this slab's requests.
    pub result: Result<SlabOutput, String>,
    /// Recyclable backing buffers of the spent slab (empty for
    /// resident ops, which carry no tensors).
    pub buffers: SlabBuffers,
}

/// Handle to a shard's executor threads. Dropping the job sender (via
/// [`ExecutorPool::shutdown`]) stops them once the queue drains.
pub struct ExecutorPool {
    jobs: SyncSender<SlabJob>,
    handles: Vec<JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `count` executors over the bank set. `queue_cap` bounds
    /// the job queue (the completion channel is unbounded, so a full
    /// job queue can only ever stall the scheduler, never deadlock it).
    pub fn spawn(
        banks: &BankSet,
        count: usize,
        queue_cap: usize,
        completions: Sender<SlabCompletion>,
        tele: Arc<Telemetry>,
    ) -> ExecutorPool {
        let count = count.max(1);
        let (tx, rx) = sync_channel::<SlabJob>(queue_cap.max(1));
        let shared_rx = Arc::new(Mutex::new(rx));
        let handles = (0..count)
            .map(|i| {
                let bank = banks.for_executor(i);
                let rx = shared_rx.clone();
                let completions = completions.clone();
                let tele = tele.clone();
                std::thread::Builder::new()
                    .name(format!("era-executor-{i}"))
                    .spawn(move || executor_loop(i, bank, rx, completions, tele))
                    .expect("spawn executor")
            })
            .collect();
        ExecutorPool { jobs: tx, handles }
    }

    /// Queue one slab for evaluation; blocks when the queue is full.
    /// Returns false only when every executor has exited.
    pub fn dispatch(&self, job: SlabJob) -> bool {
        self.jobs.send(job).is_ok()
    }

    /// Close the queue and join the executors (in-flight slabs finish
    /// and their completions are delivered first).
    pub fn shutdown(self) {
        drop(self.jobs);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn executor_loop(
    executor: usize,
    bank: Arc<dyn ModelBank>,
    jobs: Arc<Mutex<Receiver<SlabJob>>>,
    completions: Sender<SlabCompletion>,
    tele: Arc<Telemetry>,
) {
    loop {
        let idle0 = Instant::now();
        // Classic shared-receiver worker: the lock is held only while
        // this thread is the one blocked on recv; the next waiter takes
        // the mutex as soon as a job is handed out.
        let job = match jobs.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => break,
        };
        tele.executor_idle_nanos
            .fetch_add(idle0.elapsed().as_nanos() as u64, std::sync::atomic::Ordering::Relaxed);
        let job = match job {
            Ok(j) => j,
            Err(_) => break, // queue closed: shard is shutting down
        };

        let busy0 = Instant::now();
        let completion = match job.payload {
            JobPayload::Eval(slab) => {
                let rows = slab.rows();
                // A panicking bank must not kill the executor thread: an
                // unsent completion would wedge the slab's requests forever
                // (sweep/finalize wait for inflight_slabs == 0). Contain it
                // to a per-slab error like any other evaluation failure.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    bank.eval_cond(&job.dataset, slab.x(), &slab.t, slab.c())
                }))
                .unwrap_or_else(|_| Err("model evaluation panicked".into()));
                let eval_nanos = busy0.elapsed().as_nanos() as u64;
                // Row-count contract with the engine: a silent mismatch would
                // truncate or misalign eps rows. Fail the slab, not the shard.
                let result = out.and_then(|o| {
                    if o.rows() == rows {
                        Ok(SlabOutput::Eps(o))
                    } else {
                        Err(format!("model returned {} rows for a {rows}-row slab", o.rows()))
                    }
                });
                let executed_rows = bank.executed_rows(rows);
                // Surrender the slab's input refcounts *before* the
                // completion becomes visible (see module docs).
                let (segments, buffers) = slab.into_recycle();
                SlabCompletion {
                    seq: job.seq,
                    round: job.round,
                    executor,
                    segments,
                    rows,
                    executed_rows,
                    eval_nanos,
                    result,
                    buffers,
                }
            }
            JobPayload::Resident { lane, handle, rows, op } => {
                // Same containment contract as the eval path: a panic
                // inside the engine op must come back as a per-op error.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    match bank.resident() {
                        Some(rs) => rs.exec(handle, &op),
                        None => Err("bank exposes no resident state".into()),
                    }
                }))
                .unwrap_or_else(|_| Err("resident op panicked".into()));
                let eval_nanos = busy0.elapsed().as_nanos() as u64;
                SlabCompletion {
                    seq: job.seq,
                    round: job.round,
                    executor,
                    segments: vec![SlabSegment { source: lane, start: 0, src_start: 0, rows }],
                    rows,
                    executed_rows: bank.executed_rows(rows),
                    eval_nanos,
                    result: out.map(SlabOutput::Resident),
                    buffers: SlabBuffers::default(),
                }
            }
        };
        tele.executor_busy_nanos
            .fetch_add(busy0.elapsed().as_nanos() as u64, std::sync::atomic::Ordering::Relaxed);
        if completions.send(completion).is_err() {
            break; // scheduler gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{BatchPolicy, Batcher};
    use crate::coordinator::MockBank;
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::schedule::VpSchedule;
    use crate::solvers::EvalRequest;

    fn bank() -> Arc<dyn ModelBank> {
        let sched = VpSchedule::default();
        Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))))
    }

    fn eval_req(rows: usize, t: f64) -> EvalRequest {
        let mut v = Vec::with_capacity(rows * 2);
        for r in 0..rows {
            v.push(r as f32);
            v.push(t as f32);
        }
        EvalRequest { x: Arc::new(Tensor::from_vec(v, rows, 2)), t, cond: None }
    }

    #[test]
    fn bank_set_cycles_replicas() {
        let set = BankSet::new(vec![bank(), bank()]);
        assert_eq!(set.len(), 2);
        assert!(Arc::ptr_eq(&set.for_executor(0), &set.for_executor(2)));
        assert!(Arc::ptr_eq(&set.for_executor(1), &set.for_executor(3)));
        assert!(!Arc::ptr_eq(&set.for_executor(0), &set.for_executor(1)));
        let shared = BankSet::shared(bank());
        assert!(Arc::ptr_eq(&shared.for_executor(0), &shared.for_executor(7)));
    }

    #[test]
    fn executors_evaluate_and_complete_out_of_band() {
        let tele = Arc::new(Telemetry::new());
        let (ctx, crx) = std::sync::mpsc::channel();
        let pool = ExecutorPool::spawn(&BankSet::shared(bank()), 2, 8, ctx, tele.clone());
        let reqs: Vec<EvalRequest> = (0..3).map(|i| eval_req(4, 0.5 + 0.1 * i as f64)).collect();
        let batcher = Batcher::new(BatchPolicy { max_rows: 4, ..Default::default() });
        for (seq, req) in reqs.iter().enumerate() {
            let plan = batcher.pack(&[(seq, req)]);
            for slab in plan.slabs {
                assert!(pool.dispatch(SlabJob {
                    seq: seq as u64,
                    round: 0,
                    dataset: "gmm8".into(),
                    payload: JobPayload::Eval(slab),
                }));
            }
        }
        let mut seen = Vec::new();
        for _ in 0..3 {
            let c = crx.recv().expect("completion");
            assert_eq!(c.rows, 4);
            let SlabOutput::Eps(out) = c.result.expect("eval ok") else {
                panic!("eval job must complete with an eps tensor");
            };
            assert_eq!(out.rows(), 4);
            seen.push(c.seq);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        pool.shutdown();
        assert!(tele.executor_busy_nanos.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    #[test]
    fn unknown_dataset_is_a_slab_error_not_a_panic() {
        let tele = Arc::new(Telemetry::new());
        let (ctx, crx) = std::sync::mpsc::channel();
        let pool = ExecutorPool::spawn(&BankSet::shared(bank()), 1, 2, ctx, tele);
        let req = eval_req(2, 0.5);
        let plan = Batcher::new(BatchPolicy::default()).pack(&[(0, &req)]);
        for slab in plan.slabs {
            pool.dispatch(SlabJob {
                seq: 0,
                round: 0,
                dataset: "nope".into(),
                payload: JobPayload::Eval(slab),
            });
        }
        let c = crx.recv().expect("completion");
        assert!(c.result.is_err());
        pool.shutdown();
    }

    #[test]
    fn resident_ops_run_through_the_pool() {
        use crate::runtime::resident::{ResidentState, ResidentStep};

        let tele = Arc::new(Telemetry::new());
        let (ctx, crx) = std::sync::mpsc::channel();
        let sched = VpSchedule::default();
        let bank: Arc<MockBank> = Arc::new(
            MockBank::new(sched)
                .with("gmm8", Box::new(AnalyticGmm::gmm8(sched)))
                .with_residency(),
        );
        let pool = ExecutorPool::spawn(&BankSet::shared(bank.clone()), 1, 2, ctx, tele);
        let x = Tensor::from_vec(vec![0.3; 8], 4, 2);
        let handle = bank.open("gmm8", &x, false).expect("open resident lane");
        let op = ResidentOp::Step(ResidentStep { pre: None, t: 0.6, post: None });
        assert!(pool.dispatch(SlabJob {
            seq: 9,
            round: 1,
            dataset: "gmm8".into(),
            payload: JobPayload::Resident { lane: 5, handle, rows: 4, op },
        }));
        let c = crx.recv().expect("completion");
        assert_eq!((c.rows, c.seq, c.round), (4, 9, 1));
        assert_eq!(c.segments, vec![SlabSegment { source: 5, start: 0, src_start: 0, rows: 4 }]);
        let SlabOutput::Resident(out) = c.result.expect("resident op ok") else {
            panic!("resident job must complete with a resident outcome");
        };
        assert_eq!((out.handle, out.rows), (handle, 4));
        assert!(out.final_x.is_none());
        // A bank without resident support fails the op, not the shard.
        let plain: Arc<dyn ModelBank> =
            Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
        let (ctx2, crx2) = std::sync::mpsc::channel();
        let tele2 = Arc::new(Telemetry::new());
        let pool2 = ExecutorPool::spawn(&BankSet::shared(plain), 1, 2, ctx2, tele2);
        let op = ResidentOp::Step(ResidentStep { pre: None, t: 0.6, post: None });
        pool2.dispatch(SlabJob {
            seq: 0,
            round: 0,
            dataset: "gmm8".into(),
            payload: JobPayload::Resident { lane: 0, handle: 1, rows: 4, op },
        });
        assert!(crx2.recv().expect("completion").result.is_err());
        pool2.shutdown();
        pool.shutdown();
    }
}
