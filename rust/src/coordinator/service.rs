//! The coordinator engine loop: admission with backpressure, round-based
//! continuous batching, and the public [`Coordinator`] handle.
//!
//! One dedicated loop thread owns every [`RequestState`]. Each round it
//! (1) admits queued requests up to `max_active`, (2) retires requests
//! whose [`CancelHandle`] fired or whose deadline expired (mid-trajectory,
//! without touching batch-mates), (3) pulls the next evaluation from every
//! active solver, (4) optionally lingers up to `max_wait` for batch-mates
//! when under `min_rows`, (5) packs all pending evaluations *per dataset*
//! into slabs and runs them through the [`ModelBank`], (6) routes outputs
//! back and retires finished requests. Requests join and leave the
//! running batch at step granularity — continuous batching in the vLLM
//! sense, applied to diffusion sampling.
//!
//! A [`crate::pool::WorkerPool`] runs N of these loops as shards behind
//! one router; the `inflight_*` telemetry gauges updated here are what
//! its least-loaded placement and global admission control read.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatchPolicy};
use crate::coordinator::request::{RequestSpec, RequestState, SamplingResult};
use crate::coordinator::telemetry::Telemetry;
use crate::kernels::{fused, PlanCache};
use crate::runtime::PjRtEngine;
use crate::solvers::schedule::VpSchedule;
use crate::solvers::EpsModel;
use crate::tensor::Tensor;

/// What the loop evaluates against: a named family of denoisers.
/// Implemented by [`PjRtEngine`] (production) and [`MockBank`] (tests,
/// in-process benches).
pub trait ModelBank: Send + Sync {
    fn sched(&self) -> VpSchedule;
    fn dim(&self, dataset: &str) -> Result<usize, String>;
    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String>;
    /// Conditional evaluation with a per-row class channel `c` (rows
    /// `< 0` unconditional — [`crate::solvers::UNCOND`]). Banks without
    /// conditional heads may ignore the channel; the loop always routes
    /// through this method so guided rows reach conditional banks.
    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        let _ = c;
        self.eval(dataset, x, t)
    }
    /// True when `dataset`'s denoiser honours conditioned rows. Guided
    /// requests against a bank that answers false are rejected at
    /// admission — never allowed into a fused slab, where a conditional
    /// failure would take unconditional batch-mates down with it.
    fn supports_cond(&self, dataset: &str) -> bool {
        let _ = dataset;
        true
    }
    /// Rows the engine would actually execute for a slab of `rows`
    /// (bucket rounding), for padding telemetry.
    fn executed_rows(&self, rows: usize) -> usize {
        rows
    }
}

impl ModelBank for PjRtEngine {
    fn sched(&self) -> VpSchedule {
        self.manifest().schedule
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        Ok(self.dataset(dataset)?.dim)
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        self.eval_eps(dataset, x, t)
    }

    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        // Defence in depth: admission already rejects guided requests
        // against this bank (supports_cond = false); a conditioned row
        // reaching a slab anyway is a routing bug, and failing loudly
        // beats silently sampling the unconditional model under a
        // guidance scale.
        if c.iter().any(|&v| v >= 0.0) {
            return Err(format!("dataset '{dataset}' has no conditional denoiser artifact"));
        }
        self.eval_eps(dataset, x, t)
    }

    /// The AOT artifacts carry no conditional head yet.
    fn supports_cond(&self, _dataset: &str) -> bool {
        false
    }

    fn executed_rows(&self, rows: usize) -> usize {
        self.manifest().bucket_for(rows).max(rows)
    }
}

/// Test/bench bank over in-process [`EpsModel`]s.
pub struct MockBank {
    sched: VpSchedule,
    models: BTreeMap<String, Box<dyn EpsModel>>,
}

impl MockBank {
    pub fn new(sched: VpSchedule) -> Self {
        MockBank { sched, models: BTreeMap::new() }
    }

    pub fn with(mut self, name: &str, model: Box<dyn EpsModel>) -> Self {
        self.models.insert(name.to_string(), model);
        self
    }
}

impl ModelBank for MockBank {
    fn sched(&self) -> VpSchedule {
        self.sched
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        self.models
            .get(dataset)
            .map(|m| m.dim())
            .ok_or_else(|| format!("unknown dataset '{dataset}'"))
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        let m = self.models.get(dataset).ok_or_else(|| format!("unknown dataset '{dataset}'"))?;
        Ok(m.eval(x, t))
    }

    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        let m = self.models.get(dataset).ok_or_else(|| format!("unknown dataset '{dataset}'"))?;
        Ok(m.eval_cond(x, t, c))
    }
}

/// Coordinator construction knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max requests stepped concurrently (the running batch).
    pub max_active: usize,
    /// Admission queue bound; submits beyond this are rejected
    /// immediately (backpressure surfaces to the client).
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
    /// Deadline applied to requests whose spec carries none
    /// (`None` = requests without their own deadline never expire).
    pub default_deadline: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_active: 32,
            queue_capacity: 256,
            policy: BatchPolicy::default(),
            default_deadline: None,
        }
    }
}

/// Cooperative cancellation flag shared by the client handle and the
/// shard loop. Cancelling is a one-way latch: the loop retires the
/// request at its next round boundary (between solver steps), replies
/// with the partial iterate, and batch-mates are untouched.
#[derive(Clone, Debug, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    pub fn new() -> CancelHandle {
        CancelHandle(Arc::new(AtomicBool::new(false)))
    }

    /// Request cancellation. Idempotent; safe after completion.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// True when both handles latch the same request (same underlying
    /// flag). The pool's tag registry uses this to avoid evicting a
    /// *different* request's registration when a tag is reused.
    pub fn same_as(&self, other: &CancelHandle) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

/// Why a submit failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full — shed load upstream.
    QueueFull,
    /// Coordinator is shutting down.
    Shutdown,
    /// Request invalid (unknown solver/dataset, bad budget, ...).
    Invalid(String),
}

struct Envelope {
    id: u64,
    spec: RequestSpec,
    reply: Sender<Result<SamplingResult, String>>,
    cancel: CancelHandle,
    deadline: Option<Instant>,
}

/// Handle to a running coordinator. Cloneable submits are not needed —
/// the handle itself is `Sync` (submit takes `&self`).
pub struct Coordinator {
    tx: Option<SyncSender<Envelope>>,
    telemetry: Arc<Telemetry>,
    plans: Arc<PlanCache>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    handle: Option<JoinHandle<()>>,
}

/// A pending response.
pub struct Ticket {
    pub id: u64,
    rx: Receiver<Result<SamplingResult, String>>,
    cancel: CancelHandle,
}

impl Ticket {
    /// Block until the request finishes.
    pub fn wait(self) -> Result<SamplingResult, String> {
        self.rx.recv().map_err(|_| "coordinator dropped request".to_string())?
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<Result<SamplingResult, String>> {
        self.rx.recv_timeout(d).ok()
    }

    /// Ask the loop to retire this request at its next round boundary.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clonable handle for cancelling from another thread (the pool's
    /// tag registry hands these to `cancel` protocol ops).
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }
}

impl Coordinator {
    /// Spawn the engine loop over a model bank (private plan cache).
    pub fn start(bank: Arc<dyn ModelBank>, config: CoordinatorConfig) -> Self {
        Coordinator::start_with_plans(bank, config, Arc::new(PlanCache::new()))
    }

    /// Spawn the engine loop sharing an external [`PlanCache`] — the
    /// pool hands every shard the same cache so trajectory plans are
    /// computed once per configuration across the whole deployment.
    pub fn start_with_plans(
        bank: Arc<dyn ModelBank>,
        config: CoordinatorConfig,
        plans: Arc<PlanCache>,
    ) -> Self {
        let telemetry = Arc::new(Telemetry::new());
        let (tx, rx) = sync_channel::<Envelope>(config.queue_capacity);
        let tele = telemetry.clone();
        let loop_plans = plans.clone();
        let default_deadline = config.default_deadline;
        let handle = std::thread::Builder::new()
            .name("era-coordinator".into())
            .spawn(move || run_loop(bank, config, rx, tele, loop_plans))
            .expect("spawn coordinator");
        Coordinator {
            tx: Some(tx),
            telemetry,
            plans,
            next_id: AtomicU64::new(1),
            default_deadline,
            handle: Some(handle),
        }
    }

    /// The trajectory-plan cache this coordinator admits requests with.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// Validate cheaply and enqueue; returns a ticket for the reply.
    pub fn submit(&self, spec: RequestSpec) -> Result<Ticket, SubmitError> {
        self.submit_with_cancel(spec, CancelHandle::new())
    }

    /// Like [`Coordinator::submit`] but adopting a caller-created
    /// [`CancelHandle`] — the pool registers the handle in its tag
    /// registry *before* the envelope becomes visible to the loop, so a
    /// wire-level cancel can never miss an already-admitted request.
    pub fn submit_with_cancel(
        &self,
        spec: RequestSpec,
        cancel: CancelHandle,
    ) -> Result<Ticket, SubmitError> {
        if crate::solvers::SolverKind::parse(&spec.solver).is_none() {
            return Err(SubmitError::Invalid(format!("unknown solver '{}'", spec.solver)));
        }
        let tx = self.tx.as_ref().ok_or(SubmitError::Shutdown)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let deadline = spec
            .deadline_ms
            .map(Duration::from_millis)
            .or(self.default_deadline)
            .map(|d| Instant::now() + d);
        // Guided requests pin paired rows: admission control, the pool's
        // global cap and least-loaded placement all see the real eval
        // row mass, not the sample count.
        let rows = spec.admission_rows();
        // Gauge up before the envelope becomes visible to the loop so
        // the loop's retire-side decrement can never race it negative.
        self.telemetry.inflight_requests.fetch_add(1, Ordering::SeqCst);
        self.telemetry.inflight_rows.fetch_add(rows, Ordering::SeqCst);
        let env = Envelope { id, spec, reply: reply_tx, cancel: cancel.clone(), deadline };
        match tx.try_send(env) {
            Ok(()) => Ok(Ticket { id, rx: reply_rx, cancel }),
            Err(TrySendError::Full(_)) => {
                self.telemetry.inflight_requests.fetch_sub(1, Ordering::SeqCst);
                self.telemetry.inflight_rows.fetch_sub(rows, Ordering::SeqCst);
                self.telemetry.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.telemetry.inflight_requests.fetch_sub(1, Ordering::SeqCst);
                self.telemetry.inflight_rows.fetch_sub(rows, Ordering::SeqCst);
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn sample(&self, spec: RequestSpec) -> Result<SamplingResult, String> {
        self.submit(spec).map_err(|e| format!("{e:?}"))?.wait()
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Stop accepting work, drain in-flight requests, join the loop.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Active {
    state: RequestState,
    reply: Sender<Result<SamplingResult, String>>,
    cancel: CancelHandle,
    deadline: Option<Instant>,
    /// Rows this request pinned in the inflight gauges at submit.
    rows: usize,
}

/// Retire a request with a result (normal completion or cancellation),
/// releasing its inflight gauges.
fn retire_ok(done: Active, tele: &Telemetry, cancelled: bool) {
    let rows = done.rows;
    let mut res = done.state.finish();
    res.cancelled = cancelled;
    if cancelled {
        tele.requests_cancelled.fetch_add(1, Ordering::Relaxed);
    } else {
        tele.record_finish(res.total_seconds, res.queue_seconds);
    }
    tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
    tele.inflight_rows.fetch_sub(rows, Ordering::SeqCst);
    let _ = done.reply.send(Ok(res));
}

/// Retire a request with an error, releasing its inflight gauges.
fn retire_err(done: Active, tele: &Telemetry, err: String) {
    tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
    tele.inflight_rows.fetch_sub(done.rows, Ordering::SeqCst);
    let _ = done.reply.send(Err(err));
}

fn run_loop(
    bank: Arc<dyn ModelBank>,
    config: CoordinatorConfig,
    rx: Receiver<Envelope>,
    tele: Arc<Telemetry>,
    plans: Arc<PlanCache>,
) {
    let batcher = Batcher::new(config.policy);
    let mut active: Vec<Active> = Vec::new();
    let mut queue_open = true;

    let admit = |env: Envelope, active: &mut Vec<Active>, tele: &Telemetry| {
        // Requests cancelled (or expired) while still queued never cost
        // a solver build or an evaluation.
        let dead_on_arrival = env.cancel.is_cancelled()
            || env.deadline.is_some_and(|d| Instant::now() >= d);
        if dead_on_arrival {
            tele.requests_cancelled.fetch_add(1, Ordering::Relaxed);
            tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
            tele.inflight_rows.fetch_sub(env.spec.admission_rows(), Ordering::SeqCst);
            let _ = env.reply.send(Ok(SamplingResult {
                id: env.id,
                samples: Tensor::zeros(0, 0),
                nfe: 0,
                queue_seconds: 0.0,
                total_seconds: 0.0,
                cancelled: true,
            }));
            return;
        }
        let sched = bank.sched();
        let solver = if env.spec.task.is_guided() && !bank.supports_cond(&env.spec.dataset) {
            // Known-unservable at admission: a guided request must never
            // enter a fused slab whose conditional evaluation would fail
            // and retire unconditional batch-mates along with it.
            Err(format!(
                "dataset '{}' has no conditional denoiser; guided sampling unavailable",
                env.spec.dataset
            ))
        } else {
            bank.dim(&env.spec.dataset)
                .and_then(|dim| env.spec.build_solver_with_plans(sched, dim, &plans))
        };
        match solver {
            Ok(s) => {
                tele.requests_admitted.fetch_add(1, Ordering::Relaxed);
                if env.spec.task.is_guided() {
                    tele.guided_requests.fetch_add(1, Ordering::Relaxed);
                }
                if env.spec.task.is_img2img() {
                    tele.img2img_requests.fetch_add(1, Ordering::Relaxed);
                }
                if env.spec.task.is_stochastic() {
                    tele.stochastic_requests.fetch_add(1, Ordering::Relaxed);
                }
                active.push(Active {
                    rows: env.spec.admission_rows(),
                    state: RequestState::new(env.id, env.spec.dataset.clone(), s),
                    reply: env.reply,
                    cancel: env.cancel,
                    deadline: env.deadline,
                });
            }
            Err(e) => {
                tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
                tele.inflight_rows.fetch_sub(env.spec.admission_rows(), Ordering::SeqCst);
                let _ = env.reply.send(Err(e));
            }
        }
    };

    'outer: loop {
        // ---- Admission ----
        while queue_open && active.len() < config.max_active {
            match rx.try_recv() {
                Ok(env) => admit(env, &mut active, &tele),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    queue_open = false;
                    break;
                }
            }
        }
        if active.is_empty() {
            if !queue_open {
                break 'outer; // drained and closed: exit
            }
            // Idle: block for work.
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(env) => {
                    admit(env, &mut active, &tele);
                    continue;
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    queue_open = false;
                    continue;
                }
            }
        }

        tele.rounds.fetch_add(1, Ordering::Relaxed);

        // ---- Cancellation / deadline sweep ----
        // Round boundaries are the cancellation points: every pending
        // eval from the previous round has been delivered, so a retired
        // solver leaves no orphan rows in any slab and batch-mates are
        // untouched.
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            let expired = active[i].cancel.is_cancelled()
                || active[i].deadline.is_some_and(|d| now >= d);
            if expired && active[i].state.pending.is_none() {
                let done = active.swap_remove(i);
                retire_ok(done, &tele, true);
                continue;
            }
            i += 1;
        }

        // ---- Pull next evaluations; retire finished solvers ----
        let mut i = 0;
        while i < active.len() {
            let has_pending = active[i].state.pending.is_some();
            if !has_pending && !active[i].state.pull() {
                let done = active.swap_remove(i);
                retire_ok(done, &tele, false);
                continue;
            }
            i += 1;
        }
        if active.is_empty() {
            continue;
        }

        // ---- Linger under min_rows (max_wait policy) ----
        let pending_rows: usize = active.iter().map(|a| a.state.pending_rows()).sum();
        if pending_rows < config.policy.min_rows && queue_open {
            let deadline = Instant::now() + config.policy.max_wait;
            while Instant::now() < deadline && active.len() < config.max_active {
                let left = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(left) {
                    Ok(env) => {
                        let before = active.len();
                        admit(env, &mut active, &tele);
                        if active.len() == before {
                            continue; // rejected or dead on arrival
                        }
                        // New arrivals join this round immediately.
                        let n = active.len();
                        if !active[n - 1].state.pull() {
                            let done = active.swap_remove(n - 1);
                            retire_ok(done, &tele, false);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        queue_open = false;
                        break;
                    }
                }
            }
        }

        // ---- Pack per dataset and dispatch ----
        let mut by_dataset: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (idx, a) in active.iter().enumerate() {
            if a.state.pending.is_some() {
                by_dataset.entry(a.state.dataset.as_str()).or_default().push(idx);
            }
        }
        // Assemble each request's eps directly from slab outputs
        // (`source -> (buffer, rows filled)`): a single whole-request
        // slab adopts the engine output tensor outright; split requests
        // scatter each segment into one preallocated buffer — no
        // intermediate slices, no vstack.
        let mut assembled: BTreeMap<usize, (Tensor, usize)> = BTreeMap::new();
        let mut failures: Vec<(usize, String)> = Vec::new();
        for (dataset, idxs) in by_dataset {
            let pending: Vec<(usize, &crate::solvers::EvalRequest)> = idxs
                .iter()
                .map(|&i| (i, active[i].state.pending.as_ref().unwrap()))
                .collect();
            let plan = batcher.pack(&pending);
            for slab in &plan.slabs {
                let t0 = Instant::now();
                match bank.eval_cond(dataset, slab.x(), &slab.t, slab.c()) {
                    Ok(out) => {
                        // Row-count contract with the engine: a silent
                        // mismatch would truncate or misalign eps rows.
                        assert_eq!(out.rows(), slab.rows(), "model output rows mismatch");
                        tele.eval_nanos
                            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        tele.evals.fetch_add(1, Ordering::Relaxed);
                        tele.rows.fetch_add(slab.rows(), Ordering::Relaxed);
                        tele.padded_rows.fetch_add(
                            bank.executed_rows(slab.rows()) - slab.rows(),
                            Ordering::Relaxed,
                        );
                        let whole = slab.segments.len() == 1
                            && slab.segments[0].start == 0
                            && slab.segments[0].rows
                                == active[slab.segments[0].source].state.pending_rows()
                            && !assembled.contains_key(&slab.segments[0].source);
                        if whole {
                            let seg = &slab.segments[0];
                            assembled.insert(seg.source, (out, seg.rows));
                        } else {
                            for seg in &slab.segments {
                                let total = active[seg.source].state.pending_rows();
                                let entry = assembled.entry(seg.source).or_insert_with(|| {
                                    (Tensor::zeros(total, out.cols()), 0)
                                });
                                fused::scatter_rows(
                                    &mut entry.0,
                                    entry.1,
                                    &out,
                                    seg.start,
                                    seg.rows,
                                );
                                entry.1 += seg.rows;
                            }
                        }
                    }
                    Err(e) => {
                        for seg in &slab.segments {
                            failures.push((seg.source, e.clone()));
                        }
                    }
                }
            }
        }

        // ---- Route assembled outputs back ----
        // Requests with any failed slab are retired below, not delivered
        // (a partial assembly would feed a truncated eps to the solver).
        let failed_srcs: BTreeSet<usize> = failures.iter().map(|f| f.0).collect();
        for (src, (eps, filled)) in assembled {
            if failed_srcs.contains(&src) {
                continue;
            }
            debug_assert_eq!(filled, eps.rows(), "request assembly incomplete");
            tele.steps.fetch_add(1, Ordering::Relaxed);
            active[src].state.deliver(eps);
        }

        // ---- Fail requests whose evaluation errored (reverse index order
        //      keeps earlier indices stable under swap_remove) ----
        failures.sort_by(|a, b| b.0.cmp(&a.0));
        failures.dedup_by_key(|f| f.0);
        for (src, err) in failures {
            let failed = active.swap_remove(src);
            retire_err(failed, &tele, format!("model evaluation failed: {err}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::solvers::eps_model::AnalyticGmm;

    fn bank() -> Arc<dyn ModelBank> {
        let sched = VpSchedule::default();
        Arc::new(
            MockBank::new(sched)
                .with("gmm8", Box::new(AnalyticGmm::gmm8(sched)))
                .with("gmm8b", Box::new(AnalyticGmm::gmm8(sched))),
        )
    }

    fn spec(solver: &str, n: usize, seed: u64) -> RequestSpec {
        RequestSpec {
            solver: solver.into(),
            n_samples: n,
            nfe: 10,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let res = c.sample(spec("era", 32, 1)).unwrap();
        assert_eq!(res.samples.rows(), 32);
        assert_eq!(res.nfe, 10);
        assert!(res.samples.all_finite());
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let cfg = CoordinatorConfig {
            policy: BatchPolicy { max_rows: 256, min_rows: 64, max_wait: Duration::from_millis(30) },
            ..Default::default()
        };
        let c = Coordinator::start(bank(), cfg);
        let tickets: Vec<_> =
            (0..8).map(|i| c.submit(spec("era", 16, i)).unwrap()).collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert_eq!(r.samples.rows(), 16);
        }
        // 8 requests x 16 rows with min_rows 64 must have fused: strictly
        // fewer evals than 8 requests x 10 steps separately.
        let evals = c.telemetry().evals.load(Ordering::Relaxed);
        assert!(evals < 80, "no fusion happened: {evals} evals");
        assert!(c.telemetry().mean_batch_occupancy() > 16.0);
        c.shutdown();
    }

    #[test]
    fn mixed_solvers_and_datasets() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let t1 = c.submit(spec("era", 8, 1)).unwrap();
        let t2 = c.submit(spec("ddim", 8, 2)).unwrap();
        let mut s3 = spec("dpm-2", 8, 3);
        s3.dataset = "gmm8b".into();
        let t3 = c.submit(s3).unwrap();
        for t in [t1, t2, t3] {
            assert!(t.wait().is_ok());
        }
        c.shutdown();
    }

    #[test]
    fn invalid_solver_rejected_at_submit() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        match c.submit(spec("frobnicate", 4, 0)) {
            Err(SubmitError::Invalid(_)) => {}
            Err(e) => panic!("expected Invalid, got {e:?}"),
            Ok(_) => panic!("expected Invalid, got Ok"),
        }
        c.shutdown();
    }

    #[test]
    fn unknown_dataset_fails_via_reply() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 4, 0);
        s.dataset = "nope".into();
        let t = c.submit(s).unwrap();
        assert!(t.wait().is_err());
        c.shutdown();
    }

    #[test]
    fn bad_budget_fails_via_reply() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("pndm", 4, 0);
        s.nfe = 5; // below PRK warmup minimum
        match c.submit(s) {
            Ok(t) => assert!(t.wait().is_err()),
            Err(SubmitError::Invalid(_)) => {}
            Err(e) => panic!("unexpected {e:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn guided_request_matches_inprocess_guided_run() {
        // The paired-row serving path (slab cond channel, guided_combine
        // after reassembly) must equal driving the guided solver stack
        // directly against the same model.
        let sched = VpSchedule::default();
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 16, 4);
        s.task = crate::solvers::TaskSpec {
            guidance_scale: 2.0,
            guide_class: 2,
            ..Default::default()
        };
        let via_coord = c.sample(s.clone()).unwrap();
        assert_eq!(via_coord.samples.rows(), 16);
        assert_eq!(via_coord.nfe, 20, "10 paired steps = 20 evaluations");
        c.shutdown();

        let model = AnalyticGmm::gmm8(sched);
        let mut solver = s.build_solver(sched, 2).unwrap();
        let direct = crate::solvers::sample_with(&mut *solver, &model);
        assert_eq!(via_coord.samples.as_slice(), direct.as_slice());
    }

    #[test]
    fn guided_request_rejected_when_bank_has_no_conditional_head() {
        // A bank without a conditional head (PjRtEngine's situation)
        // must refuse guided requests at admission with a clear error,
        // and an unconditional batch-mate submitted alongside must be
        // completely unaffected.
        struct UncondOnly(MockBank);
        impl ModelBank for UncondOnly {
            fn sched(&self) -> VpSchedule {
                self.0.sched()
            }
            fn dim(&self, dataset: &str) -> Result<usize, String> {
                self.0.dim(dataset)
            }
            fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
                self.0.eval(dataset, x, t)
            }
            fn supports_cond(&self, _dataset: &str) -> bool {
                false
            }
        }
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> = Arc::new(UncondOnly(
            MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
        ));
        let c = Coordinator::start(bank, CoordinatorConfig::default());
        let mut guided = spec("era", 8, 1);
        guided.task = crate::solvers::TaskSpec { guidance_scale: 2.0, ..Default::default() };
        let gt = c.submit(guided).unwrap();
        let plain = c.submit(spec("era", 8, 2)).unwrap();
        let err = gt.wait().expect_err("guided must be refused");
        assert!(err.contains("no conditional denoiser"), "{err}");
        let ok = plain.wait().unwrap();
        assert!(!ok.cancelled);
        assert_eq!(ok.nfe, 10);
        // Gauges drain despite the rejection.
        assert_eq!(c.telemetry().inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn guided_scale_zero_is_the_unconditional_path() {
        // scale 0 must not wrap, not double rows, and reproduce the
        // plain trajectory bitwise.
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 8, 5);
        s.task = crate::solvers::TaskSpec { guidance_scale: 0.0, ..Default::default() };
        let guided_zero = c.sample(s).unwrap();
        let plain = c.sample(spec("era", 8, 5)).unwrap();
        assert_eq!(guided_zero.samples.as_slice(), plain.samples.as_slice());
        assert_eq!(guided_zero.nfe, plain.nfe);
        c.shutdown();
    }

    #[test]
    fn results_match_inprocess_sampling() {
        // The coordinator path must be numerically identical to driving
        // the solver directly (same seed, same model).
        let sched = VpSchedule::default();
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let s = spec("era", 64, 9);
        let via_coord = c.sample(s.clone()).unwrap();
        c.shutdown();

        let model = AnalyticGmm::gmm8(sched);
        let mut solver = s.build_solver(sched, 2).unwrap();
        let direct = crate::solvers::sample_with(&mut *solver, &model);
        assert_eq!(via_coord.samples.as_slice(), direct.as_slice());
    }

    #[test]
    fn identical_requests_share_one_trajectory_plan() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        for seed in 0..3 {
            let _ = c.sample(spec("era", 16, seed)).unwrap();
        }
        // One configuration -> one plan build, later requests hit.
        assert_eq!(c.plan_cache().misses(), 1);
        assert_eq!(c.plan_cache().hits(), 2);
        assert_eq!(c.plan_cache().len(), 1);
        // A different solver kind is its own plan.
        let _ = c.sample(spec("ddim", 16, 0)).unwrap();
        assert_eq!(c.plan_cache().len(), 2);
        c.shutdown();
    }

    #[test]
    fn samples_are_on_manifold() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let res = c.sample(spec("era", 400, 11)).unwrap();
        let cov = metrics::mode_coverage(&res.samples, &crate::data::gmm8_modes(), 0.5);
        assert!(cov > 0.9, "coverage {cov}");
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue + tiny active set: flooding must yield QueueFull.
        let cfg = CoordinatorConfig { max_active: 1, queue_capacity: 1, ..Default::default() };
        let c = Coordinator::start(bank(), cfg);
        let mut rejected = 0;
        let mut tickets = Vec::new();
        for i in 0..200 {
            match c.submit(spec("era", 64, i)) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected > 0, "queue never filled");
        for t in tickets {
            let _ = t.wait();
        }
        c.shutdown();
    }

    #[test]
    fn zero_deadline_cancels_before_start() {
        // A deadline that is already expired at submit must retire the
        // request at admission: no solver build, no evaluations.
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 32, 1);
        s.deadline_ms = Some(0);
        let res = c.submit(s).unwrap().wait().unwrap();
        assert!(res.cancelled);
        assert_eq!(res.nfe, 0);
        assert_eq!(res.samples.rows(), 0);
        let t = c.telemetry();
        assert_eq!(t.requests_cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(t.requests_admitted.load(Ordering::Relaxed), 0);
        // Gauges must drain back to zero.
        assert_eq!(t.inflight_requests.load(Ordering::Relaxed), 0);
        assert_eq!(t.inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn default_deadline_applies_when_spec_has_none() {
        let cfg = CoordinatorConfig {
            default_deadline: Some(Duration::from_millis(0)),
            ..Default::default()
        };
        let c = Coordinator::start(bank(), cfg);
        let res = c.sample(spec("era", 8, 1)).unwrap();
        assert!(res.cancelled);
        assert_eq!(res.nfe, 0);
        c.shutdown();
    }

    #[test]
    fn cancel_after_completion_is_harmless() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let ticket = c.submit(spec("era", 16, 3)).unwrap();
        let handle = ticket.cancel_handle();
        let res = ticket.wait().unwrap();
        assert!(!res.cancelled);
        assert_eq!(res.nfe, 10);
        handle.cancel(); // latched after the fact; nothing to retire
        assert!(handle.is_cancelled());
        c.shutdown();
    }

    #[test]
    fn inflight_gauges_return_to_zero() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let tickets: Vec<_> = (0..4).map(|i| c.submit(spec("era", 8, i)).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(c.telemetry().inflight_requests.load(Ordering::Relaxed), 0);
        assert_eq!(c.telemetry().inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_inflight() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let tickets: Vec<_> = (0..4).map(|i| c.submit(spec("ddim", 32, i)).unwrap()).collect();
        c.shutdown(); // must drain, not drop
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn telemetry_counts_line_up() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        for i in 0..3 {
            let _ = c.sample(spec("era", 8, i)).unwrap();
        }
        let t = c.telemetry();
        assert_eq!(t.requests_admitted.load(Ordering::Relaxed), 3);
        assert_eq!(t.requests_finished.load(Ordering::Relaxed), 3);
        assert!(t.evals.load(Ordering::Relaxed) >= 10);
        assert!(t.summary().contains("finished=3"));
        c.shutdown();
    }
}
