//! The coordinator shard: an event-driven **scheduler** thread feeding
//! a pool of **engine executors**, plus the public [`Coordinator`]
//! handle.
//!
//! The scheduler thread owns a [`LaneEngine`]: admitted requests live
//! as members of batch-major **lanes** (struct-of-arrays solver state,
//! keyed by dataset/solver/plan/workload shape — see
//! [`crate::solvers::lanes`]) instead of per-request boxed solvers, so
//! one lane step advances every co-resident request with single fused
//! passes. Each tick the scheduler (1) routes slab completions
//! arriving from the executors (scattering split segments to absolute
//! offsets, so completion order is immaterial), (2) admits queued
//! requests up to `max_active` — same-tick identical configurations
//! fuse into one lane, (3) sweeps cancellations/deadlines — compacting
//! any member no in-flight slab references out of its lane,
//! mid-trajectory, without perturbing batch-mates' bits, (4) pulls the
//! next evaluation from every idle lane (splitting lanes whose ERA
//! selections diverge), (5) optionally lingers up to `max_wait` for
//! batch-mates when under `min_rows` (the wait stays
//! cancellation-aware), and (6) packs ready lane evaluations *per
//! dataset* into slabs — a whole lane is one zero-copy segment — and
//! dispatches them to the executor pool
//! ([`crate::coordinator::executor`]). Up to `pipeline_depth` dispatch
//! rounds stay in flight, so admission, lane stepping, and packing
//! overlap engine execution, and a shard with `executors_per_shard >
//! 1` evaluates several slabs concurrently. Requests join and leave
//! the running batch at step granularity — continuous batching in the
//! vLLM sense, applied to diffusion sampling.
//!
//! A [`crate::pool::WorkerPool`] runs N of these shards behind one
//! router; the `inflight_*` telemetry gauges updated here are what its
//! least-loaded placement and global admission control read.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatchPolicy, SlabRecycler};
use crate::coordinator::executor::{
    BankSet, ExecutorPool, JobPayload, SlabCompletion, SlabJob, SlabOutput,
};
use crate::coordinator::request::{QosClass, RequestSpec, SamplingResult};
use crate::coordinator::telemetry::Telemetry;
use crate::kernels::{fused, PlanCache};
use crate::obs::trace::pack_bases;
use crate::obs::{FlightRecorder, SpanKind};
use crate::runtime::resident::{self, ResidentOutcome, ResidentState};
use crate::runtime::PjRtEngine;
use crate::solvers::lanes::{LaneEngine, Removed, ResidentCmd};
use crate::solvers::schedule::VpSchedule;
use crate::solvers::{EpsModel, EvalRequest};
use crate::tensor::Tensor;

/// What the loop evaluates against: a named family of denoisers.
/// Implemented by [`PjRtEngine`] (production) and [`MockBank`] (tests,
/// in-process benches).
pub trait ModelBank: Send + Sync {
    fn sched(&self) -> VpSchedule;
    fn dim(&self, dataset: &str) -> Result<usize, String>;
    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String>;
    /// Conditional evaluation with a per-row class channel `c` (rows
    /// `< 0` unconditional — [`crate::solvers::UNCOND`]). Banks without
    /// conditional heads may ignore the channel; the loop always routes
    /// through this method so guided rows reach conditional banks.
    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        let _ = c;
        self.eval(dataset, x, t)
    }
    /// True when `dataset`'s denoiser honours conditioned rows. Guided
    /// requests against a bank that answers false are rejected at
    /// admission — never allowed into a fused slab, where a conditional
    /// failure would take unconditional batch-mates down with it.
    fn supports_cond(&self, dataset: &str) -> bool {
        let _ = dataset;
        true
    }
    /// Rows the engine would actually execute for a slab of `rows`
    /// (bucket rounding), for padding telemetry.
    fn executed_rows(&self, rows: usize) -> usize {
        rows
    }
    /// The bank's device-resident lane store, when it has one (see
    /// [`crate::runtime::resident`]). `None` keeps every lane on the
    /// ship-the-tensors slab path.
    fn resident(&self) -> Option<&dyn ResidentState> {
        None
    }
}

impl ModelBank for PjRtEngine {
    fn sched(&self) -> VpSchedule {
        self.manifest().schedule
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        Ok(self.dataset(dataset)?.dim)
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        self.eval_eps(dataset, x, t)
    }

    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        // Defence in depth: admission already rejects guided requests
        // against this bank (supports_cond = false); a conditioned row
        // reaching a slab anyway is a routing bug, and failing loudly
        // beats silently sampling the unconditional model under a
        // guidance scale.
        if c.iter().any(|&v| v >= 0.0) {
            return Err(format!("dataset '{dataset}' has no conditional denoiser artifact"));
        }
        self.eval_eps(dataset, x, t)
    }

    /// The AOT artifacts carry no conditional head yet.
    fn supports_cond(&self, _dataset: &str) -> bool {
        false
    }

    fn executed_rows(&self, rows: usize) -> usize {
        self.manifest().bucket_for(rows).max(rows)
    }

    fn resident(&self) -> Option<&dyn ResidentState> {
        Some(self)
    }
}

/// Test/bench bank over in-process [`EpsModel`]s.
pub struct MockBank {
    sched: VpSchedule,
    models: BTreeMap<String, Box<dyn EpsModel>>,
    /// Opt-in resident-lane store, mirroring `PjRtEngine`'s. Kept off
    /// by default so existing tests exercise the pure slab path.
    resident: Option<crate::runtime::ResidentTable>,
}

impl MockBank {
    pub fn new(sched: VpSchedule) -> Self {
        MockBank { sched, models: BTreeMap::new(), resident: None }
    }

    pub fn with(mut self, name: &str, model: Box<dyn EpsModel>) -> Self {
        self.models.insert(name.to_string(), model);
        self
    }

    /// Enable the resident-lane store (the mock twin of the engine's
    /// device residency).
    pub fn with_residency(mut self) -> Self {
        self.resident = Some(crate::runtime::ResidentTable::new());
        self
    }
}

impl ResidentState for MockBank {
    fn open(&self, dataset: &str, x: &Tensor, keep_history: bool) -> Result<u64, String> {
        let table = self.resident.as_ref().ok_or("mock bank residency disabled")?;
        self.dim(dataset)?;
        Ok(table.open(dataset, x, keep_history))
    }

    fn exec(&self, handle: u64, op: &resident::ResidentOp) -> Result<ResidentOutcome, String> {
        let table = self.resident.as_ref().ok_or("mock bank residency disabled")?;
        table.exec(handle, op, |ds, x, t| ModelBank::eval(self, ds, x, t))
    }

    fn snapshot(&self, handle: u64) -> Result<resident::ResidentSnapshot, String> {
        let table = self.resident.as_ref().ok_or("mock bank residency disabled")?;
        table.snapshot(handle)
    }

    fn close(&self, handle: u64) {
        if let Some(table) = self.resident.as_ref() {
            table.close(handle);
        }
    }
}

impl ModelBank for MockBank {
    fn sched(&self) -> VpSchedule {
        self.sched
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        self.models
            .get(dataset)
            .map(|m| m.dim())
            .ok_or_else(|| format!("unknown dataset '{dataset}'"))
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        let m = self.models.get(dataset).ok_or_else(|| format!("unknown dataset '{dataset}'"))?;
        Ok(m.eval(x, t))
    }

    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        let m = self.models.get(dataset).ok_or_else(|| format!("unknown dataset '{dataset}'"))?;
        Ok(m.eval_cond(x, t, c))
    }

    fn resident(&self) -> Option<&dyn ResidentState> {
        self.resident.as_ref().map(|_| self as &dyn ResidentState)
    }
}

/// Coordinator construction knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max requests stepped concurrently (the running batch).
    pub max_active: usize,
    /// Admission queue bound; submits beyond this are rejected
    /// immediately (backpressure surfaces to the client).
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
    /// Deadline applied to requests whose spec carries none
    /// (`None` = requests without their own deadline never expire).
    pub default_deadline: Option<Duration>,
    /// Engine-executor threads per shard (>= 1). Each executor owns a
    /// [`crate::coordinator::executor::BankSet`] replica handle, so a
    /// shard with E executors can evaluate E slabs concurrently.
    pub executors_per_shard: usize,
    /// Max dispatch rounds in flight (>= 1). Depth 1 reproduces the
    /// old serialized pack→eval→route cycle exactly; deeper pipelines
    /// overlap host-side scheduling with engine execution.
    pub pipeline_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_active: 32,
            queue_capacity: 256,
            policy: BatchPolicy::default(),
            default_deadline: None,
            executors_per_shard: 1,
            pipeline_depth: 1,
        }
    }
}

/// Cooperative cancellation flag shared by the client handle and the
/// shard scheduler. Cancelling is a one-way latch: the scheduler
/// retires the request as soon as no in-flight slab references it
/// (within the current tick when idle; on the final slab completion
/// when one is out, whose output is then dropped undelivered), replies
/// with the partial iterate, and batch-mates are untouched.
#[derive(Clone, Debug, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    pub fn new() -> CancelHandle {
        CancelHandle(Arc::new(AtomicBool::new(false)))
    }

    /// Request cancellation. Idempotent; safe after completion.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// True when both handles latch the same request (same underlying
    /// flag). The pool's tag registry uses this to avoid evicting a
    /// *different* request's registration when a tag is reused.
    pub fn same_as(&self, other: &CancelHandle) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

/// Why a submit failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full — shed load upstream.
    QueueFull,
    /// Coordinator is shutting down.
    Shutdown,
    /// Request invalid (unknown solver/dataset, bad budget, ...).
    Invalid(String),
}

/// Completion callback attached to a submit: invoked (on the loop
/// thread) *after* the reply value is placed in the ticket's channel,
/// so a `try_result` issued from the callback always observes it. The
/// readiness gateway uses this to wake its event loop instead of
/// parking a thread per request.
pub type CompletionNotify = Arc<dyn Fn() + Send + Sync>;

/// The loop's side of a ticket: the reply channel plus the optional
/// completion notification. `send` delivers first, then notifies —
/// and notifies even when the receiver is gone, so an event loop that
/// dropped a connection's tickets still drains its wake queue.
struct ReplySink {
    tx: Sender<Result<SamplingResult, String>>,
    notify: Option<CompletionNotify>,
}

impl ReplySink {
    fn new(tx: Sender<Result<SamplingResult, String>>, notify: Option<CompletionNotify>) -> Self {
        ReplySink { tx, notify }
    }

    fn send(
        &self,
        value: Result<SamplingResult, String>,
    ) -> Result<(), std::sync::mpsc::SendError<Result<SamplingResult, String>>> {
        let out = self.tx.send(value);
        if let Some(notify) = &self.notify {
            notify();
        }
        out
    }
}

struct Envelope {
    id: u64,
    spec: RequestSpec,
    reply: ReplySink,
    cancel: CancelHandle,
    deadline: Option<Instant>,
}

/// Handle to a running coordinator. Cloneable submits are not needed —
/// the handle itself is `Sync` (submit takes `&self`).
pub struct Coordinator {
    tx: Option<SyncSender<Envelope>>,
    telemetry: Arc<Telemetry>,
    recorder: Arc<FlightRecorder>,
    plans: Arc<PlanCache>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    handle: Option<JoinHandle<()>>,
}

/// A pending response.
pub struct Ticket {
    pub id: u64,
    rx: Receiver<Result<SamplingResult, String>>,
    cancel: CancelHandle,
}

impl Ticket {
    /// Block until the request finishes.
    pub fn wait(self) -> Result<SamplingResult, String> {
        self.rx.recv().map_err(|_| "coordinator dropped request".to_string())?
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<Result<SamplingResult, String>> {
        self.rx.recv_timeout(d).ok()
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    /// After a [`CompletionNotify`] callback fired for this ticket the
    /// result is guaranteed present (the loop sends before notifying).
    pub fn try_result(&self) -> Option<Result<SamplingResult, String>> {
        match self.rx.try_recv() {
            Ok(out) => Some(out),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err("coordinator dropped request".to_string()))
            }
        }
    }

    /// Ask the scheduler to retire this request as soon as no in-flight
    /// slab references it.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clonable handle for cancelling from another thread (the pool's
    /// tag registry hands these to `cancel` protocol ops).
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }
}

impl Coordinator {
    /// Spawn the engine loop over a model bank (private plan cache).
    pub fn start(bank: Arc<dyn ModelBank>, config: CoordinatorConfig) -> Self {
        Coordinator::start_with_plans(bank, config, Arc::new(PlanCache::new()))
    }

    /// Spawn the engine loop sharing an external [`PlanCache`] — the
    /// pool hands every shard the same cache so trajectory plans are
    /// computed once per configuration across the whole deployment.
    /// Every executor of the shard shares the one `bank` handle.
    pub fn start_with_plans(
        bank: Arc<dyn ModelBank>,
        config: CoordinatorConfig,
        plans: Arc<PlanCache>,
    ) -> Self {
        Coordinator::start_with_bank_set(BankSet::shared(bank), config, plans)
    }

    /// Spawn the scheduler + executor pool over an explicit [`BankSet`]
    /// — per-executor engine replicas *within* the shard (executors
    /// beyond the set's length share round-robin).
    pub fn start_with_bank_set(
        banks: BankSet,
        config: CoordinatorConfig,
        plans: Arc<PlanCache>,
    ) -> Self {
        let telemetry = Arc::new(Telemetry::new());
        let recorder = Arc::new(FlightRecorder::new());
        let (tx, rx) = sync_channel::<Envelope>(config.queue_capacity);
        let tele = telemetry.clone();
        let rec = recorder.clone();
        let loop_plans = plans.clone();
        let default_deadline = config.default_deadline;
        let handle = std::thread::Builder::new()
            .name("era-coordinator".into())
            .spawn(move || run_loop(banks, config, rx, tele, rec, loop_plans))
            .expect("spawn coordinator");
        Coordinator {
            tx: Some(tx),
            telemetry,
            recorder,
            plans,
            next_id: AtomicU64::new(1),
            default_deadline,
            handle: Some(handle),
        }
    }

    /// The trajectory-plan cache this coordinator admits requests with.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// Validate cheaply and enqueue; returns a ticket for the reply.
    pub fn submit(&self, spec: RequestSpec) -> Result<Ticket, SubmitError> {
        self.submit_with_cancel(spec, CancelHandle::new())
    }

    /// Like [`Coordinator::submit`] but adopting a caller-created
    /// [`CancelHandle`] — the pool registers the handle in its tag
    /// registry *before* the envelope becomes visible to the loop, so a
    /// wire-level cancel can never miss an already-admitted request.
    pub fn submit_with_cancel(
        &self,
        spec: RequestSpec,
        cancel: CancelHandle,
    ) -> Result<Ticket, SubmitError> {
        self.submit_with_cancel_notify(spec, cancel, None)
    }

    /// Like [`Coordinator::submit_with_cancel`] with an additional
    /// completion callback: `notify` runs on the loop thread right
    /// after the reply lands in the ticket, making the ticket pollable
    /// via [`Ticket::try_result`] without a blocked thread per request.
    pub fn submit_with_cancel_notify(
        &self,
        spec: RequestSpec,
        cancel: CancelHandle,
        notify: Option<CompletionNotify>,
    ) -> Result<Ticket, SubmitError> {
        if crate::solvers::SolverKind::parse(&spec.solver).is_none() {
            return Err(SubmitError::Invalid(format!("unknown solver '{}'", spec.solver)));
        }
        let tx = self.tx.as_ref().ok_or(SubmitError::Shutdown)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let reply_tx = ReplySink::new(reply_tx, notify);
        let deadline = spec
            .deadline_ms
            .map(Duration::from_millis)
            .or(self.default_deadline)
            .map(|d| Instant::now() + d);
        // Guided requests pin paired rows: admission control, the pool's
        // global cap and least-loaded placement all see the real eval
        // row mass, not the sample count.
        let rows = spec.admission_rows();
        // Gauge up before the envelope becomes visible to the loop so
        // the loop's retire-side decrement can never race it negative.
        self.telemetry.inflight_requests.fetch_add(1, Ordering::SeqCst);
        self.telemetry.inflight_rows.fetch_add(rows, Ordering::SeqCst);
        let env = Envelope { id, spec, reply: reply_tx, cancel: cancel.clone(), deadline };
        match tx.try_send(env) {
            Ok(()) => Ok(Ticket { id, rx: reply_rx, cancel }),
            Err(TrySendError::Full(_)) => {
                self.telemetry.inflight_requests.fetch_sub(1, Ordering::SeqCst);
                self.telemetry.inflight_rows.fetch_sub(rows, Ordering::SeqCst);
                self.telemetry.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.telemetry.inflight_requests.fetch_sub(1, Ordering::SeqCst);
                self.telemetry.inflight_rows.fetch_sub(rows, Ordering::SeqCst);
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn sample(&self, spec: RequestSpec) -> Result<SamplingResult, String> {
        self.submit(spec).map_err(|e| format!("{e:?}"))?.wait()
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// This shard's flight recorder. A [`Ticket`]'s `id` is the trace
    /// id: `recorder().snapshot_trace(ticket.id)` replays the request's
    /// lifecycle (admission → queue wait → lane attach → per-step
    /// solver/slab/ERA spans → finalize or cancel).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Stop accepting work, drain in-flight requests, join the loop.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-request bookkeeping held by the scheduler: the reply channel and
/// retirement metadata. The solver state itself lives in the shard's
/// [`LaneEngine`] — admission inserts requests into batch-major lanes
/// instead of building boxed solvers, and one lane step advances every
/// member request with single fused passes over the stacked rows (see
/// [`crate::solvers::lanes`]).
struct Active {
    id: u64,
    reply: ReplySink,
    cancel: CancelHandle,
    deadline: Option<Instant>,
    /// Rows this request pinned in the inflight gauges at submit.
    rows: usize,
    submitted_at: Instant,
    /// First time the owning lane stepped (queue-wait boundary).
    started_at: Option<Instant>,
    /// QoS class: drives deadline-pressure degradation in the sweep.
    qos: QosClass,
    /// Degradation latched (at pool admission or under deadline
    /// pressure): counted once, and the lane member heads for its
    /// NFE floor.
    degraded: bool,
}

/// Per-lane dispatch bookkeeping, parallel to the engine's lane table.
/// While `inflight_slabs > 0` the lane must stay intact: the
/// cancellation point is "no in-flight slab references the lane".
#[derive(Default)]
struct Flight {
    /// Slabs of the lane's dispatched evaluation still at executors.
    inflight_slabs: usize,
    /// Rows of the dispatched evaluation.
    expect_rows: usize,
    /// Reassembly buffer: `(eps, rows filled)`. Whole-lane slabs adopt
    /// the engine output outright; split lanes scatter each completed
    /// segment to its absolute `src_start` offset, so completion order
    /// is immaterial.
    assembly: Option<(Tensor, usize)>,
    /// First slab error of the dispatched evaluation, if any. A
    /// partially failed evaluation is never delivered.
    failed: Option<String>,
    /// Completed resident op awaiting finalize (resident lanes ship
    /// one op per round instead of a slab).
    resident: Option<ResidentOutcome>,
}

/// The scheduler's request/lane tables and pipeline bookkeeping.
///
/// Request slots are **stable** (free-listed), and the lane ids carried
/// by in-flight slab segments stay valid however many members retire
/// while an evaluation is out: a lane referenced by an in-flight slab
/// is never dropped or reshaped (sweep and finalize both require
/// `inflight_slabs == 0` first).
struct Scheduler {
    slots: Vec<Option<Active>>,
    free_slots: Vec<usize>,
    active_count: usize,
    /// Batch-major solver state: every admitted request is a member of
    /// exactly one lane.
    engine: LaneEngine,
    /// Lane id -> dispatch state (lazily created per lane).
    flights: Vec<Option<Flight>>,
    tele: Arc<Telemetry>,
    /// Flight recorder: typed span events per request id (= trace id).
    /// Every record is a `Copy` write into a preallocated ring —
    /// allocation-free on the scheduling hot path.
    rec: Arc<FlightRecorder>,
    recycler: SlabRecycler,
    /// Dispatch round -> slabs still in flight from it. The window cap
    /// is `pipeline_depth` rounds.
    rounds: BTreeMap<u64, usize>,
    next_seq: u64,
    next_round: u64,
    /// Scratch for `LaneEngine::step_lane` (reused across pulls).
    affected: Vec<usize>,
}

impl Scheduler {
    fn new(tele: Arc<Telemetry>, rec: Arc<FlightRecorder>, max_lane_rows: usize) -> Scheduler {
        Scheduler {
            slots: Vec::new(),
            free_slots: Vec::new(),
            active_count: 0,
            engine: LaneEngine::new(max_lane_rows),
            flights: Vec::new(),
            tele,
            rec,
            recycler: SlabRecycler::new(),
            rounds: BTreeMap::new(),
            next_seq: 0,
            next_round: 0,
            affected: Vec::new(),
        }
    }

    fn insert(&mut self, a: Active) -> usize {
        self.active_count += 1;
        match self.free_slots.pop() {
            Some(i) => {
                debug_assert!(self.slots[i].is_none());
                self.slots[i] = Some(a);
                i
            }
            None => {
                self.slots.push(Some(a));
                self.slots.len() - 1
            }
        }
    }

    fn take_slot(&mut self, slot: usize) -> Active {
        let a = self.slots[slot].take().expect("take of empty slot");
        self.free_slots.push(slot);
        self.active_count -= 1;
        a
    }

    fn lane_inflight(&self, lane: usize) -> usize {
        self.flights.get(lane).and_then(|f| f.as_ref()).map_or(0, |f| f.inflight_slabs)
    }

    fn flight_mut(&mut self, lane: usize) -> &mut Flight {
        if self.flights.len() <= lane {
            self.flights.resize_with(lane + 1, || None);
        }
        self.flights[lane].get_or_insert_with(Flight::default)
    }

    /// Retire with a result (normal completion or cancellation),
    /// releasing the inflight gauges.
    fn retire_ok_active(&self, a: Active, removed: Removed, cancelled: bool) {
        let now = Instant::now();
        let started = a.started_at.unwrap_or(now);
        let res = SamplingResult {
            id: a.id,
            samples: removed.samples,
            nfe: removed.nfe,
            queue_seconds: (started - a.submitted_at).as_secs_f64(),
            total_seconds: (now - a.submitted_at).as_secs_f64(),
            cancelled,
            delta_eps: removed.delta_eps,
            early_stop: removed.early_stop,
        };
        self.tele.observe_delivered_nfe(res.nfe);
        if cancelled {
            self.rec.record(a.id, SpanKind::Cancelled { nfe: res.nfe as u32 });
            self.tele.requests_cancelled.fetch_add(1, Ordering::Relaxed);
        } else {
            if removed.early_stop {
                self.tele.early_stops.fetch_add(1, Ordering::Relaxed);
            }
            self.rec.record(a.id, SpanKind::Finalize { nfe: res.nfe as u32 });
            self.tele.record_finish(res.total_seconds, res.queue_seconds);
            if let Some(d) = res.delta_eps {
                self.tele.record_delta_eps(d);
            }
        }
        self.tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
        self.tele.inflight_rows.fetch_sub(a.rows, Ordering::SeqCst);
        let _ = a.reply.send(Ok(res));
    }

    /// Retire with an error, releasing the inflight gauges.
    fn retire_err_active(&self, a: Active, err: String) {
        self.tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
        self.tele.inflight_rows.fetch_sub(a.rows, Ordering::SeqCst);
        let _ = a.reply.send(Err(err));
    }

    /// Validate and admit one envelope into the lane engine; returns
    /// the request slot on success. Same-tick requests with identical
    /// `(dataset, solver, plan, workload shape)` land in one lane and
    /// step together from then on.
    /// `now` is the scheduling round's one clock snapshot: every
    /// deadline decision of the round (admission DOA checks and the
    /// sweep) compares against the same instant, so a request can
    /// never be admitted by one check and expired by the next within
    /// the same round.
    fn admit(
        &mut self,
        env: Envelope,
        bank: &dyn ModelBank,
        plans: &PlanCache,
        now: Instant,
    ) -> Option<usize> {
        // Requests cancelled (or expired) while still queued never cost
        // a lane insertion or an evaluation.
        let dead_on_arrival =
            env.cancel.is_cancelled() || env.deadline.is_some_and(|d| now >= d);
        if dead_on_arrival {
            self.rec.record(env.id, SpanKind::Cancelled { nfe: 0 });
            self.tele.requests_cancelled.fetch_add(1, Ordering::Relaxed);
            self.tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
            self.tele.inflight_rows.fetch_sub(env.spec.admission_rows(), Ordering::SeqCst);
            let _ = env.reply.send(Ok(SamplingResult {
                id: env.id,
                samples: Tensor::zeros(0, 0),
                nfe: 0,
                queue_seconds: 0.0,
                total_seconds: 0.0,
                cancelled: true,
                delta_eps: None,
                early_stop: false,
            }));
            return None;
        }
        let sched = bank.sched();
        let resolved = if env.spec.task.is_guided() && !bank.supports_cond(&env.spec.dataset) {
            // Known-unservable at admission: a guided request must never
            // enter a fused slab whose conditional evaluation would fail
            // and retire unconditional batch-mates along with it.
            Err(format!(
                "dataset '{}' has no conditional denoiser; guided sampling unavailable",
                env.spec.dataset
            ))
        } else {
            bank.dim(&env.spec.dataset)
                .and_then(|dim| env.spec.resolve_lane(sched, dim, plans))
        };
        match resolved {
            Ok(adm) => {
                self.tele.requests_admitted.fetch_add(1, Ordering::Relaxed);
                if env.spec.task.is_guided() {
                    self.tele.guided_requests.fetch_add(1, Ordering::Relaxed);
                }
                if env.spec.task.is_img2img() {
                    self.tele.img2img_requests.fetch_add(1, Ordering::Relaxed);
                }
                if env.spec.task.is_stochastic() {
                    self.tele.stochastic_requests.fetch_add(1, Ordering::Relaxed);
                }
                let id = env.id;
                let rows = env.spec.admission_rows();
                let slot = self.insert(Active {
                    id,
                    rows,
                    reply: env.reply,
                    cancel: env.cancel,
                    deadline: env.deadline,
                    submitted_at: now,
                    started_at: None,
                    qos: env.spec.qos,
                    degraded: false,
                });
                let lane = self.engine.admit(slot, &env.spec.dataset, adm);
                self.rec.record(id, SpanKind::Admitted { rows: rows as u32 });
                self.rec.record(id, SpanKind::LaneAttach { lane: lane as u32 });
                // Pool admission squeezed this request in under the
                // global row cap on the promise it heads for its NFE
                // floor: latch the lane member degraded right away.
                if env.spec.degraded && self.engine.degrade_member(slot) {
                    self.slots[slot].as_mut().expect("just inserted").degraded = true;
                    self.tele.degraded_requests.fetch_add(1, Ordering::Relaxed);
                }
                Some(slot)
            }
            Err(e) => {
                self.tele.inflight_requests.fetch_sub(1, Ordering::SeqCst);
                self.tele.inflight_rows.fetch_sub(env.spec.admission_rows(), Ordering::SeqCst);
                let _ = env.reply.send(Err(e));
                None
            }
        }
    }

    /// Retire every cancelled/expired member of lanes with no slab in
    /// flight. Compaction removes the member's rows from the lane's
    /// stacked state without perturbing batch-mates' bits; a not-yet-
    /// dispatched pending eval is regenerated from the compacted state.
    /// Runs every scheduler tick — including linger waits — so a cancel
    /// is honoured within a tick, not after `max_wait`.
    fn sweep(&mut self, rs: Option<&dyn ResidentState>, now: Instant) {
        // ---- QoS degradation under deadline pressure ----
        // A besteffort request past ~75% of its deadline budget heads
        // for its NFE floor instead of risking a deadline kill: the
        // lane member latches degraded (an ERA-only operation — the
        // closing jump needs the eps history) and the next delivery
        // retires it early. Safe with slabs in flight: the latch only
        // flags the member, it never reshapes the lane.
        for slot in 0..self.slots.len() {
            let Some(a) = self.slots[slot].as_ref() else { continue };
            if a.degraded || a.qos != QosClass::BestEffort {
                continue;
            }
            let Some(d) = a.deadline else { continue };
            if d <= a.submitted_at || now >= d {
                continue; // no budget to speak of, or the sweep below retires it
            }
            let budget = d - a.submitted_at;
            if now >= a.submitted_at + budget.mul_f64(0.75) && self.engine.degrade_member(slot) {
                self.slots[slot].as_mut().expect("checked above").degraded = true;
                self.tele.degraded_requests.fetch_add(1, Ordering::Relaxed);
            }
        }
        for lane in 0..self.engine.lane_slots() {
            if !self.engine.has_lane(lane) || self.lane_inflight(lane) > 0 {
                continue;
            }
            // A resident lane's rows live engine-side: gather it back
            // before compaction reshapes it (the snapshot is bitwise
            // the host state, so survivors are unperturbed).
            if self.engine.resident_handle(lane).is_some() {
                let dead = self.engine.members(lane).iter().any(|m| {
                    self.slots[m.slot].as_ref().is_some_and(|a| {
                        a.cancel.is_cancelled() || a.deadline.is_some_and(|d| now >= d)
                    })
                });
                if !dead {
                    continue;
                }
                let Some(rs) = rs else { continue };
                if !self.devolve_resident(lane, rs) {
                    continue; // gather failed; lane already dropped
                }
            }
            loop {
                let victim = self.engine.members(lane).iter().find_map(|m| {
                    let a = self.slots[m.slot].as_ref()?;
                    let dead = a.cancel.is_cancelled() || a.deadline.is_some_and(|d| now >= d);
                    dead.then_some(m.slot)
                });
                let Some(slot) = victim else { break };
                let removed = self.engine.remove_member(lane, slot, None);
                let a = self.take_slot(slot);
                self.rec.record(a.id, SpanKind::LaneCompact { lane: lane as u32 });
                self.retire_ok_active(a, removed, true);
                if !self.engine.has_lane(lane) {
                    if lane < self.flights.len() {
                        self.flights[lane] = None;
                    }
                    break;
                }
            }
        }
    }

    /// Step every idle lane (no pending eval, no slab in flight);
    /// retire lanes whose members all finished. Fresh lanes that
    /// qualify for engine residency convert here instead of stepping:
    /// their iterate uploads once and subsequent rounds ship only
    /// coefficient-sized ops.
    fn pull_ready(&mut self, rs: Option<&dyn ResidentState>) {
        for lane in 0..self.engine.lane_slots() {
            if !self.engine.has_lane(lane) || self.lane_inflight(lane) > 0 {
                continue;
            }
            if self.engine.is_done(lane) {
                self.retire_lane_done(lane);
                continue;
            }
            if self.engine.resident_handle(lane).is_some() {
                continue; // idle resident lanes dispatch ops, not evals
            }
            if self.engine.pending(lane).is_none() {
                if let Some(rs) = rs {
                    if self.engine.resident_eligible(lane) && self.make_resident(lane, rs) {
                        continue;
                    }
                }
                self.pull_lane(lane);
            }
        }
    }

    /// Convert an eligible fresh lane to engine-resident stepping. The
    /// one-time upload of the stacked iterate is the last O(rows×dim)
    /// transfer the lane pays until it finishes (or devolves).
    fn make_resident(&mut self, lane: usize, rs: &dyn ResidentState) -> bool {
        let keep = self.engine.resident_keeps_history(lane);
        let bytes = resident::tensor_bytes(self.engine.lane_x(lane));
        let handle = match rs.open(self.engine.dataset(lane), self.engine.lane_x(lane), keep) {
            Ok(h) => h,
            Err(_) => return false, // engine refused: stay on the slab path
        };
        self.tele.host_bytes_transferred.fetch_add(bytes, Ordering::Relaxed);
        self.tele.resident_lanes.fetch_add(1, Ordering::Relaxed);
        self.engine.resident_convert(lane, handle);
        // Conversion starts the lane's first step: stamp queue waits
        // exactly like `pull_lane` does for the host path.
        let now = Instant::now();
        let mut k = 0;
        while k < self.engine.members(lane).len() {
            let slot = self.engine.members(lane)[k].slot;
            k += 1;
            if let Some(a) = self.slots[slot].as_mut() {
                if a.started_at.is_none() {
                    a.started_at = Some(now);
                    let wait = (now - a.submitted_at).as_nanos() as u64;
                    self.rec.record(a.id, SpanKind::QueueWait { nanos: wait });
                }
            }
        }
        true
    }

    /// Gather a resident lane's state back to the host (one full
    /// snapshot transfer) so splitting, compaction, or shutdown can
    /// reshape it. Returns false when the gather failed — the lane is
    /// dropped and its members retired with an error.
    fn devolve_resident(&mut self, lane: usize, rs: &dyn ResidentState) -> bool {
        let Some(handle) = self.engine.resident_handle(lane) else {
            return true;
        };
        match rs.snapshot(handle) {
            Ok(snap) => {
                let bytes = resident::tensor_bytes(&snap.x)
                    + snap.eps.iter().map(resident::tensor_bytes).sum::<u64>();
                self.tele.host_bytes_transferred.fetch_add(bytes, Ordering::Relaxed);
                rs.close(handle);
                self.tele.resident_lanes.fetch_sub(1, Ordering::Relaxed);
                self.engine.resident_devolve(lane, snap);
                true
            }
            Err(e) => {
                rs.close(handle);
                self.tele.resident_lanes.fetch_sub(1, Ordering::Relaxed);
                if lane < self.flights.len() {
                    self.flights[lane] = None;
                }
                for slot in self.engine.drop_lane(lane) {
                    let a = self.take_slot(slot);
                    self.retire_err_active(a, format!("resident gather failed: {e}"));
                }
                false
            }
        }
    }

    /// Close a resident lane's engine-side state without gathering it
    /// (failure paths where the host copy is already stale anyway).
    fn forfeit_resident(&mut self, lane: usize, rs: Option<&dyn ResidentState>) {
        if let Some(handle) = self.engine.resident_handle(lane) {
            if let Some(rs) = rs {
                rs.close(handle);
            }
            self.tele.resident_lanes.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Pull one lane's next evaluation — possibly splitting it when
    /// ERA selections diverge — and retire any resulting lane that
    /// finished.
    fn pull_lane(&mut self, lane: usize) {
        let mut affected = std::mem::take(&mut self.affected);
        affected.clear();
        let t0 = Instant::now();
        self.engine.step_lane(lane, &mut affected);
        self.tele.stage_solver.observe_nanos(t0.elapsed().as_nanos() as u64);
        let now = Instant::now();
        for (ai, &lid) in affected.iter().enumerate() {
            let mut k = 0;
            while k < self.engine.members(lid).len() {
                let m = &self.engine.members(lid)[k];
                let (slot, step) = (m.slot, m.nfe);
                k += 1;
                if let Some(a) = self.slots[slot].as_mut() {
                    if a.started_at.is_none() {
                        a.started_at = Some(now);
                        let wait = (now - a.submitted_at).as_nanos() as u64;
                        self.rec.record(a.id, SpanKind::QueueWait { nanos: wait });
                    }
                    if ai == 0 {
                        // `affected[0]` is the pulled lane itself; the
                        // rest are ERS-divergence siblings split off it.
                        self.rec.record(
                            a.id,
                            SpanKind::SolverStep { lane: lid as u32, step: step as u32 },
                        );
                    } else {
                        self.rec.record(
                            a.id,
                            SpanKind::LaneSplit { from: lane as u32, to: lid as u32 },
                        );
                    }
                }
            }
            if self.engine.is_done(lid) {
                self.retire_lane_done(lid);
            }
        }
        self.affected = affected;
    }

    /// A finished lane retires all member requests at once (lanes run
    /// in lockstep, so completion is lane-granular).
    fn retire_lane_done(&mut self, lane: usize) {
        let t0 = Instant::now();
        for removed in self.engine.finish_lane(lane) {
            let a = self.take_slot(removed.slot);
            self.retire_ok_active(a, removed, false);
        }
        if lane < self.flights.len() {
            self.flights[lane] = None;
        }
        self.tele.stage_finalize.observe_nanos(t0.elapsed().as_nanos() as u64);
    }

    /// Rows pending on lanes that could join the next dispatch. Idle
    /// resident lanes count their stacked rows: they carry no pending
    /// eval but dispatch a coefficient op next round.
    fn dispatchable_rows(&self) -> usize {
        (0..self.engine.lane_slots())
            .filter(|&l| self.engine.has_lane(l) && self.lane_inflight(l) == 0)
            .map(|l| {
                if let Some(p) = self.engine.pending(l) {
                    p.x.rows()
                } else if self.engine.resident_handle(l).is_some() && !self.engine.is_done(l) {
                    self.engine.lane_rows(l)
                } else {
                    0
                }
            })
            .sum()
    }

    /// Pack every ready lane evaluation (per dataset) and hand the
    /// slabs to the executor pool as one dispatch round. Lane rows are
    /// already contiguous, so a lane that fits one slab ships its
    /// stacked tensor zero-copy — and the whole lane costs a single
    /// segment, however many requests it fuses.
    fn dispatch_round(
        &mut self,
        batcher: &Batcher,
        executors: &ExecutorPool,
        rs: Option<&dyn ResidentState>,
    ) -> usize {
        let mut jobs: Vec<(Arc<str>, JobPayload)> = Vec::new();
        let mut dispatched_lanes: Vec<usize> = Vec::new();
        // ---- Resident lanes: one coefficient-sized op each ----
        if let Some(rs) = rs {
            for lane in 0..self.engine.lane_slots() {
                if !self.engine.has_lane(lane)
                    || self.lane_inflight(lane) > 0
                    || self.engine.is_done(lane)
                    || self.engine.resident_handle(lane).is_none()
                {
                    continue;
                }
                match self.engine.resident_next_op(lane) {
                    ResidentCmd::Op(op) => {
                        let handle = self.engine.resident_handle(lane).unwrap();
                        let rows = self.engine.lane_rows(lane);
                        let name: Arc<str> = Arc::from(self.engine.dataset(lane));
                        jobs.push((name, JobPayload::Resident { lane, handle, rows, op }));
                        dispatched_lanes.push(lane);
                    }
                    ResidentCmd::Devolve => {
                        // ERS member selections diverged: gather the
                        // lane back so the host path can split it, and
                        // let it join this same round's slab pass.
                        if self.devolve_resident(lane, rs) {
                            self.pull_lane(lane);
                        }
                    }
                }
            }
        }
        let mut recycler = std::mem::take(&mut self.recycler);
        {
            let mut by_dataset: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
            for lane in 0..self.engine.lane_slots() {
                if !self.engine.has_lane(lane) || self.lane_inflight(lane) > 0 {
                    continue;
                }
                if self.engine.pending(lane).is_none() {
                    continue;
                }
                by_dataset.entry(self.engine.dataset(lane)).or_default().push(lane);
            }
            for (dataset, lanes) in by_dataset {
                let pending: Vec<(usize, &EvalRequest)> =
                    lanes.iter().map(|&l| (l, self.engine.pending(l).unwrap())).collect();
                let plan = batcher.pack_recycled(&pending, &mut recycler);
                // One allocation per dataset group; slabs share it.
                let name: Arc<str> = Arc::from(dataset);
                for slab in plan.slabs {
                    jobs.push((name.clone(), JobPayload::Eval(slab)));
                }
                dispatched_lanes.extend(lanes);
            }
        }
        self.recycler = recycler;
        if jobs.is_empty() {
            return 0;
        }
        self.tele.lanes.store(self.engine.lane_count(), Ordering::Relaxed);
        for &lane in &dispatched_lanes {
            self.tele.observe_lane_occupancy(self.engine.members(lane).len());
        }
        let round = self.next_round;
        self.next_round += 1;
        let mut dispatched = 0usize;
        for (dataset, payload) in jobs {
            let seq = self.next_seq;
            self.next_seq += 1;
            match &payload {
                JobPayload::Eval(slab) => {
                    let words = slab.x().len() + slab.t.len() + slab.c().len();
                    let bytes = words as u64 * 4;
                    self.tele.host_bytes_transferred.fetch_add(bytes, Ordering::Relaxed);
                    for seg in &slab.segments {
                        let rows = self.engine.pending(seg.source).map_or(0, |p| p.x.rows());
                        for m in self.engine.members(seg.source) {
                            if let Some(a) = self.slots[m.slot].as_ref() {
                                self.rec.record(
                                    a.id,
                                    SpanKind::SlabDispatch {
                                        seq,
                                        round,
                                        lane: seg.source as u32,
                                        rows: seg.rows as u32,
                                    },
                                );
                            }
                        }
                        let f = self.flight_mut(seg.source);
                        if f.inflight_slabs == 0 {
                            f.expect_rows = rows;
                            debug_assert!(f.assembly.is_none() && f.failed.is_none());
                        }
                        f.inflight_slabs += 1;
                    }
                }
                JobPayload::Resident { lane, rows, op, .. } => {
                    let bytes = resident::op_bytes(op);
                    self.tele.host_bytes_transferred.fetch_add(bytes, Ordering::Relaxed);
                    for m in self.engine.members(*lane) {
                        if let Some(a) = self.slots[m.slot].as_ref() {
                            self.rec.record(
                                a.id,
                                SpanKind::SlabDispatch {
                                    seq,
                                    round,
                                    lane: *lane as u32,
                                    rows: *rows as u32,
                                },
                            );
                        }
                    }
                    let f = self.flight_mut(*lane);
                    debug_assert!(f.assembly.is_none() && f.failed.is_none());
                    debug_assert!(f.resident.is_none() && f.inflight_slabs == 0);
                    f.expect_rows = *rows;
                    f.inflight_slabs = 1;
                }
            }
            self.tele.inflight_slabs.fetch_add(1, Ordering::SeqCst);
            dispatched += 1;
            if !executors.dispatch(SlabJob { seq, round, dataset, payload }) {
                // Every executor has exited (only possible if they all
                // panicked): no dispatched slab will ever complete, so
                // fail every lane with work in flight and reset the
                // pipeline bookkeeping rather than wait forever.
                self.tele.inflight_slabs.store(0, Ordering::SeqCst);
                self.rounds.clear();
                for lane in 0..self.flights.len() {
                    let stuck =
                        self.flights[lane].as_ref().is_some_and(|f| f.inflight_slabs > 0);
                    if !stuck {
                        continue;
                    }
                    self.flights[lane] = None;
                    if self.engine.has_lane(lane) {
                        self.forfeit_resident(lane, rs);
                        for slot in self.engine.drop_lane(lane) {
                            let a = self.take_slot(slot);
                            self.retire_err_active(a, "executor pool stopped".into());
                        }
                    }
                }
                return 0;
            }
        }
        self.rounds.insert(round, dispatched);
        self.tele.rounds.fetch_add(1, Ordering::Relaxed);
        self.tele.observe_depth(self.rounds.len());
        dispatched
    }

    /// Route one sequence-numbered slab completion: account telemetry,
    /// scatter or adopt the output per lane, and finalize every lane
    /// whose evaluation has now fully returned.
    fn route(&mut self, c: SlabCompletion, rs: Option<&dyn ResidentState>) {
        // Lanes referenced by an in-flight slab are never dropped
        // (sweep/finalize require inflight_slabs == 0), so the guards
        // below are for one degenerate case only: completions already
        // in the channel when the executor-pool-stopped cleanup failed
        // their lanes. Those route as no-ops instead of panicking the
        // scheduler or underflowing the gauge.
        let _ = self
            .tele
            .inflight_slabs
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1));
        if let Some(rem) = self.rounds.get_mut(&c.round) {
            *rem -= 1;
            if *rem == 0 {
                self.rounds.remove(&c.round);
            }
        }
        let segments = c.segments;
        match c.result {
            Ok(SlabOutput::Eps(out)) => {
                self.tele.eval_nanos.fetch_add(c.eval_nanos, Ordering::Relaxed);
                self.tele.stage_eval.observe_nanos(c.eval_nanos);
                self.tele.evals.fetch_add(1, Ordering::Relaxed);
                self.tele.rows.fetch_add(c.rows, Ordering::Relaxed);
                self.tele
                    .padded_rows
                    .fetch_add(c.executed_rows.saturating_sub(c.rows), Ordering::Relaxed);
                let bytes = resident::tensor_bytes(&out);
                self.tele.host_bytes_transferred.fetch_add(bytes, Ordering::Relaxed);
                // Zero-copy completion: a slab that was exactly one
                // whole lane evaluation adopts the engine output.
                let whole = segments.len() == 1 && {
                    let seg = &segments[0];
                    self.flights.get(seg.source).and_then(|f| f.as_ref()).is_some_and(|f| {
                        seg.src_start == 0 && seg.rows == f.expect_rows && f.assembly.is_none()
                    })
                };
                if whole {
                    let seg = &segments[0];
                    let f = self.flights[seg.source].as_mut().unwrap();
                    f.assembly = Some((out, seg.rows));
                } else {
                    let flights = &mut self.flights;
                    let recycler = &mut self.recycler;
                    for seg in &segments {
                        let Some(f) = flights.get_mut(seg.source).and_then(|o| o.as_mut())
                        else {
                            continue; // stale completion, see above
                        };
                        if f.failed.is_some() {
                            continue; // assembly will be discarded anyway
                        }
                        let expect = f.expect_rows;
                        let (buf, filled) = f.assembly.get_or_insert_with(|| {
                            (recycler.take_assembly(expect, out.cols()), 0)
                        });
                        // Absolute-offset scatter: stitching is correct
                        // under any completion order.
                        fused::scatter_rows(buf, seg.src_start, &out, seg.start, seg.rows);
                        *filled += seg.rows;
                    }
                }
            }
            Ok(SlabOutput::Resident(ro)) => {
                self.tele.eval_nanos.fetch_add(c.eval_nanos, Ordering::Relaxed);
                self.tele.stage_eval.observe_nanos(c.eval_nanos);
                // A Finish op runs no model evaluation (the final eval
                // is skipped exactly like the host path skips it), so
                // it contributes no eval/row counts.
                if ro.final_x.is_none() {
                    self.tele.evals.fetch_add(1, Ordering::Relaxed);
                    self.tele.rows.fetch_add(c.rows, Ordering::Relaxed);
                    self.tele
                        .padded_rows
                        .fetch_add(c.executed_rows.saturating_sub(c.rows), Ordering::Relaxed);
                }
                let bytes = resident::outcome_bytes(&ro);
                self.tele.host_bytes_transferred.fetch_add(bytes, Ordering::Relaxed);
                let lane = segments[0].source;
                if let Some(f) = self.flights.get_mut(lane).and_then(|o| o.as_mut()) {
                    f.resident = Some(ro);
                }
            }
            Err(e) => {
                for seg in &segments {
                    if let Some(f) = self.flights.get_mut(seg.source).and_then(|o| o.as_mut()) {
                        if f.failed.is_none() {
                            f.failed = Some(e.clone());
                        }
                    }
                }
            }
        }
        // Record the completion on every surviving member of every lane
        // the slab carried rows for (stale lanes route as no-ops).
        for seg in &segments {
            if !self.engine.has_lane(seg.source) {
                continue;
            }
            for m in self.engine.members(seg.source) {
                if let Some(a) = self.slots[m.slot].as_ref() {
                    self.rec.record(
                        a.id,
                        SpanKind::SlabComplete {
                            seq: c.seq,
                            round: c.round,
                            executor: c.executor as u16,
                            eval_nanos: c.eval_nanos,
                        },
                    );
                }
            }
        }
        // A lane appears at most once per slab, so one decrement per
        // segment; flights are lane-id-stable, so finalizing one lane
        // cannot shift another's entry.
        for seg in &segments {
            if let Some(f) = self.flights.get_mut(seg.source).and_then(|o| o.as_mut()) {
                f.inflight_slabs = f.inflight_slabs.saturating_sub(1);
            }
        }
        for seg in &segments {
            let ready = self
                .flights
                .get(seg.source)
                .and_then(|f| f.as_ref())
                .is_some_and(|f| f.inflight_slabs == 0)
                && self.engine.has_lane(seg.source);
            if ready {
                self.finalize_lane(seg.source, rs);
            }
        }
        let mut bufs = c.buffers;
        bufs.segments = segments;
        self.recycler.give_buffers(bufs);
    }

    /// All slabs of a lane's evaluation are back: compact out members
    /// whose cancel/deadline latched while it was in flight (their
    /// share of the output is dropped undelivered, without perturbing
    /// batch-mates' bits), then deliver the stacked eps — one fused
    /// advance for every surviving member.
    fn finalize_lane(&mut self, lane: usize, rs: Option<&dyn ResidentState>) {
        let Some(f) = self.flights[lane].take() else { return };
        debug_assert_eq!(f.inflight_slabs, 0);
        if let Some(err) = f.failed {
            if let Some((buf, _)) = f.assembly {
                self.recycler.give_assembly(buf);
            }
            self.forfeit_resident(lane, rs);
            for slot in self.engine.drop_lane(lane) {
                let a = self.take_slot(slot);
                self.retire_err_active(a, format!("model evaluation failed: {err}"));
            }
            return;
        }
        if let Some(outcome) = f.resident {
            self.finalize_resident(lane, outcome, rs);
            return;
        }
        let (mut eps, filled) = f.assembly.expect("deliver without assembly");
        debug_assert_eq!(filled, eps.rows(), "lane assembly incomplete");
        debug_assert_eq!(eps.rows(), f.expect_rows);
        let now = Instant::now();
        loop {
            let victim = self.engine.members(lane).iter().find_map(|m| {
                let a = self.slots[m.slot].as_ref()?;
                let dead = a.cancel.is_cancelled() || a.deadline.is_some_and(|d| now >= d);
                dead.then_some(m.slot)
            });
            let Some(slot) = victim else { break };
            let removed = self.engine.remove_member(lane, slot, Some(&mut eps));
            let a = self.take_slot(slot);
            self.rec.record(a.id, SpanKind::LaneCompact { lane: lane as u32 });
            self.retire_ok_active(a, removed, true);
            if !self.engine.has_lane(lane) {
                // Every member cancelled mid-flight: drop the output.
                self.recycler.give_assembly(eps);
                return;
            }
        }
        self.tele.steps.fetch_add(self.engine.members(lane).len(), Ordering::Relaxed);
        let t0 = Instant::now();
        self.engine.deliver(lane, eps);
        self.tele.stage_solver.observe_nanos(t0.elapsed().as_nanos() as u64);
        // An ERA lane's delivery runs the error-robust selection (Eq.
        // 15); surface the per-member error measure and the selected
        // Lagrange basis indices on every member's trace.
        if let Some((_, idx)) = self.engine.era_selection(lane) {
            let (k, bases) = pack_bases(idx);
            for m in self.engine.members(lane) {
                if let Some(a) = self.slots[m.slot].as_ref() {
                    self.rec.record(
                        a.id,
                        SpanKind::EraStep {
                            lane: lane as u32,
                            step: m.nfe as u32,
                            delta_eps: m.delta_eps,
                            k,
                            bases,
                        },
                    );
                }
            }
        }
        // ---- Convergence control (adaptive NFE) ----
        // Members whose delta_eps trend satisfies their convergence
        // predicate — or whose QoS degraded them toward the floor —
        // retire now via one closing DDIM jump, compacting out of the
        // lane without perturbing batch-mates' bits.
        for slot in self.engine.converged_members(lane) {
            let removed = self.engine.finish_member_early(lane, slot);
            let a = self.take_slot(slot);
            self.rec.record(a.id, SpanKind::LaneCompact { lane: lane as u32 });
            self.retire_ok_active(a, removed, false);
            if !self.engine.has_lane(lane) {
                return; // every member converged
            }
        }
        if self.engine.is_done(lane) {
            self.retire_lane_done(lane);
        } else {
            // Pull immediately so the lane can join the next dispatch
            // round without waiting a tick.
            self.pull_lane(lane);
        }
    }

    /// A resident op completed: deliver its per-row eps summary (or
    /// final iterate), then handle cancels that latched while it was
    /// in flight. The slab path compacts victims *before* delivery;
    /// here the engine already advanced, so victims are compacted
    /// after — survivor bits are identical either way, but a victim's
    /// reported nfe includes the in-flight eval (see DESIGN.md).
    fn finalize_resident(
        &mut self,
        lane: usize,
        outcome: ResidentOutcome,
        rs: Option<&dyn ResidentState>,
    ) {
        let t0 = Instant::now();
        let finished = outcome.final_x.is_some();
        self.engine.resident_deliver(lane, outcome);
        if !finished {
            self.tele.steps.fetch_add(self.engine.members(lane).len(), Ordering::Relaxed);
        }
        self.tele.stage_solver.observe_nanos(t0.elapsed().as_nanos() as u64);
        if finished {
            // The Finish op shipped the final iterate home and dropped
            // the engine-side entry.
            self.tele.resident_lanes.fetch_sub(1, Ordering::Relaxed);
        }
        let now = Instant::now();
        let mut devolved = false;
        // Converged/degraded members retire through the host path (the
        // closing jump needs the eps history): gather the lane first,
        // then compact them out exactly like the slab path does.
        if !finished && !self.engine.converged_members(lane).is_empty() {
            if let Some(rs) = rs {
                if !self.devolve_resident(lane, rs) {
                    return; // gather failed; lane already dropped
                }
                devolved = true;
                for slot in self.engine.converged_members(lane) {
                    let removed = self.engine.finish_member_early(lane, slot);
                    let a = self.take_slot(slot);
                    self.rec.record(a.id, SpanKind::LaneCompact { lane: lane as u32 });
                    self.retire_ok_active(a, removed, false);
                    if !self.engine.has_lane(lane) {
                        return; // every member converged
                    }
                }
            }
        }
        loop {
            let victim = self.engine.members(lane).iter().find_map(|m| {
                let a = self.slots[m.slot].as_ref()?;
                let dead = a.cancel.is_cancelled() || a.deadline.is_some_and(|d| now >= d);
                dead.then_some(m.slot)
            });
            let Some(slot) = victim else { break };
            if !devolved {
                devolved = true;
                if !finished {
                    // Compaction reshapes host rows: gather first.
                    let Some(rs) = rs else { break };
                    if !self.devolve_resident(lane, rs) {
                        return; // gather failed; lane already dropped
                    }
                }
            }
            let removed = self.engine.remove_member(lane, slot, None);
            let a = self.take_slot(slot);
            self.rec.record(a.id, SpanKind::LaneCompact { lane: lane as u32 });
            self.retire_ok_active(a, removed, true);
            if !self.engine.has_lane(lane) {
                return;
            }
        }
        if self.engine.is_done(lane) {
            self.retire_lane_done(lane);
        } else if devolved {
            // Back on the host path: step immediately so the lane can
            // join the next dispatch round.
            self.pull_lane(lane);
        }
        // Lanes still resident and idle get their next op at dispatch.
    }
}

fn run_loop(
    banks: BankSet,
    config: CoordinatorConfig,
    rx: Receiver<Envelope>,
    tele: Arc<Telemetry>,
    rec: Arc<FlightRecorder>,
    plans: Arc<PlanCache>,
) {
    let batcher = Batcher::new(config.policy);
    let depth = config.pipeline_depth.max(1);
    let bank = banks.primary().clone();
    let (comp_tx, comp_rx) = std::sync::mpsc::channel::<SlabCompletion>();
    let executors = ExecutorPool::spawn(
        &banks,
        config.executors_per_shard.max(1),
        config.max_active.max(1) * depth,
        comp_tx,
        tele.clone(),
    );
    let mut s = Scheduler::new(tele, rec, config.policy.max_rows);
    let mut queue_open = true;
    // Device residency needs one shared bank: with per-shard replicas
    // each executor would hold its own lane table, so the scheduler
    // falls back to the slab path.
    let residency: Option<&dyn ResidentState> =
        if banks.len() == 1 { bank.resident() } else { None };

    'outer: loop {
        // One clock snapshot per scheduling round: admission DOA checks
        // and the sweep compare deadlines against the same instant, so
        // a round's decisions are mutually consistent.
        let now = Instant::now();
        // ---- Route completions that arrived since the last tick ----
        while let Ok(c) = comp_rx.try_recv() {
            s.route(c, residency);
        }

        // ---- Admission ----
        while queue_open && s.active_count < config.max_active {
            match rx.try_recv() {
                Ok(env) => {
                    s.admit(env, bank.as_ref(), &plans, now);
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    queue_open = false;
                    break;
                }
            }
        }
        if s.active_count == 0 {
            if !queue_open {
                break 'outer; // drained and closed: exit
            }
            // Idle: block for work (the blocking wait moved the clock,
            // so the arrival gets a fresh snapshot).
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(env) => {
                    s.admit(env, bank.as_ref(), &plans, Instant::now());
                    continue;
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    queue_open = false;
                    continue;
                }
            }
        }

        // ---- Cancellation / deadline sweep + solver stepping ----
        s.sweep(residency, now);
        s.pull_ready(residency);
        if s.active_count == 0 {
            continue;
        }

        // ---- Linger under min_rows (max_wait policy) ----
        let mut rows = s.dispatchable_rows();
        if s.rounds.len() < depth && rows > 0 && rows < config.policy.min_rows && queue_open {
            let deadline = Instant::now() + config.policy.max_wait;
            loop {
                // Each linger slice is its own mini-round with its own
                // clock snapshot (time passes while waiting).
                let now = Instant::now();
                // Completions landing mid-linger free more pending work
                // to join this round.
                while let Ok(c) = comp_rx.try_recv() {
                    s.route(c, residency);
                }
                // The linger wait is cancellation-aware: every slice
                // re-checks cancels/deadlines of already-active
                // requests instead of blindly sleeping out `max_wait`.
                s.sweep(residency, now);
                s.pull_ready(residency);
                rows = s.dispatchable_rows();
                if rows == 0
                    || rows >= config.policy.min_rows
                    || s.active_count >= config.max_active
                {
                    break;
                }
                if now >= deadline {
                    break;
                }
                let slice = (deadline - now).min(Duration::from_millis(1));
                match rx.recv_timeout(slice) {
                    Ok(env) => {
                        let anow = Instant::now();
                        let mut admitted =
                            s.admit(env, bank.as_ref(), &plans, anow).is_some();
                        // Drain the rest of the burst before stepping:
                        // the first pull seals new lanes, so same-window
                        // identical arrivals must land first to fuse.
                        while s.active_count < config.max_active {
                            match rx.try_recv() {
                                Ok(env) => {
                                    admitted |=
                                        s.admit(env, bank.as_ref(), &plans, anow).is_some();
                                }
                                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                    queue_open = false;
                                    break;
                                }
                            }
                        }
                        if admitted {
                            // New arrivals join this round immediately.
                            s.pull_ready(residency);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        queue_open = false;
                        break;
                    }
                }
            }
            rows = s.dispatchable_rows();
        }

        // ---- Dispatch into the pipeline window, or wait on events ----
        if s.rounds.len() < depth && rows > 0 {
            s.dispatch_round(&batcher, &executors, residency);
        } else if !s.rounds.is_empty() {
            // Window full (or nothing ready): wait for a completion,
            // waking periodically to keep admission and cancellation
            // sweeps responsive while evaluations run.
            match comp_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(c) => s.route(c, residency),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {}
            }
        } else {
            // Active requests but nothing in flight and nothing to
            // dispatch (all pending retired this tick): brief blocking
            // wait for admission to avoid a busy spin.
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(env) => {
                    s.admit(env, bank.as_ref(), &plans, Instant::now());
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    queue_open = false;
                }
            }
        }
    }
    // Queue closed, every request retired, nothing in flight: stop the
    // executors (closing the job queue joins them).
    executors.shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::solvers::eps_model::AnalyticGmm;

    fn bank() -> Arc<dyn ModelBank> {
        let sched = VpSchedule::default();
        Arc::new(
            MockBank::new(sched)
                .with("gmm8", Box::new(AnalyticGmm::gmm8(sched)))
                .with("gmm8b", Box::new(AnalyticGmm::gmm8(sched))),
        )
    }

    fn spec(solver: &str, n: usize, seed: u64) -> RequestSpec {
        RequestSpec {
            solver: solver.into(),
            n_samples: n,
            nfe: 10,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let res = c.sample(spec("era", 32, 1)).unwrap();
        assert_eq!(res.samples.rows(), 32);
        assert_eq!(res.nfe, 10);
        assert!(res.samples.all_finite());
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let cfg = CoordinatorConfig {
            policy: BatchPolicy { max_rows: 256, min_rows: 64, max_wait: Duration::from_millis(30) },
            ..Default::default()
        };
        let c = Coordinator::start(bank(), cfg);
        let tickets: Vec<_> =
            (0..8).map(|i| c.submit(spec("era", 16, i)).unwrap()).collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert_eq!(r.samples.rows(), 16);
        }
        // 8 requests x 16 rows with min_rows 64 must have fused: strictly
        // fewer evals than 8 requests x 10 steps separately.
        let evals = c.telemetry().evals.load(Ordering::Relaxed);
        assert!(evals < 80, "no fusion happened: {evals} evals");
        assert!(c.telemetry().mean_batch_occupancy() > 16.0);
        c.shutdown();
    }

    #[test]
    fn mixed_solvers_and_datasets() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let t1 = c.submit(spec("era", 8, 1)).unwrap();
        let t2 = c.submit(spec("ddim", 8, 2)).unwrap();
        let mut s3 = spec("dpm-2", 8, 3);
        s3.dataset = "gmm8b".into();
        let t3 = c.submit(s3).unwrap();
        for t in [t1, t2, t3] {
            assert!(t.wait().is_ok());
        }
        c.shutdown();
    }

    #[test]
    fn invalid_solver_rejected_at_submit() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        match c.submit(spec("frobnicate", 4, 0)) {
            Err(SubmitError::Invalid(_)) => {}
            Err(e) => panic!("expected Invalid, got {e:?}"),
            Ok(_) => panic!("expected Invalid, got Ok"),
        }
        c.shutdown();
    }

    #[test]
    fn unknown_dataset_fails_via_reply() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 4, 0);
        s.dataset = "nope".into();
        let t = c.submit(s).unwrap();
        assert!(t.wait().is_err());
        c.shutdown();
    }

    #[test]
    fn bad_budget_fails_via_reply() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("pndm", 4, 0);
        s.nfe = 5; // below PRK warmup minimum
        match c.submit(s) {
            Ok(t) => assert!(t.wait().is_err()),
            Err(SubmitError::Invalid(_)) => {}
            Err(e) => panic!("unexpected {e:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn guided_request_matches_inprocess_guided_run() {
        // The paired-row serving path (slab cond channel, guided_combine
        // after reassembly) must equal driving the guided solver stack
        // directly against the same model.
        let sched = VpSchedule::default();
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 16, 4);
        s.task = crate::solvers::TaskSpec {
            guidance_scale: 2.0,
            guide_class: 2,
            ..Default::default()
        };
        let via_coord = c.sample(s.clone()).unwrap();
        assert_eq!(via_coord.samples.rows(), 16);
        assert_eq!(via_coord.nfe, 20, "10 paired steps = 20 evaluations");
        c.shutdown();

        let model = AnalyticGmm::gmm8(sched);
        let mut solver = s.build_solver(sched, 2).unwrap();
        let direct = crate::solvers::sample_with(&mut *solver, &model);
        assert_eq!(via_coord.samples.as_slice(), direct.as_slice());
    }

    #[test]
    fn guided_request_rejected_when_bank_has_no_conditional_head() {
        // A bank without a conditional head (PjRtEngine's situation)
        // must refuse guided requests at admission with a clear error,
        // and an unconditional batch-mate submitted alongside must be
        // completely unaffected.
        struct UncondOnly(MockBank);
        impl ModelBank for UncondOnly {
            fn sched(&self) -> VpSchedule {
                self.0.sched()
            }
            fn dim(&self, dataset: &str) -> Result<usize, String> {
                self.0.dim(dataset)
            }
            fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
                self.0.eval(dataset, x, t)
            }
            fn supports_cond(&self, _dataset: &str) -> bool {
                false
            }
        }
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> = Arc::new(UncondOnly(
            MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
        ));
        let c = Coordinator::start(bank, CoordinatorConfig::default());
        let mut guided = spec("era", 8, 1);
        guided.task = crate::solvers::TaskSpec { guidance_scale: 2.0, ..Default::default() };
        let gt = c.submit(guided).unwrap();
        let plain = c.submit(spec("era", 8, 2)).unwrap();
        let err = gt.wait().expect_err("guided must be refused");
        assert!(err.contains("no conditional denoiser"), "{err}");
        let ok = plain.wait().unwrap();
        assert!(!ok.cancelled);
        assert_eq!(ok.nfe, 10);
        // Gauges drain despite the rejection.
        assert_eq!(c.telemetry().inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn guided_scale_zero_is_the_unconditional_path() {
        // scale 0 must not wrap, not double rows, and reproduce the
        // plain trajectory bitwise.
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 8, 5);
        s.task = crate::solvers::TaskSpec { guidance_scale: 0.0, ..Default::default() };
        let guided_zero = c.sample(s).unwrap();
        let plain = c.sample(spec("era", 8, 5)).unwrap();
        assert_eq!(guided_zero.samples.as_slice(), plain.samples.as_slice());
        assert_eq!(guided_zero.nfe, plain.nfe);
        c.shutdown();
    }

    #[test]
    fn row_count_mismatch_fails_the_slab_not_the_shard() {
        // A bank that breaks the row-count contract for one dataset
        // must fail only that slab's requests via the normal error
        // path; requests on other slabs — and later submissions — keep
        // being served (previously an assert poisoned the loop thread).
        struct WrongRows(MockBank);
        impl ModelBank for WrongRows {
            fn sched(&self) -> VpSchedule {
                self.0.sched()
            }
            fn dim(&self, dataset: &str) -> Result<usize, String> {
                if dataset == "bad" {
                    Ok(2)
                } else {
                    self.0.dim(dataset)
                }
            }
            fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
                if dataset == "bad" {
                    // One row short: a contract violation, not an Err.
                    Ok(Tensor::zeros(x.rows().saturating_sub(1), x.cols()))
                } else {
                    self.0.eval(dataset, x, t)
                }
            }
            fn eval_cond(
                &self,
                dataset: &str,
                x: &Tensor,
                t: &[f32],
                _c: &[f32],
            ) -> Result<Tensor, String> {
                self.eval(dataset, x, t)
            }
        }
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> = Arc::new(WrongRows(
            MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
        ));
        let c = Coordinator::start(bank, CoordinatorConfig::default());
        let mut bad = spec("era", 8, 1);
        bad.dataset = "bad".into();
        let bad_ticket = c.submit(bad).unwrap();
        let good_ticket = c.submit(spec("era", 8, 2)).unwrap();
        let err = bad_ticket.wait().expect_err("row mismatch must fail the request");
        assert!(err.contains("rows"), "{err}");
        let ok = good_ticket.wait().unwrap();
        assert_eq!(ok.nfe, 10, "batch-mate on another slab must be unaffected");
        // The shard survives: a fresh request still completes.
        let later = c.sample(spec("era", 4, 3)).unwrap();
        assert_eq!(later.samples.rows(), 4);
        assert_eq!(c.telemetry().inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn cancel_during_linger_is_honoured_within_the_wait() {
        // min_rows far above the request's rows forces a linger; the
        // cancel must retire the request during the wait — before any
        // evaluation ships — instead of after the full max_wait.
        let cfg = CoordinatorConfig {
            policy: BatchPolicy {
                max_rows: 256,
                min_rows: 4096,
                max_wait: Duration::from_secs(5),
            },
            ..Default::default()
        };
        let c = Coordinator::start(bank(), cfg);
        let ticket = c.submit(spec("era", 8, 1)).unwrap();
        // Wait until the request is admitted (it then sits lingering).
        let t0 = Instant::now();
        while c.telemetry().requests_admitted.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(2), "request never admitted");
            std::thread::sleep(Duration::from_millis(1));
        }
        ticket.cancel();
        let res = ticket.wait().unwrap();
        assert!(res.cancelled);
        assert_eq!(res.nfe, 0, "no evaluation may ship after the cancel");
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "cancel must not wait out the full linger budget"
        );
        assert_eq!(c.telemetry().evals.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn pipelined_coordinator_is_bitwise_identical_to_depth_one() {
        // The acceptance invariant: pipeline_depth/executors must not
        // change a single bit of any request's trajectory.
        let specs: Vec<RequestSpec> = vec![
            spec("era", 16, 1),
            spec("ddim", 8, 2),
            spec("dpm-2", 8, 3),
            {
                let mut s = spec("era", 8, 4);
                s.task = crate::solvers::TaskSpec {
                    guidance_scale: 2.0,
                    guide_class: 2,
                    ..Default::default()
                };
                s
            },
        ];
        let run = |executors: usize, depth: usize| -> Vec<Vec<f32>> {
            let cfg = CoordinatorConfig {
                executors_per_shard: executors,
                pipeline_depth: depth,
                // Tiny slabs force splits so reassembly is exercised.
                policy: BatchPolicy { max_rows: 8, ..Default::default() },
                ..Default::default()
            };
            let c = Coordinator::start(bank(), cfg);
            let tickets: Vec<_> =
                specs.iter().map(|s| c.submit(s.clone()).unwrap()).collect();
            let outs = tickets
                .into_iter()
                .map(|t| t.wait().unwrap().samples.as_slice().to_vec())
                .collect();
            c.shutdown();
            outs
        };
        let baseline = run(1, 1);
        for (e, d) in [(1, 2), (2, 1), (2, 4), (4, 3)] {
            let got = run(e, d);
            assert_eq!(got, baseline, "executors={e} depth={d} diverged");
        }
    }

    #[test]
    fn per_shard_bank_replicas_via_bank_set() {
        // Two replicas within one shard (BankSet), two executors: the
        // results must match the single-bank path bitwise.
        let sched = VpSchedule::default();
        let set = BankSet::new(vec![bank(), bank()]);
        let cfg = CoordinatorConfig {
            executors_per_shard: 2,
            pipeline_depth: 2,
            ..Default::default()
        };
        let c = Coordinator::start_with_bank_set(
            set,
            cfg,
            Arc::new(crate::kernels::PlanCache::new()),
        );
        let s = spec("era", 32, 7);
        let via_coord = c.sample(s.clone()).unwrap();
        c.shutdown();
        let model = AnalyticGmm::gmm8(sched);
        let mut solver = s.build_solver(sched, 2).unwrap();
        let direct = crate::solvers::sample_with(&mut *solver, &model);
        assert_eq!(via_coord.samples.as_slice(), direct.as_slice());
    }

    #[test]
    fn results_match_inprocess_sampling() {
        // The coordinator path must be numerically identical to driving
        // the solver directly (same seed, same model).
        let sched = VpSchedule::default();
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let s = spec("era", 64, 9);
        let via_coord = c.sample(s.clone()).unwrap();
        c.shutdown();

        let model = AnalyticGmm::gmm8(sched);
        let mut solver = s.build_solver(sched, 2).unwrap();
        let direct = crate::solvers::sample_with(&mut *solver, &model);
        assert_eq!(via_coord.samples.as_slice(), direct.as_slice());
    }

    #[test]
    fn identical_requests_share_one_trajectory_plan() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        for seed in 0..3 {
            let _ = c.sample(spec("era", 16, seed)).unwrap();
        }
        // One configuration -> one plan build, later requests hit.
        assert_eq!(c.plan_cache().misses(), 1);
        assert_eq!(c.plan_cache().hits(), 2);
        assert_eq!(c.plan_cache().len(), 1);
        // A different solver kind is its own plan.
        let _ = c.sample(spec("ddim", 16, 0)).unwrap();
        assert_eq!(c.plan_cache().len(), 2);
        c.shutdown();
    }

    #[test]
    fn samples_are_on_manifold() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let res = c.sample(spec("era", 400, 11)).unwrap();
        let cov = metrics::mode_coverage(&res.samples, &crate::data::gmm8_modes(), 0.5);
        assert!(cov > 0.9, "coverage {cov}");
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue + tiny active set: flooding must yield QueueFull.
        let cfg = CoordinatorConfig { max_active: 1, queue_capacity: 1, ..Default::default() };
        let c = Coordinator::start(bank(), cfg);
        let mut rejected = 0;
        let mut tickets = Vec::new();
        for i in 0..200 {
            match c.submit(spec("era", 64, i)) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected > 0, "queue never filled");
        for t in tickets {
            let _ = t.wait();
        }
        c.shutdown();
    }

    #[test]
    fn zero_deadline_cancels_before_start() {
        // A deadline that is already expired at submit must retire the
        // request at admission: no solver build, no evaluations.
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let mut s = spec("era", 32, 1);
        s.deadline_ms = Some(0);
        let res = c.submit(s).unwrap().wait().unwrap();
        assert!(res.cancelled);
        assert_eq!(res.nfe, 0);
        assert_eq!(res.samples.rows(), 0);
        let t = c.telemetry();
        assert_eq!(t.requests_cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(t.requests_admitted.load(Ordering::Relaxed), 0);
        // Gauges must drain back to zero.
        assert_eq!(t.inflight_requests.load(Ordering::Relaxed), 0);
        assert_eq!(t.inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn default_deadline_applies_when_spec_has_none() {
        let cfg = CoordinatorConfig {
            default_deadline: Some(Duration::from_millis(0)),
            ..Default::default()
        };
        let c = Coordinator::start(bank(), cfg);
        let res = c.sample(spec("era", 8, 1)).unwrap();
        assert!(res.cancelled);
        assert_eq!(res.nfe, 0);
        c.shutdown();
    }

    #[test]
    fn cancel_after_completion_is_harmless() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let ticket = c.submit(spec("era", 16, 3)).unwrap();
        let handle = ticket.cancel_handle();
        let res = ticket.wait().unwrap();
        assert!(!res.cancelled);
        assert_eq!(res.nfe, 10);
        handle.cancel(); // latched after the fact; nothing to retire
        assert!(handle.is_cancelled());
        c.shutdown();
    }

    #[test]
    fn inflight_gauges_return_to_zero() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let tickets: Vec<_> = (0..4).map(|i| c.submit(spec("era", 8, i)).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(c.telemetry().inflight_requests.load(Ordering::Relaxed), 0);
        assert_eq!(c.telemetry().inflight_rows.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_inflight() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let tickets: Vec<_> = (0..4).map(|i| c.submit(spec("ddim", 32, i)).unwrap()).collect();
        c.shutdown(); // must drain, not drop
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn flight_recorder_traces_a_request_end_to_end() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        let ticket = c.submit(spec("era", 16, 1)).unwrap();
        let trace = ticket.id;
        let res = ticket.wait().unwrap();
        assert_eq!(res.nfe, 10);
        // Every span — including the terminal — is recorded before the
        // reply is sent, so the trace is complete once wait() returns.
        let events = c.recorder().snapshot_trace(trace);
        assert!(
            matches!(events.first().map(|e| e.kind), Some(SpanKind::Admitted { rows: 16 })),
            "trace must open with admission: {events:?}"
        );
        assert!(
            matches!(events.last().map(|e| e.kind), Some(SpanKind::Finalize { nfe: 10 })),
            "trace must close with finalize: {events:?}"
        );
        assert!(events.windows(2).all(|w| w[0].at_nanos <= w[1].at_nanos));
        let count = |pred: fn(&SpanKind) -> bool| events.iter().filter(|e| pred(&e.kind)).count();
        assert_eq!(count(|k| matches!(k, SpanKind::LaneAttach { .. })), 1);
        assert_eq!(count(|k| matches!(k, SpanKind::QueueWait { .. })), 1);
        assert!(count(|k| matches!(k, SpanKind::SolverStep { .. })) >= 1);
        assert!(count(|k| matches!(k, SpanKind::SlabDispatch { .. })) >= 1);
        assert!(count(|k| matches!(k, SpanKind::SlabComplete { .. })) >= 1);
        let era: Vec<(f64, u8)> = events
            .iter()
            .filter_map(|e| match e.kind {
                SpanKind::EraStep { delta_eps, k, .. } => Some((delta_eps, k)),
                _ => None,
            })
            .collect();
        assert!(!era.is_empty(), "ERA selections must be traced: {events:?}");
        assert!(era.iter().all(|&(d, k)| d.is_finite() && k >= 2), "{era:?}");
        c.shutdown();
    }

    #[test]
    fn cancelled_request_trace_ends_at_the_cancel_event() {
        // Linger-cancel (no evaluation ever ships): the trace must show
        // the cancel and nothing after it.
        let cfg = CoordinatorConfig {
            policy: BatchPolicy {
                max_rows: 256,
                min_rows: 4096,
                max_wait: Duration::from_secs(5),
            },
            ..Default::default()
        };
        let c = Coordinator::start(bank(), cfg);
        let ticket = c.submit(spec("era", 8, 1)).unwrap();
        let trace = ticket.id;
        let t0 = Instant::now();
        while c.telemetry().requests_admitted.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(2), "request never admitted");
            std::thread::sleep(Duration::from_millis(1));
        }
        ticket.cancel();
        let res = ticket.wait().unwrap();
        assert!(res.cancelled);
        let events = c.recorder().snapshot_trace(trace);
        let cancel_at = events
            .iter()
            .position(|e| matches!(e.kind, SpanKind::Cancelled { .. }))
            .expect("cancel event present");
        assert_eq!(cancel_at, events.len() - 1, "no spans after the cancel: {events:?}");
        c.shutdown();
    }

    #[test]
    fn admission_deadline_uses_the_round_snapshot() {
        // The wall clock may pass a request's deadline between the
        // round's snapshot and the admission check; the decision must
        // follow the snapshot (one consistent clock per round), not
        // the racing wall clock.
        let b = bank();
        let plans = PlanCache::new();
        let tele = Arc::new(Telemetry::new());
        let rec = Arc::new(FlightRecorder::new());
        let mut s = Scheduler::new(tele.clone(), rec, 256);
        let now0 = Instant::now();
        // Mirror submit(): gauges go up before the envelope is visible.
        tele.inflight_requests.fetch_add(1, Ordering::SeqCst);
        tele.inflight_rows.fetch_add(4, Ordering::SeqCst);
        let (reply, rx) = std::sync::mpsc::channel();
        let env = Envelope {
            id: 1,
            spec: spec("era", 4, 1),
            reply: ReplySink::new(reply, None),
            cancel: CancelHandle::new(),
            deadline: Some(now0 + Duration::from_millis(5)),
        };
        std::thread::sleep(Duration::from_millis(10));
        let slot = s.admit(env, b.as_ref(), &plans, now0);
        assert!(slot.is_some(), "round-snapshot deadline check must admit");
        assert!(rx.try_recv().is_err(), "no dead-on-arrival reply may be sent");
        assert_eq!(tele.requests_cancelled.load(Ordering::Relaxed), 0);
        // The same envelope admitted under a fresh snapshot would be
        // dead on arrival — the snapshot is what changed the outcome.
        let (reply2, rx2) = std::sync::mpsc::channel();
        let env2 = Envelope {
            id: 2,
            spec: spec("era", 4, 2),
            reply: ReplySink::new(reply2, None),
            cancel: CancelHandle::new(),
            deadline: Some(now0 + Duration::from_millis(5)),
        };
        tele.inflight_requests.fetch_add(1, Ordering::SeqCst);
        tele.inflight_rows.fetch_add(4, Ordering::SeqCst);
        assert!(s.admit(env2, b.as_ref(), &plans, Instant::now()).is_none());
        assert!(matches!(rx2.try_recv(), Ok(Ok(r)) if r.cancelled));
    }

    /// A constant-eps denoiser: ERA's Lagrange prediction of a constant
    /// function is exact, so `delta_eps` collapses immediately — the
    /// canonical converging workload for the adaptive controller.
    struct ConstEps;
    impl crate::solvers::EpsModel for ConstEps {
        fn eval(&self, x: &Tensor, _t: &[f32]) -> Tensor {
            let mut e = Tensor::zeros(x.rows(), x.cols());
            e.as_mut_slice().fill(0.25);
            e
        }
        fn dim(&self) -> usize {
            2
        }
    }

    fn const_bank() -> Arc<dyn ModelBank> {
        let sched = VpSchedule::default();
        Arc::new(MockBank::new(sched).with("const", Box::new(ConstEps)))
    }

    #[test]
    fn adaptive_controller_cuts_nfe_and_stays_accurate() {
        let run = |threshold: f64| {
            let c = Coordinator::start(const_bank(), CoordinatorConfig::default());
            let mut s = spec("era", 16, 3);
            s.dataset = "const".into();
            s.nfe = 24;
            s.qos = QosClass::Balanced;
            s.conv_threshold = threshold;
            let r = c.sample(s).unwrap();
            c.shutdown();
            r
        };
        let fixed = run(0.0);
        assert!(!fixed.early_stop);
        assert_eq!(fixed.nfe, 24, "threshold 0 must run the full budget");
        let adaptive = run(0.2);
        assert!(adaptive.early_stop, "converging workload must stop early");
        assert!(
            (adaptive.nfe as f64) < 0.8 * 24.0,
            "mean NFE must drop >= 20%: delivered {}",
            adaptive.nfe
        );
        let max_abs = fixed
            .samples
            .as_slice()
            .iter()
            .zip(adaptive.samples.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_abs < 1e-3, "early-stopped iterate drifted: max|d|={max_abs}");
        let t_adaptive = run(0.2);
        assert_eq!(
            t_adaptive.samples.as_slice(),
            adaptive.samples.as_slice(),
            "early stop must be deterministic"
        );
    }

    #[test]
    fn strict_qos_ignores_the_convergence_controller() {
        let c = Coordinator::start(const_bank(), CoordinatorConfig::default());
        let mut s = spec("era", 8, 5);
        s.dataset = "const".into();
        s.nfe = 24;
        s.conv_threshold = 0.2; // strict (default) must force this off
        let r = c.sample(s).unwrap();
        assert!(!r.early_stop);
        assert_eq!(r.nfe, 24);
        c.shutdown();
    }

    #[test]
    fn besteffort_degrades_under_deadline_pressure() {
        // A besteffort request whose deadline budget is mostly spent
        // must degrade toward its NFE floor and complete (early_stop),
        // not blow the deadline and come back cancelled.
        struct SlowConstEps;
        impl crate::solvers::EpsModel for SlowConstEps {
            fn eval(&self, x: &Tensor, _t: &[f32]) -> Tensor {
                std::thread::sleep(Duration::from_millis(2));
                let mut e = Tensor::zeros(x.rows(), x.cols());
                e.as_mut_slice().fill(0.25);
                e
            }
            fn dim(&self) -> usize {
                2
            }
        }
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> =
            Arc::new(MockBank::new(sched).with("const", Box::new(SlowConstEps)));
        let c = Coordinator::start(bank, CoordinatorConfig::default());
        let mut s = spec("era", 8, 7);
        s.dataset = "const".into();
        s.nfe = 2000; // ~4s of evaluations: far more than the deadline affords
        s.qos = QosClass::BestEffort;
        s.deadline_ms = Some(500);
        let r = c.sample(s).unwrap();
        assert!(!r.cancelled, "pressured besteffort must not blow the deadline");
        assert!(r.early_stop, "pressured besteffort must finish early");
        assert!(r.nfe < 2000, "delivered NFE must be degraded: {}", r.nfe);
        assert_eq!(r.samples.rows(), 8);
        assert_eq!(c.telemetry().degraded_requests.load(Ordering::Relaxed), 1);
        assert!(c.telemetry().early_stops.load(Ordering::Relaxed) >= 1);
        c.shutdown();
    }

    #[test]
    fn telemetry_counts_line_up() {
        let c = Coordinator::start(bank(), CoordinatorConfig::default());
        for i in 0..3 {
            let _ = c.sample(spec("era", 8, i)).unwrap();
        }
        let t = c.telemetry();
        assert_eq!(t.requests_admitted.load(Ordering::Relaxed), 3);
        assert_eq!(t.requests_finished.load(Ordering::Relaxed), 3);
        assert!(t.evals.load(Ordering::Relaxed) >= 10);
        assert!(t.summary().contains("finished=3"));
        c.shutdown();
    }
}
