//! Serving telemetry: counters, latency recording, and batch-occupancy
//! tracking for the Tab. 7 reproduction and the §Perf iteration log.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Shared counters (cheap, lock-free) + latency samples (mutex; only
/// touched once per finished request).
#[derive(Default)]
pub struct Telemetry {
    pub requests_admitted: AtomicUsize,
    pub requests_finished: AtomicUsize,
    pub requests_rejected: AtomicUsize,
    /// Requests retired early by client cancellation or deadline expiry.
    pub requests_cancelled: AtomicUsize,
    /// Workload mix: admitted requests using classifier-free guidance
    /// (each pins 2x its sample rows), img2img partial trajectories, and
    /// stochastic (churned) sampling. One request may count in several.
    pub guided_requests: AtomicUsize,
    pub img2img_requests: AtomicUsize,
    pub stochastic_requests: AtomicUsize,
    /// Gauge: requests submitted but not yet retired (queued + active).
    /// The pool router reads this for least-loaded placement.
    pub inflight_requests: AtomicUsize,
    /// Gauge: rows (n_samples) belonging to in-flight requests.
    pub inflight_rows: AtomicUsize,
    /// Fused model evaluations dispatched.
    pub evals: AtomicUsize,
    /// Rows packed into those evaluations.
    pub rows: AtomicUsize,
    /// Sum over evals of (bucket - rows): padding waste, in rows.
    pub padded_rows: AtomicUsize,
    /// Total solver transitions stepped.
    pub steps: AtomicUsize,
    /// Busy-loop rounds executed.
    pub rounds: AtomicUsize,
    /// Nanoseconds spent inside model evaluation.
    pub eval_nanos: AtomicU64,
    /// Nanoseconds the shard's executor threads spent evaluating slabs
    /// (summed across executors; > wall time when several overlap).
    pub executor_busy_nanos: AtomicU64,
    /// Nanoseconds the executor threads spent waiting for work.
    pub executor_idle_nanos: AtomicU64,
    /// Gauge: slabs dispatched to the executor pool and not yet routed
    /// back by the scheduler.
    pub inflight_slabs: AtomicUsize,
    /// Pipeline-depth histogram: bucket `d-1` counts dispatches made
    /// while `d` rounds (this one included) were in flight; the last
    /// bucket absorbs `>= DEPTH_HIST_BUCKETS`.
    pub depth_hist: [AtomicUsize; DEPTH_HIST_BUCKETS],
    /// Gauge: live lanes in the shard's lane engine (updated at each
    /// dispatch round).
    pub lanes: AtomicUsize,
    /// Lane-occupancy histogram: bucket `m-1` counts lane dispatches
    /// whose lane held `m` member requests; the last bucket absorbs
    /// `>= LANE_OCC_BUCKETS` (deep fusion).
    pub lane_occ_hist: [AtomicUsize; LANE_OCC_BUCKETS],
    /// Sum + count of final per-request `delta_eps` values (ERA
    /// requests only) — the wire-visible error-robust diagnostics,
    /// aggregated for `stats`.
    delta_eps_agg: Mutex<(f64, usize)>,
    latencies: Mutex<Vec<f64>>,
    queue_waits: Mutex<Vec<f64>>,
}

/// Buckets of the pipeline-depth histogram (depth 1..=8+).
pub const DEPTH_HIST_BUCKETS: usize = 8;

/// Buckets of the lane-occupancy histogram (1..=8+ members per lane).
pub const LANE_OCC_BUCKETS: usize = 8;

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_finish(&self, total_seconds: f64, queue_seconds: f64) {
        self.requests_finished.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap().push(total_seconds);
        self.queue_waits.lock().unwrap().push(queue_seconds);
    }

    /// Latency percentile over finished requests (0.0..=1.0), seconds.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        percentile(&self.latencies.lock().unwrap(), q)
    }

    /// Snapshot of raw per-request latencies, seconds (unsorted). The
    /// pool merges these across shards for exact pooled percentiles.
    pub fn latency_samples(&self) -> Vec<f64> {
        self.latencies.lock().unwrap().clone()
    }

    /// Snapshot of raw per-request queue waits, seconds (unsorted).
    pub fn queue_wait_samples(&self) -> Vec<f64> {
        self.queue_waits.lock().unwrap().clone()
    }

    pub fn queue_wait_percentile(&self, q: f64) -> f64 {
        percentile(&self.queue_waits.lock().unwrap(), q)
    }

    pub fn mean_latency(&self) -> f64 {
        let l = self.latencies.lock().unwrap();
        if l.is_empty() {
            0.0
        } else {
            l.iter().sum::<f64>() / l.len() as f64
        }
    }

    /// Record one round dispatch observed at `depth` in-flight rounds.
    pub fn observe_depth(&self, depth: usize) {
        let bucket = depth.clamp(1, DEPTH_HIST_BUCKETS) - 1;
        self.depth_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the pipeline-depth histogram (bucket `d-1` = depth
    /// `d`, last bucket = deeper).
    pub fn depth_hist_snapshot(&self) -> [usize; DEPTH_HIST_BUCKETS] {
        let mut out = [0usize; DEPTH_HIST_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.depth_hist.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Record one lane dispatch carrying `members` fused requests.
    pub fn observe_lane_occupancy(&self, members: usize) {
        let bucket = members.clamp(1, LANE_OCC_BUCKETS) - 1;
        self.lane_occ_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the lane-occupancy histogram (bucket `m-1` = lanes
    /// dispatched with `m` members, last bucket = more).
    pub fn lane_occ_snapshot(&self) -> [usize; LANE_OCC_BUCKETS] {
        let mut out = [0usize; LANE_OCC_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.lane_occ_hist.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Record one finished ERA request's final error measure.
    pub fn record_delta_eps(&self, d: f64) {
        let mut agg = self.delta_eps_agg.lock().unwrap();
        agg.0 += d;
        agg.1 += 1;
    }

    /// `(sum, count)` of recorded final `delta_eps` values — the pool
    /// merges these across shards before averaging.
    pub fn delta_eps_agg(&self) -> (f64, usize) {
        *self.delta_eps_agg.lock().unwrap()
    }

    /// Mean final `delta_eps` over finished ERA requests (0 when none).
    pub fn mean_delta_eps(&self) -> f64 {
        let (sum, count) = self.delta_eps_agg();
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    /// Fraction of executor thread time spent evaluating (0 when no
    /// executor has ticked yet).
    pub fn executor_busy_fraction(&self) -> f64 {
        let busy = self.executor_busy_nanos.load(Ordering::Relaxed) as f64;
        let idle = self.executor_idle_nanos.load(Ordering::Relaxed) as f64;
        if busy + idle == 0.0 {
            0.0
        } else {
            busy / (busy + idle)
        }
    }

    /// Mean rows per fused evaluation (batching efficiency).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let evals = self.evals.load(Ordering::Relaxed);
        if evals == 0 {
            0.0
        } else {
            self.rows.load(Ordering::Relaxed) as f64 / evals as f64
        }
    }

    /// Fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let rows = self.rows.load(Ordering::Relaxed);
        let pad = self.padded_rows.load(Ordering::Relaxed);
        if rows + pad == 0 {
            0.0
        } else {
            pad as f64 / (rows + pad) as f64
        }
    }

    /// One-line summary for logs / bench output.
    pub fn summary(&self) -> String {
        format!(
            "finished={} cancelled={} rejected={} evals={} rows={} occupancy={:.1} pad={:.1}% \
             guided={} img2img={} sde={} exec_busy={:.0}% inflight_slabs={} lanes={} \
             p50={:.1}ms p99={:.1}ms",
            self.requests_finished.load(Ordering::Relaxed),
            self.requests_cancelled.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.evals.load(Ordering::Relaxed),
            self.rows.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            100.0 * self.padding_fraction(),
            self.guided_requests.load(Ordering::Relaxed),
            self.img2img_requests.load(Ordering::Relaxed),
            self.stochastic_requests.load(Ordering::Relaxed),
            100.0 * self.executor_busy_fraction(),
            self.inflight_slabs.load(Ordering::Relaxed),
            self.lanes.load(Ordering::Relaxed),
            1e3 * self.latency_percentile(0.5),
            1e3 * self.latency_percentile(0.99),
        )
    }
}

fn percentile(src: &[f64], q: f64) -> f64 {
    let mut v = src.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted_percentile(&v, q)
}

/// Nearest-rank percentile over an already-sorted slice (0.0..=1.0).
/// Shared with the pool's merged stats so per-shard and pool-wide
/// quantiles can never drift onto different index conventions.
pub(crate) fn sorted_percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let t = Telemetry::new();
        for i in 1..=100 {
            t.record_finish(i as f64, 0.0);
        }
        assert_eq!(t.requests_finished.load(Ordering::Relaxed), 100);
        assert!((t.latency_percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((t.latency_percentile(1.0) - 100.0).abs() < 1e-9);
        assert!((t.latency_percentile(0.5) - 50.0).abs() <= 1.0);
        assert!((t.mean_latency() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn occupancy_and_padding() {
        let t = Telemetry::new();
        t.evals.fetch_add(2, Ordering::Relaxed);
        t.rows.fetch_add(24, Ordering::Relaxed);
        t.padded_rows.fetch_add(8, Ordering::Relaxed);
        assert!((t.mean_batch_occupancy() - 12.0).abs() < 1e-9);
        assert!((t.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn latency_snapshots_match_counts() {
        let t = Telemetry::new();
        t.record_finish(1.0, 0.5);
        t.record_finish(2.0, 0.25);
        assert_eq!(t.latency_samples().len(), 2);
        assert_eq!(t.queue_wait_samples().len(), 2);
        assert!(t.summary().contains("cancelled=0"));
    }

    #[test]
    fn depth_histogram_buckets_and_clamps() {
        let t = Telemetry::new();
        t.observe_depth(1);
        t.observe_depth(1);
        t.observe_depth(3);
        t.observe_depth(0); // clamped into the depth-1 bucket
        t.observe_depth(500); // clamped into the last bucket
        let h = t.depth_hist_snapshot();
        assert_eq!(h[0], 3);
        assert_eq!(h[2], 1);
        assert_eq!(h[DEPTH_HIST_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn lane_occupancy_histogram_buckets_and_clamps() {
        let t = Telemetry::new();
        t.observe_lane_occupancy(1);
        t.observe_lane_occupancy(1);
        t.observe_lane_occupancy(4);
        t.observe_lane_occupancy(0); // clamped into the 1-member bucket
        t.observe_lane_occupancy(64); // clamped into the last bucket
        let h = t.lane_occ_snapshot();
        assert_eq!(h[0], 3);
        assert_eq!(h[3], 1);
        assert_eq!(h[LANE_OCC_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<usize>(), 5);
        t.lanes.store(7, Ordering::Relaxed);
        assert!(t.summary().contains("lanes=7"));
    }

    #[test]
    fn delta_eps_aggregation_means_over_count() {
        let t = Telemetry::new();
        assert_eq!(t.mean_delta_eps(), 0.0);
        t.record_delta_eps(0.2);
        t.record_delta_eps(0.4);
        let (sum, count) = t.delta_eps_agg();
        assert!((sum - 0.6).abs() < 1e-12);
        assert_eq!(count, 2);
        assert!((t.mean_delta_eps() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn executor_busy_fraction_from_clocks() {
        let t = Telemetry::new();
        assert_eq!(t.executor_busy_fraction(), 0.0);
        t.executor_busy_nanos.fetch_add(300, Ordering::Relaxed);
        t.executor_idle_nanos.fetch_add(100, Ordering::Relaxed);
        assert!((t.executor_busy_fraction() - 0.75).abs() < 1e-12);
        assert!(t.summary().contains("exec_busy=75%"));
    }

    #[test]
    fn empty_telemetry_is_zero() {
        let t = Telemetry::new();
        assert_eq!(t.latency_percentile(0.5), 0.0);
        assert_eq!(t.mean_batch_occupancy(), 0.0);
        assert_eq!(t.padding_fraction(), 0.0);
        assert!(t.summary().contains("finished=0"));
    }
}
