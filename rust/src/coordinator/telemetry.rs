//! Serving telemetry: counters, latency recording, per-stage latency
//! histograms, and batch-occupancy tracking for the Tab. 7 reproduction
//! and the §Perf iteration log.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::rng::Rng;

/// Shared counters (cheap, lock-free) + latency samples (mutex; only
/// touched once per finished request).
#[derive(Default)]
pub struct Telemetry {
    pub requests_admitted: AtomicUsize,
    pub requests_finished: AtomicUsize,
    pub requests_rejected: AtomicUsize,
    /// Requests retired early by client cancellation or deadline expiry.
    pub requests_cancelled: AtomicUsize,
    /// Requests the convergence controller retired before their full
    /// NFE budget (delivered with the `early_stop` marker).
    pub early_stops: AtomicUsize,
    /// Requests latched to their NFE floor by QoS degradation (pool
    /// admission cap or scheduler deadline pressure).
    pub degraded_requests: AtomicUsize,
    /// Workload mix: admitted requests using classifier-free guidance
    /// (each pins 2x its sample rows), img2img partial trajectories, and
    /// stochastic (churned) sampling. One request may count in several.
    pub guided_requests: AtomicUsize,
    pub img2img_requests: AtomicUsize,
    pub stochastic_requests: AtomicUsize,
    /// Gauge: requests submitted but not yet retired (queued + active).
    /// The pool router reads this for least-loaded placement.
    pub inflight_requests: AtomicUsize,
    /// Gauge: rows (n_samples) belonging to in-flight requests.
    pub inflight_rows: AtomicUsize,
    /// Fused model evaluations dispatched.
    pub evals: AtomicUsize,
    /// Rows packed into those evaluations.
    pub rows: AtomicUsize,
    /// Sum over evals of (bucket - rows): padding waste, in rows.
    pub padded_rows: AtomicUsize,
    /// Total solver transitions stepped.
    pub steps: AtomicUsize,
    /// Busy-loop rounds executed.
    pub rounds: AtomicUsize,
    /// Nanoseconds spent inside model evaluation.
    pub eval_nanos: AtomicU64,
    /// Nanoseconds the shard's executor threads spent evaluating slabs
    /// (summed across executors; > wall time when several overlap).
    pub executor_busy_nanos: AtomicU64,
    /// Nanoseconds the executor threads spent waiting for work.
    pub executor_idle_nanos: AtomicU64,
    /// Gauge: slabs dispatched to the executor pool and not yet routed
    /// back by the scheduler.
    pub inflight_slabs: AtomicUsize,
    /// Bytes crossing the host↔engine boundary: slab payloads and eps
    /// outputs on the slab path; one-time iterate uploads, per-step
    /// coefficient ops/outcomes, and devolve gathers on the resident
    /// path. The resident-lane bench asserts this stays O(1) per step.
    pub host_bytes_transferred: AtomicU64,
    /// Gauge: lanes currently stepping engine-resident (state lives in
    /// engine-owned buffers; the host ships only coefficients).
    pub resident_lanes: AtomicUsize,
    /// Pipeline-depth histogram: bucket `d-1` counts dispatches made
    /// while `d` rounds (this one included) were in flight; the last
    /// bucket absorbs `>= DEPTH_HIST_BUCKETS`.
    pub depth_hist: [AtomicUsize; DEPTH_HIST_BUCKETS],
    /// Gauge: live lanes in the shard's lane engine (updated at each
    /// dispatch round).
    pub lanes: AtomicUsize,
    /// Lane-occupancy histogram: bucket `m-1` counts lane dispatches
    /// whose lane held `m` member requests; the last bucket absorbs
    /// `>= LANE_OCC_BUCKETS` (deep fusion).
    pub lane_occ_hist: [AtomicUsize; LANE_OCC_BUCKETS],
    /// Delivered-NFE histogram over retired requests (power-of-two
    /// upper edges, [`NFE_HIST_BOUNDS`]; last slot overflow). Under the
    /// convergence controller this is the load-shed diagnostic: mass
    /// below the budget edge = NFE actually saved.
    pub nfe_hist: [AtomicU64; NFE_HIST_BUCKETS],
    /// Per-stage latency histograms (log-scaled fixed buckets, seconds):
    /// queue wait before the first solver step, host time per lane
    /// solver step/deliver, engine eval time per slab, and the finalize
    /// (deliver-to-reply) path. Rendered as Prometheus histograms and
    /// summarised p50/p99 per stage on the heartbeat line.
    pub stage_queue: StageHist,
    pub stage_solver: StageHist,
    pub stage_eval: StageHist,
    pub stage_finalize: StageHist,
    /// Sum + count of final per-request `delta_eps` values (ERA
    /// requests only) — the wire-visible error-robust diagnostics,
    /// aggregated for `stats`.
    delta_eps_agg: Mutex<(f64, usize)>,
    latencies: Mutex<Reservoir>,
    queue_waits: Mutex<Reservoir>,
}

/// Buckets of the pipeline-depth histogram (depth 1..=8+).
pub const DEPTH_HIST_BUCKETS: usize = 8;

/// Buckets of the lane-occupancy histogram (1..=8+ members per lane).
pub const LANE_OCC_BUCKETS: usize = 8;

/// Upper edges of the delivered-NFE histogram buckets; one implicit
/// overflow slot follows.
pub const NFE_HIST_BOUNDS: [usize; NFE_HIST_BUCKETS - 1] = [1, 2, 4, 8, 16, 32, 64];

/// Bucket count of the delivered-NFE histogram (edges + overflow).
pub const NFE_HIST_BUCKETS: usize = 8;

/// Stage labels, in the order `stage_snapshots` returns them.
pub const STAGES: [&str; 4] = ["queue", "solver_step", "eval", "finalize"];

/// Upper bucket edges (seconds) of the per-stage latency histograms:
/// half-decade log scale from 10µs to 1s, plus an implicit overflow
/// (`+Inf`) bucket.
pub const STAGE_BOUNDS: [f64; STAGE_BUCKETS - 1] = [
    1e-5, 3.2e-5, 1e-4, 3.2e-4, 1e-3, 3.2e-3, 1e-2, 3.2e-2, 1e-1, 3.2e-1, 1.0,
];

/// Bucket count of a [`StageHist`]: the bounds plus the overflow slot.
pub const STAGE_BUCKETS: usize = 12;

/// Fixed-bucket latency histogram for one pipeline stage. Lock-free
/// (atomic buckets), allocation-free to observe, mergeable across
/// shards by element-wise summation.
#[derive(Default)]
pub struct StageHist {
    buckets: [AtomicU64; STAGE_BUCKETS],
    sum_nanos: AtomicU64,
    count: AtomicU64,
}

impl StageHist {
    pub fn observe_seconds(&self, seconds: f64) {
        self.observe_nanos((seconds.max(0.0) * 1e9) as u64);
    }

    pub fn observe_nanos(&self, nanos: u64) {
        let seconds = nanos as f64 * 1e-9;
        let bucket = STAGE_BOUNDS
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(STAGE_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StageHistSnapshot {
        let mut buckets = [0u64; STAGE_BUCKETS];
        for (o, b) in buckets.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        StageHistSnapshot {
            buckets,
            sum_seconds: self.sum_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Mergeable, plain-data view of a [`StageHist`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StageHistSnapshot {
    /// Per-bucket (non-cumulative) counts; the last slot is overflow.
    pub buckets: [u64; STAGE_BUCKETS],
    pub sum_seconds: f64,
    pub count: u64,
}

impl StageHistSnapshot {
    /// Element-wise merge (the pool's cross-shard rule: sums add).
    pub fn merge(&mut self, other: &StageHistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.sum_seconds += other.sum_seconds;
        self.count += other.count;
    }

    /// Quantile estimate (seconds) from the bucket counts: the upper
    /// edge of the bucket holding the `q`-th observation. A quantile
    /// landing in the overflow bucket has no finite upper edge and
    /// reports `f64::INFINITY` — rendering it as any finite number
    /// would silently under-report p99 on slow stages (renderers print
    /// it `+Inf`-aware, see [`fmt_quantile_ms`]). Coarse by design —
    /// exact pooled percentiles still come from the latency reservoir.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if i < STAGE_BOUNDS.len() {
                    STAGE_BOUNDS[i]
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }

    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        Json::obj(vec![
            (
                "buckets",
                Json::Arr(self.buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("sum_seconds", Json::Num(self.sum_seconds)),
            ("count", Json::Num(self.count as f64)),
        ])
    }
}

/// Render a stage quantile (seconds) as a millisecond figure for
/// heartbeat summaries, `+Inf`-aware: an overflow-bucket quantile
/// prints as `+Inf` instead of a made-up finite number.
pub fn fmt_quantile_ms(seconds: f64) -> String {
    if seconds.is_infinite() {
        "+Inf".into()
    } else {
        format!("{:.2}", 1e3 * seconds)
    }
}

/// Capacity of the latency/queue-wait reservoirs: bounded memory under
/// sustained traffic, exact below the cap (tests and pooled-percentile
/// merges at realistic loads see every sample).
pub const RESERVOIR_CAP: usize = 4096;

/// Fixed-capacity uniform reservoir (Vitter's algorithm R) with a
/// deterministic seed: below `cap` it stores every sample exactly; past
/// it, each of the `seen` observations has equal probability of being
/// retained, so percentiles stay meaningful at millions of requests
/// without unbounded memory.
pub(crate) struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    pub(crate) fn new(cap: usize, seed: u64) -> Self {
        Reservoir { cap: cap.max(1), seen: 0, samples: Vec::new(), rng: Rng::new(seed) }
    }

    pub(crate) fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    pub(crate) fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub(crate) fn seen(&self) -> u64 {
        self.seen
    }
}

impl Default for Reservoir {
    fn default() -> Self {
        // Deterministic seed: reservoir contents are a pure function of
        // the observation sequence.
        Reservoir::new(RESERVOIR_CAP, 0x0b5e_ed5e_ed5e_ed01)
    }
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_finish(&self, total_seconds: f64, queue_seconds: f64) {
        self.requests_finished.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap().push(total_seconds);
        self.queue_waits.lock().unwrap().push(queue_seconds);
        self.stage_queue.observe_seconds(queue_seconds);
    }

    /// Latency percentile over finished requests (0.0..=1.0), seconds.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        percentile(self.latencies.lock().unwrap().samples(), q)
    }

    /// Snapshot of retained per-request latencies, seconds (unsorted).
    /// Exact below [`RESERVOIR_CAP`]; a uniform subsample past it. The
    /// pool merges these across shards for pooled percentiles.
    pub fn latency_samples(&self) -> Vec<f64> {
        self.latencies.lock().unwrap().samples().to_vec()
    }

    /// Snapshot of retained per-request queue waits, seconds (unsorted).
    pub fn queue_wait_samples(&self) -> Vec<f64> {
        self.queue_waits.lock().unwrap().samples().to_vec()
    }

    pub fn queue_wait_percentile(&self, q: f64) -> f64 {
        percentile(self.queue_waits.lock().unwrap().samples(), q)
    }

    pub fn mean_latency(&self) -> f64 {
        let l = self.latencies.lock().unwrap();
        let s = l.samples();
        if s.is_empty() {
            0.0
        } else {
            s.iter().sum::<f64>() / s.len() as f64
        }
    }

    /// Per-stage latency histogram snapshots, in [`STAGES`] order
    /// (queue, solver_step, eval, finalize).
    pub fn stage_snapshots(&self) -> [StageHistSnapshot; 4] {
        [
            self.stage_queue.snapshot(),
            self.stage_solver.snapshot(),
            self.stage_eval.snapshot(),
            self.stage_finalize.snapshot(),
        ]
    }

    /// Record one round dispatch observed at `depth` in-flight rounds.
    pub fn observe_depth(&self, depth: usize) {
        let bucket = depth.clamp(1, DEPTH_HIST_BUCKETS) - 1;
        self.depth_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the pipeline-depth histogram (bucket `d-1` = depth
    /// `d`, last bucket = deeper).
    pub fn depth_hist_snapshot(&self) -> [usize; DEPTH_HIST_BUCKETS] {
        let mut out = [0usize; DEPTH_HIST_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.depth_hist.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Record one lane dispatch carrying `members` fused requests.
    pub fn observe_lane_occupancy(&self, members: usize) {
        let bucket = members.clamp(1, LANE_OCC_BUCKETS) - 1;
        self.lane_occ_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the lane-occupancy histogram (bucket `m-1` = lanes
    /// dispatched with `m` members, last bucket = more).
    pub fn lane_occ_snapshot(&self) -> [usize; LANE_OCC_BUCKETS] {
        let mut out = [0usize; LANE_OCC_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.lane_occ_hist.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Record one retired request's delivered NFE.
    pub fn observe_delivered_nfe(&self, nfe: usize) {
        let bucket = NFE_HIST_BOUNDS
            .iter()
            .position(|&b| nfe <= b)
            .unwrap_or(NFE_HIST_BUCKETS - 1);
        self.nfe_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the delivered-NFE histogram (per-bucket counts,
    /// [`NFE_HIST_BOUNDS`] edges, last slot overflow).
    pub fn nfe_hist_snapshot(&self) -> [u64; NFE_HIST_BUCKETS] {
        let mut out = [0u64; NFE_HIST_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.nfe_hist.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Record one finished ERA request's final error measure.
    pub fn record_delta_eps(&self, d: f64) {
        let mut agg = self.delta_eps_agg.lock().unwrap();
        agg.0 += d;
        agg.1 += 1;
    }

    /// `(sum, count)` of recorded final `delta_eps` values — the pool
    /// merges these across shards before averaging.
    pub fn delta_eps_agg(&self) -> (f64, usize) {
        *self.delta_eps_agg.lock().unwrap()
    }

    /// Mean final `delta_eps` over finished ERA requests (0 when none).
    pub fn mean_delta_eps(&self) -> f64 {
        let (sum, count) = self.delta_eps_agg();
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    /// Fraction of executor thread time spent evaluating (0 when no
    /// executor has ticked yet).
    pub fn executor_busy_fraction(&self) -> f64 {
        let busy = self.executor_busy_nanos.load(Ordering::Relaxed) as f64;
        let idle = self.executor_idle_nanos.load(Ordering::Relaxed) as f64;
        if busy + idle == 0.0 {
            0.0
        } else {
            busy / (busy + idle)
        }
    }

    /// Mean rows per fused evaluation (batching efficiency).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let evals = self.evals.load(Ordering::Relaxed);
        if evals == 0 {
            0.0
        } else {
            self.rows.load(Ordering::Relaxed) as f64 / evals as f64
        }
    }

    /// Fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let rows = self.rows.load(Ordering::Relaxed);
        let pad = self.padded_rows.load(Ordering::Relaxed);
        if rows + pad == 0 {
            0.0
        } else {
            pad as f64 / (rows + pad) as f64
        }
    }

    /// One-line summary for logs / bench output. Ends with end-to-end
    /// p50/p99 plus per-stage p50/p99 (queue vs solver-step vs eval) so
    /// operators can spot which stage regressed without pulling JSON.
    pub fn summary(&self) -> String {
        let [queue, solver, eval, _finalize] = self.stage_snapshots();
        format!(
            "finished={} cancelled={} rejected={} early_stops={} degraded={} evals={} rows={} \
             occupancy={:.1} pad={:.1}% \
             guided={} img2img={} sde={} exec_busy={:.0}% inflight_slabs={} lanes={} \
             p50={:.1}ms p99={:.1}ms queue={}/{}ms step={}/{}ms eval={}/{}ms",
            self.requests_finished.load(Ordering::Relaxed),
            self.requests_cancelled.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.early_stops.load(Ordering::Relaxed),
            self.degraded_requests.load(Ordering::Relaxed),
            self.evals.load(Ordering::Relaxed),
            self.rows.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            100.0 * self.padding_fraction(),
            self.guided_requests.load(Ordering::Relaxed),
            self.img2img_requests.load(Ordering::Relaxed),
            self.stochastic_requests.load(Ordering::Relaxed),
            100.0 * self.executor_busy_fraction(),
            self.inflight_slabs.load(Ordering::Relaxed),
            self.lanes.load(Ordering::Relaxed),
            1e3 * self.latency_percentile(0.5),
            1e3 * self.latency_percentile(0.99),
            fmt_quantile_ms(queue.quantile(0.5)),
            fmt_quantile_ms(queue.quantile(0.99)),
            fmt_quantile_ms(solver.quantile(0.5)),
            fmt_quantile_ms(solver.quantile(0.99)),
            fmt_quantile_ms(eval.quantile(0.5)),
            fmt_quantile_ms(eval.quantile(0.99)),
        )
    }
}

fn percentile(src: &[f64], q: f64) -> f64 {
    let mut v = src.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted_percentile(&v, q)
}

/// Nearest-rank percentile over an already-sorted slice (0.0..=1.0).
/// Shared with the pool's merged stats so per-shard and pool-wide
/// quantiles can never drift onto different index conventions.
pub(crate) fn sorted_percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Connection-level counters for a serving front end (the blocking
/// [`crate::server::Server`] or the readiness gateway). One instance
/// per front end, registered with the pool
/// ([`crate::pool::WorkerPool::register_conn_counters`]) so multiple
/// servers fronting one pool merge into a single [`ConnSnapshot`] in
/// `PoolStats` — the same merge story the per-shard telemetry follows.
#[derive(Default)]
pub struct ConnCounters {
    /// Gauge: connections currently registered with the front end.
    pub open_connections: AtomicUsize,
    /// Connections admitted into service (excludes rejects).
    pub accepted_total: AtomicUsize,
    /// Connections turned away (over the connection cap).
    pub rejected_total: AtomicUsize,
    /// Times a connection's read interest was parked because its
    /// bounded write queue was full (gateway backpressure).
    pub backpressure_stalls: AtomicUsize,
    /// Wire bytes read from clients (request lines + binary payloads).
    pub bytes_in: AtomicUsize,
    /// Wire bytes written to clients (reply lines + binary payloads).
    pub bytes_out: AtomicUsize,
}

impl ConnCounters {
    pub fn new() -> ConnCounters {
        ConnCounters::default()
    }

    pub fn snapshot(&self) -> ConnSnapshot {
        ConnSnapshot {
            open_connections: self.open_connections.load(Ordering::Relaxed),
            accepted_total: self.accepted_total.load(Ordering::Relaxed),
            rejected_total: self.rejected_total.load(Ordering::Relaxed),
            backpressure_stalls: self.backpressure_stalls.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`ConnCounters`]. Merge rule: every field
/// sums — the gauge sums across front ends (total open connections on
/// the pool), the counters are monotone tallies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConnSnapshot {
    pub open_connections: usize,
    pub accepted_total: usize,
    pub rejected_total: usize,
    pub backpressure_stalls: usize,
    pub bytes_in: usize,
    pub bytes_out: usize,
}

impl ConnSnapshot {
    pub fn merge(&mut self, other: &ConnSnapshot) {
        self.open_connections += other.open_connections;
        self.accepted_total += other.accepted_total;
        self.rejected_total += other.rejected_total;
        self.backpressure_stalls += other.backpressure_stalls;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
    }

    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        Json::obj(vec![
            ("open", Json::Num(self.open_connections as f64)),
            ("accepted", Json::Num(self.accepted_total as f64)),
            ("rejected", Json::Num(self.rejected_total as f64)),
            ("backpressure_stalls", Json::Num(self.backpressure_stalls as f64)),
            ("bytes_in", Json::Num(self.bytes_in as f64)),
            ("bytes_out", Json::Num(self.bytes_out as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let t = Telemetry::new();
        for i in 1..=100 {
            t.record_finish(i as f64, 0.0);
        }
        assert_eq!(t.requests_finished.load(Ordering::Relaxed), 100);
        assert!((t.latency_percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((t.latency_percentile(1.0) - 100.0).abs() < 1e-9);
        assert!((t.latency_percentile(0.5) - 50.0).abs() <= 1.0);
        assert!((t.mean_latency() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn occupancy_and_padding() {
        let t = Telemetry::new();
        t.evals.fetch_add(2, Ordering::Relaxed);
        t.rows.fetch_add(24, Ordering::Relaxed);
        t.padded_rows.fetch_add(8, Ordering::Relaxed);
        assert!((t.mean_batch_occupancy() - 12.0).abs() < 1e-9);
        assert!((t.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn latency_snapshots_match_counts() {
        let t = Telemetry::new();
        t.record_finish(1.0, 0.5);
        t.record_finish(2.0, 0.25);
        assert_eq!(t.latency_samples().len(), 2);
        assert_eq!(t.queue_wait_samples().len(), 2);
        assert!(t.summary().contains("cancelled=0"));
    }

    #[test]
    fn reservoir_is_exact_below_cap() {
        let mut r = Reservoir::new(16, 7);
        for i in 0..16 {
            r.push(i as f64);
        }
        assert_eq!(r.samples().len(), 16);
        assert_eq!(r.seen(), 16);
        let want: Vec<f64> = (0..16).map(|i| i as f64).collect();
        assert_eq!(r.samples(), &want[..], "below cap every sample is kept in order");
    }

    #[test]
    fn reservoir_bounds_memory_and_stays_uniform() {
        let mut r = Reservoir::new(64, 42);
        for i in 0..100_000 {
            r.push(i as f64);
        }
        assert_eq!(r.samples().len(), 64, "capacity bounds retained samples");
        assert_eq!(r.seen(), 100_000);
        // Uniform retention: the retained sample mean is close to the
        // stream mean (loose band, deterministic seed so never flaky).
        let mean = r.samples().iter().sum::<f64>() / 64.0;
        assert!(
            (mean - 49_999.5).abs() < 20_000.0,
            "retained mean {mean} not representative"
        );
        // Deterministic: same seed + stream = same retained set.
        let mut r2 = Reservoir::new(64, 42);
        for i in 0..100_000 {
            r2.push(i as f64);
        }
        assert_eq!(r.samples(), r2.samples());
    }

    #[test]
    fn telemetry_latency_storage_is_bounded() {
        let t = Telemetry::new();
        for i in 0..(RESERVOIR_CAP + 500) {
            t.record_finish(1.0 + (i % 10) as f64, 0.001);
        }
        assert_eq!(t.latency_samples().len(), RESERVOIR_CAP);
        assert_eq!(t.queue_wait_samples().len(), RESERVOIR_CAP);
        assert_eq!(
            t.requests_finished.load(Ordering::Relaxed),
            RESERVOIR_CAP + 500,
            "counters keep exact totals even when samples subsample"
        );
        let p50 = t.latency_percentile(0.5);
        assert!((1.0..=10.0).contains(&p50), "p50 {p50} from retained samples");
    }

    #[test]
    fn stage_hist_buckets_sum_and_quantiles() {
        let h = StageHist::default();
        for _ in 0..99 {
            h.observe_seconds(2e-5); // second bucket (3.2e-5 edge)
        }
        h.observe_seconds(0.5); // 3.2e-1..1.0 bucket
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.buckets[1], 99);
        assert_eq!(s.buckets.iter().sum::<u64>(), 100);
        assert!((s.sum_seconds - (99.0 * 2e-5 + 0.5)).abs() < 1e-6);
        assert!((s.quantile(0.5) - 3.2e-5).abs() < 1e-12);
        assert!((s.quantile(1.0) - 1.0).abs() < 1e-12, "p100 lands in the 1.0-edge bucket");
        // Overflow bucket: beyond the last edge.
        let h2 = StageHist::default();
        h2.observe_seconds(30.0);
        let s2 = h2.snapshot();
        assert_eq!(s2.buckets[STAGE_BUCKETS - 1], 1);
        assert!(
            s2.quantile(0.5).is_infinite(),
            "overflow-bucket quantiles have no finite upper bound"
        );
        // Empty histogram quantiles are zero.
        assert_eq!(StageHist::default().snapshot().quantile(0.99), 0.0);
    }

    #[test]
    fn quantile_overflow_boundary_and_rendering() {
        // At the last finite edge: quantile stays finite and exact.
        let h = StageHist::default();
        h.observe_seconds(1.0);
        let s = h.snapshot();
        assert!((s.quantile(0.5) - 1.0).abs() < 1e-12);
        assert_eq!(fmt_quantile_ms(s.quantile(0.5)), "1000.00");
        // Past it: infinity, rendered as "+Inf" (Prometheus idiom).
        let h2 = StageHist::default();
        h2.observe_seconds(1.0 + 1e-9);
        let s2 = h2.snapshot();
        assert!(s2.quantile(0.5).is_infinite());
        assert_eq!(fmt_quantile_ms(s2.quantile(0.5)), "+Inf");
    }

    #[test]
    fn delivered_nfe_histogram_buckets_and_clamps() {
        let t = Telemetry::default();
        t.observe_delivered_nfe(1); // first bucket (edge 1)
        t.observe_delivered_nfe(2); // edge-2 bucket
        t.observe_delivered_nfe(3); // edge-4 bucket
        t.observe_delivered_nfe(64); // last finite edge
        t.observe_delivered_nfe(65); // overflow
        t.observe_delivered_nfe(10_000); // overflow clamp
        let snap = t.nfe_hist_snapshot();
        assert_eq!(snap[0], 1);
        assert_eq!(snap[1], 1);
        assert_eq!(snap[2], 1);
        assert_eq!(snap[NFE_HIST_BUCKETS - 2], 1);
        assert_eq!(snap[NFE_HIST_BUCKETS - 1], 2);
        assert_eq!(snap.iter().sum::<u64>(), 6);
    }

    #[test]
    fn summary_includes_qos_counters() {
        let t = Telemetry::default();
        t.early_stops.fetch_add(3, Ordering::Relaxed);
        t.degraded_requests.fetch_add(2, Ordering::Relaxed);
        let s = t.summary();
        assert!(s.contains("early_stops=3"), "summary was: {s}");
        assert!(s.contains("degraded=2"), "summary was: {s}");
    }

    #[test]
    fn stage_hist_merge_is_elementwise() {
        let a = StageHist::default();
        a.observe_seconds(1e-4);
        a.observe_seconds(1e-2);
        let b = StageHist::default();
        b.observe_seconds(1e-4);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.buckets[2], 2, "two 1e-4 observations pooled");
        assert!((m.sum_seconds - (2e-4 + 1e-2)).abs() < 1e-9);
    }

    #[test]
    fn summary_carries_per_stage_percentiles() {
        let t = Telemetry::new();
        t.record_finish(0.05, 0.002);
        t.stage_solver.observe_nanos(50_000);
        t.stage_eval.observe_nanos(2_000_000);
        let s = t.summary();
        assert!(s.contains("queue="), "{s}");
        assert!(s.contains("step="), "{s}");
        assert!(s.contains("eval="), "{s}");
    }

    #[test]
    fn depth_histogram_buckets_and_clamps() {
        let t = Telemetry::new();
        t.observe_depth(1);
        t.observe_depth(1);
        t.observe_depth(3);
        t.observe_depth(0); // clamped into the depth-1 bucket
        t.observe_depth(500); // clamped into the last bucket
        let h = t.depth_hist_snapshot();
        assert_eq!(h[0], 3);
        assert_eq!(h[2], 1);
        assert_eq!(h[DEPTH_HIST_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn lane_occupancy_histogram_buckets_and_clamps() {
        let t = Telemetry::new();
        t.observe_lane_occupancy(1);
        t.observe_lane_occupancy(1);
        t.observe_lane_occupancy(4);
        t.observe_lane_occupancy(0); // clamped into the 1-member bucket
        t.observe_lane_occupancy(64); // clamped into the last bucket
        let h = t.lane_occ_snapshot();
        assert_eq!(h[0], 3);
        assert_eq!(h[3], 1);
        assert_eq!(h[LANE_OCC_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<usize>(), 5);
        t.lanes.store(7, Ordering::Relaxed);
        assert!(t.summary().contains("lanes=7"));
    }

    #[test]
    fn delta_eps_aggregation_means_over_count() {
        let t = Telemetry::new();
        assert_eq!(t.mean_delta_eps(), 0.0);
        t.record_delta_eps(0.2);
        t.record_delta_eps(0.4);
        let (sum, count) = t.delta_eps_agg();
        assert!((sum - 0.6).abs() < 1e-12);
        assert_eq!(count, 2);
        assert!((t.mean_delta_eps() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn executor_busy_fraction_from_clocks() {
        let t = Telemetry::new();
        assert_eq!(t.executor_busy_fraction(), 0.0);
        t.executor_busy_nanos.fetch_add(300, Ordering::Relaxed);
        t.executor_idle_nanos.fetch_add(100, Ordering::Relaxed);
        assert!((t.executor_busy_fraction() - 0.75).abs() < 1e-12);
        assert!(t.summary().contains("exec_busy=75%"));
    }

    #[test]
    fn empty_telemetry_is_zero() {
        let t = Telemetry::new();
        assert_eq!(t.latency_percentile(0.5), 0.0);
        assert_eq!(t.mean_batch_occupancy(), 0.0);
        assert_eq!(t.padding_fraction(), 0.0);
        assert!(t.summary().contains("finished=0"));
    }

    #[test]
    fn conn_snapshots_merge_by_summing_every_field() {
        let a = ConnCounters::new();
        a.open_connections.store(3, Ordering::Relaxed);
        a.accepted_total.store(10, Ordering::Relaxed);
        a.rejected_total.store(1, Ordering::Relaxed);
        a.backpressure_stalls.store(2, Ordering::Relaxed);
        a.bytes_in.store(100, Ordering::Relaxed);
        a.bytes_out.store(1000, Ordering::Relaxed);
        let b = ConnCounters::new();
        b.open_connections.store(5, Ordering::Relaxed);
        b.accepted_total.store(7, Ordering::Relaxed);
        b.backpressure_stalls.store(4, Ordering::Relaxed);
        b.bytes_in.store(11, Ordering::Relaxed);
        b.bytes_out.store(22, Ordering::Relaxed);
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(
            merged,
            ConnSnapshot {
                open_connections: 8,
                accepted_total: 17,
                rejected_total: 1,
                backpressure_stalls: 6,
                bytes_in: 111,
                bytes_out: 1022,
            }
        );
        let j = merged.to_json();
        assert_eq!(j.get("open").as_usize(), Some(8));
        assert_eq!(j.get("backpressure_stalls").as_usize(), Some(6));
        assert_eq!(j.get("bytes_in").as_usize(), Some(111));
        assert_eq!(j.get("bytes_out").as_usize(), Some(1022));
    }
}
