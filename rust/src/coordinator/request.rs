//! Request/response types and the per-request solver state machine.

use std::sync::Arc;
use std::time::Instant;

use crate::kernels::PlanCache;
use crate::rng::Rng;
use crate::solvers::lanes::LaneAdmission;
use crate::solvers::schedule::{make_grid, GridKind, VpSchedule};
use crate::solvers::{EvalRequest, Solver, SolverKind, TaskSpec};
use crate::tensor::Tensor;

/// Service tier of one request: how much the serving stack may trade
/// the request's NFE budget against load and deadlines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QosClass {
    /// Full fixed-NFE budget, bitwise-reproducible; rejected outright
    /// at the admission cap. The convergence controller never runs.
    #[default]
    Strict,
    /// Opted into early stop via `conv_threshold`, charged predicted
    /// rows at admission, but never degraded below its own settings.
    Balanced,
    /// Like balanced, and additionally degradable: under deadline
    /// pressure or at the admission cap the scheduler latches the
    /// request to finish at its NFE floor instead of rejecting it.
    BestEffort,
}

impl QosClass {
    pub fn parse(s: &str) -> Option<QosClass> {
        match s {
            "strict" => Some(QosClass::Strict),
            "balanced" => Some(QosClass::Balanced),
            "besteffort" => Some(QosClass::BestEffort),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            QosClass::Strict => "strict",
            QosClass::Balanced => "balanced",
            QosClass::BestEffort => "besteffort",
        }
    }
}

/// What a client asks for: a batch of samples from one dataset's
/// denoiser under a chosen solver at a chosen NFE budget.
#[derive(Clone, Debug)]
pub struct RequestSpec {
    /// Dataset / model name ("gmm8", "checkerboard", ...).
    pub dataset: String,
    /// Solver name, parsed by [`SolverKind::parse`] ("era", "ddim",
    /// "dpm-fast", "era-fixed-5", ...).
    pub solver: String,
    /// Network-evaluation budget.
    pub nfe: usize,
    /// Samples requested.
    pub n_samples: usize,
    /// Timestep grid flavour ("uniform" | "quadratic" | "logsnr").
    pub grid: String,
    /// Final time t_N (the paper's 1e-3 / 1e-4 settings).
    pub t_end: f64,
    /// Seed for the prior noise (and ancestral noise for DDPM).
    pub seed: u64,
    /// Per-request deadline, milliseconds from submit. When it expires
    /// the shard loop retires the solver mid-trajectory and replies with
    /// a partial, `cancelled` result. `None` falls back to the
    /// coordinator's `default_deadline` (which may also be none).
    pub deadline_ms: Option<u64>,
    /// Workload description: classifier-free guidance, img2img partial
    /// trajectory, stochastic churn. Defaults to the plain unconditional
    /// full trajectory.
    pub task: TaskSpec,
    /// Service tier (see [`QosClass`]). `nfe` is the budget ceiling;
    /// `min_nfe` the floor early stop / degradation may reach.
    pub qos: QosClass,
    /// Early-stop NFE floor (0 = the solver's structural minimum).
    pub min_nfe: usize,
    /// Convergence-controller threshold on the relative `delta_eps`
    /// change per scored step (0 = fixed NFE). Ignored for `strict`.
    pub conv_threshold: f64,
    /// Set by pool admission when an over-cap `besteffort` request was
    /// accepted in degraded form instead of rejected. Not a wire field.
    pub degraded: bool,
}

impl Default for RequestSpec {
    fn default() -> Self {
        RequestSpec {
            dataset: "gmm8".into(),
            solver: "era".into(),
            nfe: 10,
            n_samples: 16,
            grid: "uniform".into(),
            t_end: 1e-3,
            seed: 0,
            deadline_ms: None,
            task: TaskSpec::default(),
            qos: QosClass::Strict,
            min_nfe: 0,
            conv_threshold: 0.0,
            degraded: false,
        }
    }
}

impl RequestSpec {
    /// Model-eval rows this request pins in the admission gauges: a
    /// guided request evaluates paired cond/uncond rows, so it counts
    /// (and is admission-controlled as) twice its `n_samples`.
    pub fn admission_rows(&self) -> usize {
        self.n_samples * self.task.rows_per_sample()
    }

    /// Effective convergence threshold: `strict` requests are
    /// guaranteed fixed-NFE, so the controller is forced off for them.
    pub fn effective_conv_threshold(&self) -> f64 {
        if self.qos == QosClass::Strict {
            0.0
        } else {
            self.conv_threshold
        }
    }

    /// Whether admission may accept this request in degraded form
    /// (finish at the NFE floor) instead of rejecting it at the cap:
    /// `besteffort` ERA requests with room between floor and budget.
    pub fn degradable(&self) -> bool {
        if self.qos != QosClass::BestEffort || self.degraded {
            return false;
        }
        match SolverKind::parse(&self.solver) {
            Some(kind @ SolverKind::Era { .. }) => kind.nfe_floor(self.min_nfe, self.nfe) < self.nfe,
            _ => false,
        }
    }

    /// Rows the admission cap charges this request. `strict` requests
    /// pay worst case; adaptive tiers pay rows scaled by their
    /// *predicted* NFE — floor for `besteffort` (degradable on
    /// demand), the floor/budget midpoint for `balanced` with the
    /// controller on — converting the fixed row budget into a
    /// load-responsive one.
    pub fn charged_rows(&self) -> usize {
        let worst = self.admission_rows();
        if self.qos == QosClass::Strict {
            return worst;
        }
        let Some(kind @ SolverKind::Era { .. }) = SolverKind::parse(&self.solver) else {
            return worst;
        };
        if self.qos == QosClass::Balanced && self.effective_conv_threshold() <= 0.0 {
            return worst;
        }
        let floor = kind.nfe_floor(self.min_nfe, self.nfe);
        let predicted = match self.qos {
            QosClass::Strict => self.nfe,
            QosClass::Balanced => (floor + self.nfe).div_ceil(2),
            QosClass::BestEffort => floor,
        };
        (worst * predicted).div_ceil(self.nfe).max(1)
    }

    /// Validate and instantiate the solver state for this request with
    /// a private trajectory plan (tests / one-off drivers).
    pub fn build_solver(
        &self,
        sched: VpSchedule,
        dim: usize,
    ) -> Result<Box<dyn Solver>, String> {
        self.build_solver_impl(sched, dim, None)
    }

    /// Like [`RequestSpec::build_solver`] but sharing the precomputed
    /// [`crate::kernels::TrajectoryPlan`] through `plans` — the serving
    /// path: every request with the same `(solver, nfe, grid, t_end)`
    /// on one schedule reuses one plan across the shard (and, with the
    /// pool's shared cache, across shards).
    pub fn build_solver_with_plans(
        &self,
        sched: VpSchedule,
        dim: usize,
        plans: &PlanCache,
    ) -> Result<Box<dyn Solver>, String> {
        self.build_solver_impl(sched, dim, Some(plans))
    }

    fn build_solver_impl(
        &self,
        sched: VpSchedule,
        dim: usize,
        plans: Option<&PlanCache>,
    ) -> Result<Box<dyn Solver>, String> {
        let (kind, plan, x0) = self.resolve_parts(sched, dim, plans)?;
        kind.build_task(plan, x0, self.seed, &self.task)
    }

    /// Shared validation + plan + prior-noise resolution behind both
    /// the boxed-solver path and the lane path.
    fn resolve_parts(
        &self,
        sched: VpSchedule,
        dim: usize,
        plans: Option<&PlanCache>,
    ) -> Result<(SolverKind, Arc<crate::kernels::TrajectoryPlan>, Tensor), String> {
        let kind = SolverKind::parse(&self.solver)
            .ok_or_else(|| format!("unknown solver '{}'", self.solver))?;
        let grid_kind = GridKind::parse(&self.grid)
            .ok_or_else(|| format!("unknown grid '{}'", self.grid))?;
        if self.n_samples == 0 {
            return Err("n_samples must be positive".into());
        }
        if !(self.t_end > 0.0 && self.t_end < 1.0) {
            return Err(format!("t_end {} out of (0, 1)", self.t_end));
        }
        kind.validate_nfe(self.nfe)?;
        if self.min_nfe > self.nfe {
            return Err(format!("min_nfe {} above nfe budget {}", self.min_nfe, self.nfe));
        }
        if !(self.conv_threshold >= 0.0 && self.conv_threshold.is_finite()) {
            return Err(format!("conv_threshold {} out of range", self.conv_threshold));
        }
        let plan = match plans {
            Some(cache) => {
                kind.plan_from_cache(cache, sched, grid_kind, self.nfe, 1.0, self.t_end)
            }
            None => {
                let steps = kind.steps_for_nfe(self.nfe);
                let grid = make_grid(&sched, grid_kind, steps, 1.0, self.t_end);
                Arc::new(kind.make_plan(sched, grid, self.nfe))
            }
        };
        let mut rng = Rng::for_stream(self.seed, 0x5eed);
        let x0 = rng.normal_tensor(self.n_samples, dim);
        Ok((kind, plan, x0))
    }

    /// Resolve this request for lane admission (the serving path):
    /// validation, shared plan, prior noise and task resolution are
    /// identical to [`RequestSpec::build_solver_with_plans`], but no
    /// boxed solver is built — the scheduler inserts the resolution
    /// into the shard's [`crate::solvers::lanes::LaneEngine`].
    pub fn resolve_lane(
        &self,
        sched: VpSchedule,
        dim: usize,
        plans: &PlanCache,
    ) -> Result<LaneAdmission, String> {
        let (kind, plan, x0) = self.resolve_parts(sched, dim, Some(plans))?;
        let res = kind.resolve_task(plan, x0, &self.task)?;
        let conv_threshold = self.effective_conv_threshold();
        let min_nfe = kind.nfe_floor(self.min_nfe, self.nfe);
        Ok(LaneAdmission {
            kind,
            view: res.view,
            x: res.x,
            churn: res.churn,
            guided: res.guided,
            seed: self.seed,
            conv_threshold,
            min_nfe,
        })
    }
}

/// Terminal outcome of one request.
#[derive(Debug)]
pub struct SamplingResult {
    pub id: u64,
    pub samples: Tensor,
    pub nfe: usize,
    /// Time spent queued before the first solver step.
    pub queue_seconds: f64,
    /// Submit-to-finish wall time.
    pub total_seconds: f64,
    /// True when the request was retired early (client cancellation or
    /// deadline expiry); `samples` then holds the partial iterate and
    /// `nfe` the evaluations actually consumed.
    pub cancelled: bool,
    /// Last error-robust error measure (Eq. 15) — ERA solvers only.
    /// Surfaced on the wire so clients can observe the error-robust
    /// selection working.
    pub delta_eps: Option<f64>,
    /// True when the convergence controller (or QoS degradation)
    /// retired the request before its full NFE budget; `nfe` then
    /// holds the evaluations actually delivered.
    pub early_stop: bool,
}

/// Lifecycle of an admitted request inside the engine loop.
pub struct RequestState {
    pub id: u64,
    pub dataset: String,
    pub solver: Box<dyn Solver>,
    /// Evaluation handed out in the current round, if any.
    pub pending: Option<EvalRequest>,
    pub submitted_at: Instant,
    pub started_at: Option<Instant>,
}

impl RequestState {
    pub fn new(id: u64, dataset: String, solver: Box<dyn Solver>) -> Self {
        RequestState {
            id,
            dataset,
            solver,
            pending: None,
            submitted_at: Instant::now(),
            started_at: None,
        }
    }

    /// Pull the next evaluation from the solver into `pending`.
    /// Returns false when the solver has finished.
    pub fn pull(&mut self) -> bool {
        if self.started_at.is_none() {
            self.started_at = Some(Instant::now());
        }
        debug_assert!(self.pending.is_none(), "pull with an eval outstanding");
        match self.solver.next_eval() {
            Some(req) => {
                self.pending = Some(req);
                true
            }
            None => false,
        }
    }

    /// Rows this request contributes to the current round.
    pub fn pending_rows(&self) -> usize {
        self.pending.as_ref().map_or(0, |p| p.x.rows())
    }

    /// Consume the model output for the pending evaluation.
    pub fn deliver(&mut self, eps: Tensor) {
        debug_assert!(self.pending.is_some(), "deliver without pending eval");
        self.pending = None;
        self.solver.on_eval(eps);
    }

    pub fn finish(self) -> SamplingResult {
        let now = Instant::now();
        let started = self.started_at.unwrap_or(now);
        SamplingResult {
            id: self.id,
            nfe: self.solver.nfe(),
            samples: self.solver.current().clone(),
            queue_seconds: (started - self.submitted_at).as_secs_f64(),
            total_seconds: (now - self.submitted_at).as_secs_f64(),
            cancelled: false,
            delta_eps: self.solver.delta_eps(),
            early_stop: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::eps_model::{AnalyticGmm, EpsModel};

    fn sched() -> VpSchedule {
        VpSchedule::default()
    }

    #[test]
    fn spec_builds_every_known_solver() {
        for s in ["ddim", "ddpm", "iadams", "dpm-2", "dpm-fast", "era", "era-fixed-4"] {
            let spec = RequestSpec { solver: s.into(), nfe: 15, ..Default::default() };
            let solver = spec.build_solver(sched(), 2);
            assert!(solver.is_ok(), "{s}: {:?}", solver.err());
        }
        // PNDM needs its RK warmup budget.
        let spec = RequestSpec { solver: "pndm".into(), nfe: 15, ..Default::default() };
        assert!(spec.build_solver(sched(), 2).is_ok());
    }

    #[test]
    fn spec_rejects_bad_inputs() {
        let bad_solver = RequestSpec { solver: "wat".into(), ..Default::default() };
        assert!(bad_solver.build_solver(sched(), 2).is_err());
        let bad_grid = RequestSpec { grid: "banana".into(), ..Default::default() };
        assert!(bad_grid.build_solver(sched(), 2).is_err());
        let bad_n = RequestSpec { n_samples: 0, ..Default::default() };
        assert!(bad_n.build_solver(sched(), 2).is_err());
        let bad_t = RequestSpec { t_end: 0.0, ..Default::default() };
        assert!(bad_t.build_solver(sched(), 2).is_err());
        let low_nfe = RequestSpec { solver: "pndm".into(), nfe: 5, ..Default::default() };
        assert!(low_nfe.build_solver(sched(), 2).is_err());
    }

    #[test]
    fn guided_spec_counts_double_rows_and_builds() {
        let spec = RequestSpec {
            task: TaskSpec { guidance_scale: 2.0, guide_class: 1, ..Default::default() },
            ..Default::default()
        };
        assert_eq!(spec.admission_rows(), 32, "16 samples x 2 paired rows");
        let solver = spec.build_solver(sched(), 2).unwrap();
        assert_eq!(solver.current().rows(), 16, "iterate keeps requested rows");
        assert_eq!(RequestSpec::default().admission_rows(), 16);
    }

    #[test]
    fn task_spec_rejections_surface_as_errors() {
        // Interior strength without an init.
        let s = RequestSpec {
            task: TaskSpec { strength: 0.5, ..Default::default() },
            ..Default::default()
        };
        assert!(s.build_solver(sched(), 2).is_err());
        // Churn on a non-ERA solver.
        let s = RequestSpec {
            solver: "ddim".into(),
            task: TaskSpec { churn: 0.5, ..Default::default() },
            ..Default::default()
        };
        assert!(s.build_solver(sched(), 2).is_err());
        // Out-of-range guidance.
        let s = RequestSpec {
            task: TaskSpec { guidance_scale: -3.0, ..Default::default() },
            ..Default::default()
        };
        assert!(s.build_solver(sched(), 2).is_err());
    }

    #[test]
    fn img2img_spec_builds_suffix_trajectory() {
        let init = Tensor::from_vec(vec![0.5f32; 8], 4, 2);
        let spec = RequestSpec {
            n_samples: 4,
            task: TaskSpec { strength: 0.5, init: Some(init), ..Default::default() },
            ..Default::default()
        };
        let mut st = RequestState::new(1, "gmm8".into(), spec.build_solver(sched(), 2).unwrap());
        let model = AnalyticGmm::gmm8(sched());
        while st.pull() {
            let req = st.pending.as_ref().unwrap();
            let t = vec![req.t as f32; req.x.rows()];
            let eps = model.eval(&req.x, &t);
            st.deliver(eps);
        }
        let res = st.finish();
        // strength 0.5 over a 10-step grid = 5 remaining transitions.
        assert_eq!(res.nfe, 5);
        assert_eq!(res.samples.rows(), 4);
        assert!(res.samples.all_finite());
    }

    #[test]
    fn state_machine_runs_to_completion() {
        let spec = RequestSpec { nfe: 10, n_samples: 4, ..Default::default() };
        let solver = spec.build_solver(sched(), 2).unwrap();
        let mut st = RequestState::new(7, "gmm8".into(), solver);
        let model = AnalyticGmm::gmm8(sched());
        let mut rounds = 0;
        while st.pull() {
            let req = st.pending.as_ref().unwrap();
            let t = vec![req.t as f32; req.x.rows()];
            let eps = model.eval(&req.x, &t);
            st.deliver(eps);
            rounds += 1;
            assert!(rounds < 100, "runaway");
        }
        let res = st.finish();
        assert_eq!(res.id, 7);
        assert_eq!(res.nfe, 10);
        assert_eq!(res.samples.rows(), 4);
        assert!(res.total_seconds >= res.queue_seconds);
    }

    #[test]
    fn qos_charged_rows_scale_with_predicted_nfe() {
        // era default: floor 4, budget 24, 16 samples (worst 16 rows).
        let strict = RequestSpec { nfe: 24, ..Default::default() };
        assert_eq!(strict.charged_rows(), strict.admission_rows());
        let balanced = RequestSpec {
            nfe: 24,
            qos: QosClass::Balanced,
            conv_threshold: 0.2,
            ..Default::default()
        };
        let besteffort =
            RequestSpec { nfe: 24, qos: QosClass::BestEffort, ..Default::default() };
        assert!(balanced.charged_rows() < balanced.admission_rows());
        assert!(besteffort.charged_rows() < balanced.charged_rows(), "floor < midpoint");
        assert!(besteffort.charged_rows() >= 1);
        // Balanced without the controller runs fixed-NFE: worst case.
        let balanced_off =
            RequestSpec { nfe: 24, qos: QosClass::Balanced, ..Default::default() };
        assert_eq!(balanced_off.charged_rows(), balanced_off.admission_rows());
        // Non-ERA solvers cannot stop early: worst case regardless.
        let ddim = RequestSpec {
            solver: "ddim".into(),
            nfe: 24,
            qos: QosClass::BestEffort,
            ..Default::default()
        };
        assert_eq!(ddim.charged_rows(), ddim.admission_rows());
    }

    #[test]
    fn degradable_only_for_besteffort_era_with_headroom() {
        let be = RequestSpec { nfe: 24, qos: QosClass::BestEffort, ..Default::default() };
        assert!(be.degradable());
        assert!(!RequestSpec { nfe: 24, ..Default::default() }.degradable(), "strict");
        let non_era = RequestSpec {
            solver: "ddim".into(),
            nfe: 24,
            qos: QosClass::BestEffort,
            ..Default::default()
        };
        assert!(!non_era.degradable(), "no eps history to jump from");
        let tight = RequestSpec {
            nfe: 24,
            min_nfe: 24,
            qos: QosClass::BestEffort,
            ..Default::default()
        };
        assert!(!tight.degradable(), "floor == budget leaves nothing to degrade");
        let already = RequestSpec {
            nfe: 24,
            qos: QosClass::BestEffort,
            degraded: true,
            ..Default::default()
        };
        assert!(!already.degradable(), "degradation latches once");
    }

    #[test]
    fn qos_validation_and_strict_override() {
        let bad_floor = RequestSpec { nfe: 10, min_nfe: 11, ..Default::default() };
        assert!(bad_floor.build_solver(sched(), 2).is_err());
        let bad_thresh = RequestSpec { conv_threshold: f64::NAN, ..Default::default() };
        assert!(bad_thresh.build_solver(sched(), 2).is_err());
        let neg_thresh = RequestSpec { conv_threshold: -0.1, ..Default::default() };
        assert!(neg_thresh.build_solver(sched(), 2).is_err());
        // Strict forces the controller off however the threshold is set.
        let strict = RequestSpec { conv_threshold: 0.5, ..Default::default() };
        assert_eq!(strict.effective_conv_threshold(), 0.0);
        let balanced = RequestSpec {
            conv_threshold: 0.5,
            qos: QosClass::Balanced,
            ..Default::default()
        };
        assert_eq!(balanced.effective_conv_threshold(), 0.5);
        assert_eq!(QosClass::parse("besteffort"), Some(QosClass::BestEffort));
        assert_eq!(QosClass::parse("gold-plated"), None);
        assert_eq!(QosClass::BestEffort.label(), "besteffort");
    }

    #[test]
    fn deterministic_prior_per_seed() {
        let spec = RequestSpec { seed: 42, ..Default::default() };
        let a = spec.build_solver(sched(), 2).unwrap().current().clone();
        let b = spec.build_solver(sched(), 2).unwrap().current().clone();
        assert_eq!(a.as_slice(), b.as_slice());
        let spec2 = RequestSpec { seed: 43, ..Default::default() };
        let c = spec2.build_solver(sched(), 2).unwrap().current().clone();
        assert_ne!(a.as_slice(), c.as_slice());
    }
}
