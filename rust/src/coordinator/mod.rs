//! The serving coordinator: continuous dynamic batching of concurrent
//! sampling requests over one denoiser artifact.
//!
//! Fast diffusion sampling is a serving problem (the paper's Tab. 7
//! benchmarks solvers inside a sampler service): many clients ask for
//! batches of samples with per-request solver/NFE settings, and the
//! dominant cost is network evaluation. Because the denoiser takes the
//! diffusion time as a *per-row* input, evaluations from requests sitting
//! at **different timesteps** can be fused into one PJRT call — the
//! diffusion analogue of vLLM-style continuous batching, where requests
//! join and leave the running batch at step granularity.
//!
//! Module map:
//! * [`request`] — the request/response types and per-request state
//!   machine wrapper around a [`crate::solvers::Solver`].
//! * [`batcher`]  — pure batch assembly: pack pending per-request
//!   evaluations into bucket-sized slabs (with per-row times and
//!   absolute `src_start` reassembly offsets), unpack model output back
//!   to requests, recycle slab buffers. Unit-testable without PJRT.
//! * [`telemetry`] — counters, per-stage latency histograms, a bounded
//!   latency reservoir, and occupancy/executor-utilisation recorders
//!   feeding the serving benches (Tab. 7) and the Prometheus
//!   exposition (DESIGN.md §11). The scheduler also records every
//!   request's lifecycle into its shard's
//!   [`crate::obs::FlightRecorder`].
//! * [`executor`] — the per-shard engine-executor pool: `E` threads,
//!   each owning a [`executor::BankSet`] replica handle, evaluating
//!   sequence-numbered slabs off a bounded queue.
//! * [`service`] — the event-driven scheduler: admission queue with
//!   backpressure, cancellation sweeps, dispatch policy (max-rows /
//!   max-wait / pipeline depth), slab dispatch + out-of-order
//!   completion routing, and the public [`service::Coordinator`]
//!   handle. Up to `pipeline_depth` dispatch rounds stay in flight, so
//!   host-side scheduling overlaps engine execution.

pub mod batcher;
pub mod executor;
pub mod request;
pub mod service;
pub mod telemetry;

pub use batcher::{BatchPlan, Batcher, BatchPolicy};
pub use executor::BankSet;
pub use request::{QosClass, RequestSpec, RequestState, SamplingResult};
pub use service::{
    CancelHandle, CompletionNotify, Coordinator, CoordinatorConfig, MockBank, ModelBank,
    SubmitError, Ticket,
};
pub use telemetry::{ConnCounters, ConnSnapshot, Telemetry};
