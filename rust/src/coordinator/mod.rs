//! The serving coordinator: continuous dynamic batching of concurrent
//! sampling requests over one denoiser artifact.
//!
//! Fast diffusion sampling is a serving problem (the paper's Tab. 7
//! benchmarks solvers inside a sampler service): many clients ask for
//! batches of samples with per-request solver/NFE settings, and the
//! dominant cost is network evaluation. Because the denoiser takes the
//! diffusion time as a *per-row* input, evaluations from requests sitting
//! at **different timesteps** can be fused into one PJRT call — the
//! diffusion analogue of vLLM-style continuous batching, where requests
//! join and leave the running batch at step granularity.
//!
//! Module map:
//! * [`request`] — the request/response types and per-request state
//!   machine wrapper around a [`crate::solvers::Solver`].
//! * [`batcher`]  — pure batch assembly: pack pending per-request
//!   evaluations into bucket-sized slabs (with per-row times), unpack
//!   model output back to requests. Unit-testable without PJRT.
//! * [`telemetry`] — counters + latency/occupancy recorders feeding the
//!   serving benches (Tab. 7).
//! * [`service`] — the engine loop: admission queue with backpressure,
//!   round-based stepping, dispatch policy (max-rows / max-wait), and
//!   the public [`service::Coordinator`] handle.

pub mod batcher;
pub mod request;
pub mod service;
pub mod telemetry;

pub use batcher::{BatchPlan, Batcher, BatchPolicy};
pub use request::{RequestSpec, RequestState, SamplingResult};
pub use service::{
    CancelHandle, Coordinator, CoordinatorConfig, MockBank, ModelBank, SubmitError, Ticket,
};
pub use telemetry::Telemetry;
