//! Small dense linear-algebra substrate (f64), built for the Fréchet
//! distance: symmetric eigendecomposition (cyclic Jacobi), PSD matrix
//! square root, and plain matmul. Matrices are row-major `Vec<f64>`; the
//! dimensions here are tiny (2 for the planar datasets, 64 for patches64),
//! so O(n^3) Jacobi with guaranteed accuracy beats anything fancier.

/// Row-major n x n matmul: `a @ b`.
pub fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * n);
    let mut c = vec![0.0; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * n..(k + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Matrix transpose.
pub fn transpose(a: &[f64], n: usize) -> Vec<f64> {
    let mut t = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            t[j * n + i] = a[i * n + j];
        }
    }
    t
}

pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

/// Symmetric eigendecomposition via cyclic Jacobi rotations.
///
/// Returns `(eigvals, eigvecs)` with `a = V diag(w) V^T`, eigenvectors in
/// the *columns* of `V` (row-major). Input must be symmetric; asymmetry
/// above 1e-8 panics in debug to catch misuse.
pub fn eigh(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    debug_assert!(
        (0..n).all(|i| (0..n).all(|j| (m[i * n + j] - m[j * n + i]).abs() < 1e-8)),
        "eigh requires a symmetric matrix"
    );
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    if n == 1 {
        return (vec![m[0]], v);
    }

    // Cyclic sweeps until the off-diagonal Frobenius mass is negligible.
    // Threshold strategy after Numerical Recipes §11.1: early sweeps skip
    // rotations below a coarse threshold (they would be redone anyway),
    // late sweeps zero out elements that are negligible relative to
    // their diagonals instead of rotating — measured 2-3x on the 64-dim
    // FID path (§Perf).
    for sweep in 0..100 {
        let mut off = 0.0;
        let mut sm = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
                sm += m[i * n + j].abs();
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + trace(&m, n).abs()) {
            break;
        }
        let tresh = if sweep < 3 { 0.2 * sm / (n * n) as f64 } else { 0.0 };
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                let g = 100.0 * apq.abs();
                if sweep > 3
                    && m[p * n + p].abs() + g == m[p * n + p].abs()
                    && m[q * n + q].abs() + g == m[q * n + q].abs()
                {
                    m[p * n + q] = 0.0;
                    continue;
                }
                if apq.abs() <= tresh {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                // Stable tan of the rotation angle.
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                let tau = s / (1.0 + c);

                // A <- J^T A J, upper triangle only (NR §11.1 `rotate`):
                // the symmetric counterpart entries are never read again
                // within the sweep, saving half the element updates.
                let rot = |x: &mut f64, y: &mut f64| {
                    let (g, h) = (*x, *y);
                    *x = g - s * (h + g * tau);
                    *y = h + s * (g - h * tau);
                };
                m[p * n + p] = app - t * apq;
                m[q * n + q] = aqq + t * apq;
                m[p * n + q] = 0.0;
                for k in 0..p {
                    let (i1, i2) = (k * n + p, k * n + q);
                    let (mut x, mut y) = (m[i1], m[i2]);
                    rot(&mut x, &mut y);
                    m[i1] = x;
                    m[i2] = y;
                }
                for k in p + 1..q {
                    let (i1, i2) = (p * n + k, k * n + q);
                    let (mut x, mut y) = (m[i1], m[i2]);
                    rot(&mut x, &mut y);
                    m[i1] = x;
                    m[i2] = y;
                }
                for k in q + 1..n {
                    let (i1, i2) = (p * n + k, q * n + k);
                    let (mut x, mut y) = (m[i1], m[i2]);
                    rot(&mut x, &mut y);
                    m[i1] = x;
                    m[i2] = y;
                }
                // Accumulate the eigenvector rotation.
                for k in 0..n {
                    let (mut x, mut y) = (v[k * n + p], v[k * n + q]);
                    rot(&mut x, &mut y);
                    v[k * n + p] = x;
                    v[k * n + q] = y;
                }
            }
        }
    }
    let w: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    (w, v)
}

/// PSD matrix square root: `sqrtm(a) = V diag(sqrt(max(w,0))) V^T`.
///
/// Slightly negative eigenvalues (sampling noise in covariance estimates)
/// are clamped to zero, matching the standard FID implementations.
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let (w, v) = eigh(a, n);
    let mut out = vec![0.0; n * n];
    for k in 0..n {
        let s = w[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = v[i * n + k] * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += vik * v[j * n + k];
            }
        }
    }
    out
}

/// Symmetrise `(a + a^T) / 2` — used before sqrtm on products that are
/// mathematically symmetric but numerically slightly off.
pub fn symmetrize(a: &[f64], n: usize) -> Vec<f64> {
    let mut s = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            s[i * n + j] = 0.5 * (a[i * n + j] + a[j * n + i]);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &i, 2), a);
        assert_eq!(matmul(&i, &a, 2), a);
    }

    #[test]
    fn matmul_known() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn eigh_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 7.0];
        let (mut w, _) = eigh(&a, 2);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_close(&w, &[3.0, 7.0], 1e-12);
    }

    #[test]
    fn eigh_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let (mut w, _) = eigh(&a, 2);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_close(&w, &[1.0, 3.0], 1e-10);
    }

    #[test]
    fn eigh_reconstructs() {
        // Random-ish symmetric 5x5; check V diag(w) V^T == A.
        let n = 5;
        let mut a = vec![0.0; n * n];
        let mut s = 1u64;
        for i in 0..n {
            for j in i..n {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                let v = ((s >> 33) as f64 / (1u64 << 31) as f64) - 1.0;
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        let (w, v) = eigh(&a, n);
        // rebuild
        let mut rec = vec![0.0; n * n];
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    rec[i * n + j] += v[i * n + k] * w[k] * v[j * n + k];
                }
            }
        }
        assert_close(&rec, &a, 1e-9);
        // orthonormal columns
        let vt_v = matmul(&transpose(&v, n), &v, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vt_v[i * n + j] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        // SPD matrix: A = B B^T + I.
        let n = 4;
        let mut b = vec![0.0; n * n];
        for (i, x) in b.iter_mut().enumerate() {
            *x = ((i * 7 + 3) % 11) as f64 / 11.0;
        }
        let mut a = matmul(&b, &transpose(&b, n), n);
        for i in 0..n {
            a[i * n + i] += 1.0;
        }
        let r = sqrtm_psd(&a, n);
        let rr = matmul(&r, &r, n);
        assert_close(&rr, &a, 1e-9);
    }

    #[test]
    fn sqrtm_clamps_negative_eigs() {
        // Nearly-PSD with a tiny negative eigenvalue must not produce NaN.
        let a = vec![1.0, 0.0, 0.0, -1e-14];
        let r = sqrtm_psd(&a, 2);
        assert!(r.iter().all(|v| v.is_finite()));
        assert!((r[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eigh_1x1() {
        let (w, v) = eigh(&[4.0], 1);
        assert_eq!(w, vec![4.0]);
        assert_eq!(v, vec![1.0]);
    }

    #[test]
    fn trace_and_symmetrize() {
        let a = vec![1.0, 2.0, 4.0, 3.0];
        assert_eq!(trace(&a, 2), 4.0);
        assert_eq!(symmetrize(&a, 2), vec![1.0, 3.0, 3.0, 3.0]);
    }
}
