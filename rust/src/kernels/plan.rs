//! Per-trajectory precomputation and the shared plan cache.
//!
//! Everything a solver derives from `(solver kind, grid, schedule)` —
//! and nothing that depends on the iterate — is computed once into a
//! [`TrajectoryPlan`] and shared: the timestep grid, VP-schedule samples
//! at every grid point, per-transition DDIM transfer coefficients,
//! Adams–Moulton corrector weights, per-step DPM exponential-integrator
//! coefficients, and a concurrent memo of Lagrange basis weights keyed
//! by `(target step, selected buffer indices)` — the ERA predictor's
//! weights repeat across requests whenever the error-robust selection
//! lands on the same index set, which is the common case for similar
//! error levels.
//!
//! [`PlanCache`] keys plans by `(solver label, NFE, grid kind, schedule,
//! t-range)` and is shared by every request of a coordinator shard and —
//! through the pool — across shards: DPM-Solver and SA-Solver both
//! precompute their coefficient schedules once per trajectory; this
//! moves that to once per *configuration*.
//!
//! Every value is computed with the exact f64 expressions the solvers
//! used inline pre-refactor, so plan-backed stepping is bit-identical
//! (pinned by `tests/golden_trajectories.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use crate::solvers::lagrange;
use crate::solvers::schedule::{GridKind, VpSchedule};

/// Largest interpolation order memoised per-(step, indices); higher
/// orders fall back to direct computation (no fixed-size key fits).
pub const MAX_MEMO_K: usize = 8;

#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct LagKey {
    target: u32,
    k: u32,
    idx: [u32; MAX_MEMO_K],
}

/// Precomputed coefficients for one DPM-Solver transition (Lu et al.
/// Algorithms 1/2 with r1 = 1/3, r2 = 2/3). Fields unused at a given
/// order stay zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct DpmStepPlan {
    pub order: usize,
    /// Stage-1 intermediate point and its order-1 transfer (order >= 2).
    pub t_s1: f64,
    pub a_s1: f64,
    pub b_s1: f64,
    /// Stage-2 intermediate point and coefficients (order 3):
    /// `u2 = a_s2 x + b_s2 e0 + c_s2 (e1 - e0)`.
    pub t_s2: f64,
    pub a_s2: f64,
    pub b_s2: f64,
    pub c_s2: f64,
    /// Final combination: order 1/2 use `x' = a_f x + b_f e_last`;
    /// order 3 uses `x' = a_f x + b_f e0 + c_f (e_last - e0)`.
    pub a_f: f64,
    pub b_f: f64,
    pub c_f: f64,
}

/// All per-trajectory constants for one `(solver kind, grid, schedule)`.
pub struct TrajectoryPlan {
    sched: VpSchedule,
    grid: Vec<f64>,
    /// `alpha_bar` sampled at every grid point — the one raw VP sample
    /// a solver consumes directly (DDPM's posterior); everything else
    /// the schedule would provide is already folded into the DDIM / AM
    /// / DPM coefficient tables below.
    alpha_bar: Vec<f64>,
    /// DDIM transfer `(a, b)` per transition (`grid.len() - 1` entries).
    ddim: Vec<(f64, f64)>,
    /// Adams–Moulton corrector weights, orders 2..=4 (index `order - 2`).
    am: [Vec<f64>; 3],
    am_builds: AtomicUsize,
    /// DPM per-step coefficients (only for DPM solver kinds).
    dpm: Option<Vec<DpmStepPlan>>,
    /// Lagrange basis-weight memo: `(target grid index, buffer indices)`
    /// -> weights. Concurrent reads; deterministic values.
    lagrange: RwLock<HashMap<LagKey, Arc<Vec<f64>>>>,
    lagrange_builds: AtomicUsize,
    lagrange_hits: AtomicUsize,
}

impl TrajectoryPlan {
    /// Precompute schedule samples and transition coefficients for a
    /// decreasing timestep grid.
    pub fn new(sched: VpSchedule, grid: Vec<f64>) -> TrajectoryPlan {
        assert!(grid.len() >= 2, "plan needs at least one transition");
        debug_assert!(grid.windows(2).all(|w| w[1] < w[0]), "grid must decrease");
        let alpha_bar: Vec<f64> = grid.iter().map(|&t| sched.alpha_bar(t)).collect();
        let ddim: Vec<(f64, f64)> =
            grid.windows(2).map(|w| sched.ddim_coeffs(w[0], w[1])).collect();
        // The single AM-weight computation of this trajectory (the
        // regression test pins builds == 1 however many steps consume
        // these).
        let am = [
            vec![0.5, 0.5],
            vec![5.0 / 12.0, 8.0 / 12.0, -1.0 / 12.0],
            vec![9.0 / 24.0, 19.0 / 24.0, -5.0 / 24.0, 1.0 / 24.0],
        ];
        TrajectoryPlan {
            sched,
            grid,
            alpha_bar,
            ddim,
            am,
            am_builds: AtomicUsize::new(1),
            dpm: None,
            lagrange: RwLock::new(HashMap::new()),
            lagrange_builds: AtomicUsize::new(0),
            lagrange_hits: AtomicUsize::new(0),
        }
    }

    /// Attach the per-step DPM-Solver coefficients for an order
    /// schedule (`orders.len()` must equal the transition count).
    pub fn with_dpm_orders(mut self, orders: &[usize]) -> TrajectoryPlan {
        assert_eq!(orders.len() + 1, self.grid.len(), "orders must match grid transitions");
        assert!(orders.iter().all(|&o| (1..=3).contains(&o)));
        let steps = orders
            .iter()
            .enumerate()
            .map(|(i, &order)| self.dpm_step_plan(i, order))
            .collect();
        self.dpm = Some(steps);
        self
    }

    /// Order-1 transfer coefficients from `t_from` to `t_to` — the exact
    /// expressions of the singlestep DPM update.
    fn dpm_order1(&self, t_from: f64, t_to: f64) -> (f64, f64) {
        let h = self.sched.lambda(t_to) - self.sched.lambda(t_from);
        let a = self.sched.sqrt_alpha_bar(t_to) / self.sched.sqrt_alpha_bar(t_from);
        let b = -self.sched.sigma(t_to) * h.exp_m1();
        (a, b)
    }

    fn dpm_step_plan(&self, i: usize, order: usize) -> DpmStepPlan {
        let (tc, tn) = (self.grid[i], self.grid[i + 1]);
        let (lc, ln) = (self.sched.lambda(tc), self.sched.lambda(tn));
        let h = ln - lc;
        let t_mid = |r: f64| self.sched.t_of_lambda(lc + r * h);
        let mut sp = DpmStepPlan { order, ..Default::default() };
        match order {
            1 => {
                let (a, b) = self.dpm_order1(tc, tn);
                sp.a_f = a;
                sp.b_f = b;
            }
            2 => {
                let s = t_mid(0.5);
                let (a1, b1) = self.dpm_order1(tc, s);
                sp.t_s1 = s;
                sp.a_s1 = a1;
                sp.b_s1 = b1;
                let (a, b) = self.dpm_order1(tc, tn);
                sp.a_f = a;
                sp.b_f = b;
            }
            3 => {
                let (r1, r2) = (1.0 / 3.0, 2.0 / 3.0);
                let s1 = t_mid(r1);
                let (a1, b1) = self.dpm_order1(tc, s1);
                sp.t_s1 = s1;
                sp.a_s1 = a1;
                sp.b_s1 = b1;
                let s2 = t_mid(r2);
                let sig2 = self.sched.sigma(s2);
                let em = (r2 * h).exp_m1();
                sp.t_s2 = s2;
                sp.a_s2 = self.sched.sqrt_alpha_bar(s2) / self.sched.sqrt_alpha_bar(tc);
                sp.b_s2 = -sig2 * em;
                sp.c_s2 = -(sig2 * r2 / r1) * (em / (r2 * h) - 1.0);
                let sig_n = self.sched.sigma(tn);
                let em_h = h.exp_m1();
                sp.a_f = self.sched.sqrt_alpha_bar(tn) / self.sched.sqrt_alpha_bar(tc);
                sp.b_f = -sig_n * em_h;
                sp.c_f = -(sig_n / r2) * (em_h / h - 1.0);
            }
            _ => unreachable!("dpm order out of range"),
        }
        sp
    }

    pub fn sched(&self) -> VpSchedule {
        self.sched
    }

    pub fn grid(&self) -> &[f64] {
        &self.grid
    }

    /// Grid transition count (solver steps).
    pub fn steps(&self) -> usize {
        self.grid.len() - 1
    }

    /// Timestep at grid point `i`.
    #[inline]
    pub fn t(&self, i: usize) -> f64 {
        self.grid[i]
    }

    /// DDIM transfer `(a, b)` for transition `i` (grid[i] -> grid[i+1]).
    #[inline]
    pub fn ddim_coeffs(&self, i: usize) -> (f64, f64) {
        self.ddim[i]
    }

    #[inline]
    pub fn alpha_bar_at(&self, i: usize) -> f64 {
        self.alpha_bar[i]
    }

    /// Adams–Moulton weights by order (2..=4; higher orders clamp to 4,
    /// matching the pre-refactor `am_weights` free function). Index 0
    /// multiplies the implicit (newest) slot.
    #[inline]
    pub fn am_weights(&self, order: usize) -> &[f64] {
        match order {
            2 => &self.am[0],
            3 => &self.am[1],
            _ => &self.am[2],
        }
    }

    /// How many times this plan computed its AM weight tables (always 1;
    /// the regression test pins it).
    pub fn am_builds(&self) -> usize {
        self.am_builds.load(Ordering::Relaxed)
    }

    /// Per-step DPM coefficients; panics when the plan was not built for
    /// a DPM solver kind.
    #[inline]
    pub fn dpm_step(&self, i: usize) -> DpmStepPlan {
        self.dpm.as_ref().expect("plan has no DPM coefficients")[i]
    }

    pub fn has_dpm(&self) -> bool {
        self.dpm.is_some()
    }

    /// Lagrange basis weights for interpolating the buffered estimates
    /// at grid point `target` from buffer entries `indices` (ascending
    /// grid indices). Memoised per plan and therefore shared across
    /// every request using this plan; concurrent lookups return the
    /// same `Arc` deterministically.
    pub fn lagrange_weights(&self, target: usize, indices: &[usize]) -> Arc<Vec<f64>> {
        assert!(!indices.is_empty(), "lagrange over no indices");
        assert!(target < self.grid.len(), "lagrange target off grid");
        let compute = || {
            let nodes: Vec<f64> = indices.iter().map(|&n| self.grid[n]).collect();
            Arc::new(lagrange::weights(&nodes, self.grid[target]))
        };
        if indices.len() > MAX_MEMO_K {
            self.lagrange_builds.fetch_add(1, Ordering::Relaxed);
            return compute();
        }
        let mut idx = [0u32; MAX_MEMO_K];
        for (slot, &n) in idx.iter_mut().zip(indices.iter()) {
            *slot = n as u32;
        }
        let key = LagKey { target: target as u32, k: indices.len() as u32, idx };
        if let Some(w) = self.lagrange.read().unwrap().get(&key) {
            self.lagrange_hits.fetch_add(1, Ordering::Relaxed);
            return w.clone();
        }
        // Compute outside the write lock (deterministic value: a racing
        // builder produces the identical vector; first insert wins).
        let w = compute();
        self.lagrange_builds.fetch_add(1, Ordering::Relaxed);
        self.lagrange.write().unwrap().entry(key).or_insert_with(|| w.clone()).clone()
    }

    pub fn lagrange_builds(&self) -> usize {
        self.lagrange_builds.load(Ordering::Relaxed)
    }

    pub fn lagrange_hits(&self) -> usize {
        self.lagrange_hits.load(Ordering::Relaxed)
    }
}

/// A (possibly suffix) window into a shared [`TrajectoryPlan`].
///
/// The img2img workload starts a trajectory at an *interior* grid index
/// (`strength` quantized to a transition); everything the solver reads —
/// timesteps, DDIM/DPM coefficients, schedule samples — is the full
/// plan's data offset by `base`, so the [`PlanCache`] keeps exactly one
/// plan per configuration no matter how many strengths are in flight.
/// `base = 0` is the full trajectory and adds no indirection cost beyond
/// one `usize` add per accessor.
///
/// Lagrange memo lookups translate relative indices to absolute grid
/// indices, so suffix requests share the same memo (and can never alias
/// a full request's entries: the absolute indices differ).
#[derive(Clone)]
pub struct PlanView {
    plan: Arc<TrajectoryPlan>,
    base: usize,
}

impl PlanView {
    /// The whole trajectory (what every pre-existing path uses).
    pub fn full(plan: Arc<TrajectoryPlan>) -> PlanView {
        PlanView { plan, base: 0 }
    }

    /// Suffix starting at grid index `base` (must leave >= 1 transition).
    pub fn suffix(plan: Arc<TrajectoryPlan>, base: usize) -> PlanView {
        assert!(
            base + 2 <= plan.grid().len(),
            "suffix base {base} leaves no transition (grid has {} points)",
            plan.grid().len()
        );
        PlanView { plan, base }
    }

    /// Grid index this view starts at (0 = full trajectory).
    pub fn base(&self) -> usize {
        self.base
    }

    /// The shared full plan behind this view.
    pub fn plan(&self) -> &Arc<TrajectoryPlan> {
        &self.plan
    }

    pub fn sched(&self) -> VpSchedule {
        self.plan.sched()
    }

    /// The visible (suffix) grid.
    pub fn grid(&self) -> &[f64] {
        &self.plan.grid()[self.base..]
    }

    /// Visible transition count.
    pub fn steps(&self) -> usize {
        self.plan.steps() - self.base
    }

    #[inline]
    pub fn t(&self, i: usize) -> f64 {
        self.plan.t(self.base + i)
    }

    #[inline]
    pub fn ddim_coeffs(&self, i: usize) -> (f64, f64) {
        self.plan.ddim_coeffs(self.base + i)
    }

    #[inline]
    pub fn alpha_bar_at(&self, i: usize) -> f64 {
        self.plan.alpha_bar_at(self.base + i)
    }

    #[inline]
    pub fn am_weights(&self, order: usize) -> &[f64] {
        self.plan.am_weights(order)
    }

    #[inline]
    pub fn dpm_step(&self, i: usize) -> DpmStepPlan {
        self.plan.dpm_step(self.base + i)
    }

    pub fn has_dpm(&self) -> bool {
        self.plan.has_dpm()
    }

    /// Lagrange basis weights with view-relative `target`/`indices`.
    /// `abs` is a caller-owned scratch for the translated indices so the
    /// suffix path stays allocation-free after warmup; the full view
    /// skips the translation entirely.
    pub fn lagrange_weights_into(
        &self,
        target: usize,
        indices: &[usize],
        abs: &mut Vec<usize>,
    ) -> Arc<Vec<f64>> {
        if self.base == 0 {
            return self.plan.lagrange_weights(target, indices);
        }
        abs.clear();
        abs.extend(indices.iter().map(|&n| n + self.base));
        self.plan.lagrange_weights(target + self.base, abs)
    }
}

/// Cache key: everything the plan contents depend on.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Solver label (`SolverKind::label()` — distinct kinds carry
    /// distinct precomputes, e.g. DPM order schedules).
    pub solver: String,
    pub nfe: usize,
    pub grid: GridKind,
    pub t_start_bits: u64,
    pub t_end_bits: u64,
    pub beta_min_bits: u64,
    pub beta_max_bits: u64,
}

impl PlanKey {
    pub fn new(
        solver: String,
        nfe: usize,
        grid: GridKind,
        sched: &VpSchedule,
        t_start: f64,
        t_end: f64,
    ) -> PlanKey {
        PlanKey {
            solver,
            nfe,
            grid,
            t_start_bits: t_start.to_bits(),
            t_end_bits: t_end.to_bits(),
            beta_min_bits: sched.beta_min.to_bits(),
            beta_max_bits: sched.beta_max.to_bits(),
        }
    }
}

/// Concurrent plan cache shared across requests and coordinator shards.
///
/// Bounded: the key embeds client-controlled fields (`nfe`, `t_end`
/// bits), so an unbounded map would let wire traffic with per-request
/// parameter sweeps grow process memory forever. At `max_plans`
/// retained configurations a miss evicts an arbitrary entry before
/// inserting — the cache tracks current traffic instead of fossilising
/// whichever configurations arrived first.
pub struct PlanCache {
    plans: RwLock<HashMap<PlanKey, Arc<TrajectoryPlan>>>,
    max_plans: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Entries evicted to admit newer configurations (cache at cap).
    evicted: AtomicUsize,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::with_capacity(512)
    }
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Cache retaining at most `max_plans` distinct configurations.
    pub fn with_capacity(max_plans: usize) -> PlanCache {
        PlanCache {
            plans: RwLock::new(HashMap::new()),
            max_plans: max_plans.max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evicted: AtomicUsize::new(0),
        }
    }

    /// Look up the plan for `key`, building it with `build` on a miss.
    /// Racing builders are benign: plans for one key are deterministic
    /// and the first insert wins.
    pub fn get_or_build(
        &self,
        key: PlanKey,
        build: impl FnOnce() -> TrajectoryPlan,
    ) -> Arc<TrajectoryPlan> {
        if let Some(p) = self.plans.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return p.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build());
        let mut plans = self.plans.write().unwrap();
        if let Some(p) = plans.get(&key) {
            // Raced with another builder; keep the retained one.
            return p.clone();
        }
        if plans.len() >= self.max_plans {
            // Arbitrary victim; in-flight holders keep their Arc alive.
            if let Some(victim) = plans.keys().next().cloned() {
                plans.remove(&victim);
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
        plans.insert(key, built.clone());
        built
    }

    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// Entries evicted past the retention cap.
    pub fn evicted(&self) -> usize {
        self.evicted.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::schedule::make_grid;

    fn plan(steps: usize) -> TrajectoryPlan {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        TrajectoryPlan::new(sched, grid)
    }

    #[test]
    fn samples_match_schedule_closed_form() {
        let p = plan(12);
        let sched = p.sched();
        for (i, &t) in p.grid().iter().enumerate() {
            assert_eq!(p.alpha_bar_at(i), sched.alpha_bar(t));
        }
        for i in 0..p.steps() {
            assert_eq!(p.ddim_coeffs(i), sched.ddim_coeffs(p.t(i), p.t(i + 1)));
        }
    }

    #[test]
    fn am_weights_built_once_and_sum_to_one() {
        let p = plan(8);
        for order in 2..=5 {
            let s: f64 = p.am_weights(order).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "order {order}");
        }
        // Many consumers, one computation.
        assert_eq!(p.am_builds(), 1);
        assert_eq!(p.am_weights(5), p.am_weights(4), "orders clamp to 4");
    }

    #[test]
    fn lagrange_memo_hits_and_matches_direct() {
        let p = plan(12);
        let idx = [2usize, 5, 8, 10];
        let w1 = p.lagrange_weights(11, &idx);
        let w2 = p.lagrange_weights(11, &idx);
        assert!(Arc::ptr_eq(&w1, &w2), "second lookup must hit the memo");
        assert_eq!(p.lagrange_builds(), 1);
        assert_eq!(p.lagrange_hits(), 1);
        let nodes: Vec<f64> = idx.iter().map(|&n| p.grid()[n]).collect();
        assert_eq!(*w1, lagrange::weights(&nodes, p.grid()[11]));
        // A different index set is its own entry.
        let _ = p.lagrange_weights(11, &[1, 5, 8, 10]);
        assert_eq!(p.lagrange_builds(), 2);
    }

    #[test]
    fn oversized_orders_bypass_memo() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, 16, 1.0, 1e-3);
        let p = TrajectoryPlan::new(sched, grid);
        let idx: Vec<usize> = (0..MAX_MEMO_K + 2).collect();
        let w1 = p.lagrange_weights(MAX_MEMO_K + 3, &idx);
        let w2 = p.lagrange_weights(MAX_MEMO_K + 3, &idx);
        assert_eq!(*w1, *w2);
        assert!(!Arc::ptr_eq(&w1, &w2), "above MAX_MEMO_K computes directly");
    }

    #[test]
    fn dpm_step_plans_match_manual_math() {
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::LogSnr, 4, 1.0, 1e-3);
        let p = TrajectoryPlan::new(sched, grid.clone()).with_dpm_orders(&[3, 2, 1, 3]);
        assert!(p.has_dpm());
        let sp = p.dpm_step(0);
        assert_eq!(sp.order, 3);
        let (tc, tn) = (grid[0], grid[1]);
        let h = sched.lambda(tn) - sched.lambda(tc);
        assert!((sp.a_f - sched.sqrt_alpha_bar(tn) / sched.sqrt_alpha_bar(tc)).abs() < 1e-15);
        assert!((sp.b_f - (-sched.sigma(tn) * h.exp_m1())).abs() < 1e-15);
        let s1 = sched.t_of_lambda(sched.lambda(tc) + h / 3.0);
        assert!((sp.t_s1 - s1).abs() < 1e-12);
        let sp1 = p.dpm_step(2);
        assert_eq!(sp1.order, 1);
        assert_eq!(sp1.t_s1, 0.0, "order-1 steps have no intermediate stage");
    }

    #[test]
    fn suffix_view_offsets_every_accessor() {
        let p = Arc::new(plan(10));
        let v = PlanView::suffix(p.clone(), 4);
        assert_eq!(v.base(), 4);
        assert_eq!(v.steps(), 6);
        assert_eq!(v.grid(), &p.grid()[4..]);
        for i in 0..v.steps() {
            assert_eq!(v.t(i), p.t(4 + i));
            assert_eq!(v.ddim_coeffs(i), p.ddim_coeffs(4 + i));
            assert_eq!(v.alpha_bar_at(i), p.alpha_bar_at(4 + i));
        }
        // The suffix never aliases the full plan's early transitions.
        assert_ne!(v.ddim_coeffs(0), p.ddim_coeffs(0));
        // Full view is transparent.
        let f = PlanView::full(p.clone());
        assert_eq!(f.base(), 0);
        assert_eq!(f.steps(), p.steps());
        assert_eq!(f.t(0), p.t(0));
    }

    #[test]
    fn suffix_view_lagrange_shares_absolute_memo() {
        let p = Arc::new(plan(12));
        let v = PlanView::suffix(p.clone(), 3);
        let mut scratch = Vec::new();
        // Relative (target 8, indices 2/4/6) == absolute (11, 5/7/9).
        let w_rel = v.lagrange_weights_into(8, &[2, 4, 6], &mut scratch);
        let w_abs = p.lagrange_weights(11, &[5, 7, 9]);
        assert!(Arc::ptr_eq(&w_rel, &w_abs), "suffix lookups must hit the shared memo");
        // A full view bypasses the translation and still shares.
        let f = PlanView::full(p.clone());
        let w_full = f.lagrange_weights_into(11, &[5, 7, 9], &mut scratch);
        assert!(Arc::ptr_eq(&w_full, &w_abs));
    }

    #[test]
    #[should_panic(expected = "no transition")]
    fn suffix_view_rejects_empty_window() {
        let p = Arc::new(plan(5));
        let _ = PlanView::suffix(p, 5); // grid has 6 points; base 5 leaves 0 transitions
    }

    #[test]
    fn cache_shares_plans_by_key() {
        let cache = PlanCache::new();
        let sched = VpSchedule::default();
        let key = PlanKey::new("era-4@0.3".into(), 10, GridKind::Uniform, &sched, 1.0, 1e-3);
        let p1 = cache.get_or_build(key.clone(), || plan(10));
        let p2 = cache.get_or_build(key, || plan(10));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!((cache.len(), cache.hits(), cache.misses()), (1, 1, 1));
        let other = PlanKey::new("ddim".into(), 10, GridKind::Uniform, &sched, 1.0, 1e-3);
        let _ = cache.get_or_build(other, || plan(10));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cache_evicts_past_capacity() {
        let cache = PlanCache::with_capacity(2);
        let sched = VpSchedule::default();
        for nfe in [4usize, 5, 6, 7] {
            let key = PlanKey::new("ddim".into(), nfe, GridKind::Uniform, &sched, 1.0, 1e-3);
            let p = cache.get_or_build(key, || plan(nfe));
            assert_eq!(p.steps(), nfe, "capped cache must still serve correct plans");
        }
        assert_eq!(cache.len(), 2, "size stays bounded at the cap");
        assert_eq!(cache.evicted(), 2);
        // The newest configuration is always the retained one: steady
        // traffic ends up cached no matter what arrived before it.
        let key = PlanKey::new("ddim".into(), 7, GridKind::Uniform, &sched, 1.0, 1e-3);
        let _ = cache.get_or_build(key, || plan(7));
        assert_eq!(cache.hits(), 1);
    }
}
