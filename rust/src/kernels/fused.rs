//! In-place fused f32 slice kernels for the solver hot path.
//!
//! Every op writes into a caller-owned buffer — no allocation, one pass
//! where fusion allows it. Iterator zips (not indexed loops) keep the
//! bounds checks out of the inner loops so the compiler auto-vectorises;
//! the arithmetic and accumulation order mirror the original
//! [`crate::tensor::Tensor`] methods exactly, so switching a solver to
//! these kernels changes performance, never numerics (pinned by
//! `tests/golden_trajectories.rs`).

use crate::tensor::Tensor;

/// `out[i] += s * x[i]`.
#[inline]
pub fn axpy(out: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o += s * v;
    }
}

/// `out[i] *= s`.
#[inline]
pub fn scale(out: &mut [f32], s: f32) {
    for o in out.iter_mut() {
        *o *= s;
    }
}

/// `out[i] = 0`.
#[inline]
pub fn zero(out: &mut [f32]) {
    out.fill(0.0);
}

/// `out[i] = a * out[i] + b * e[i]` — the DDIM transition, in place.
#[inline]
pub fn affine_inplace(out: &mut [f32], a: f32, b: f32, e: &[f32]) {
    debug_assert_eq!(out.len(), e.len());
    for (o, &v) in out.iter_mut().zip(e.iter()) {
        *o = a * *o + b * v;
    }
}

/// `out[i] = a * x[i] + b * e[i]` — the DDIM transition into a scratch
/// buffer (predicted eval points, DPM intermediate stages).
#[inline]
pub fn affine_into(out: &mut [f32], a: f32, x: &[f32], b: f32, e: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    debug_assert_eq!(out.len(), e.len());
    for ((o, &xv), &ev) in out.iter_mut().zip(x.iter()).zip(e.iter()) {
        *o = a * xv + b * ev;
    }
}

/// `out = sum_k w[k] * parts[k]`, zeroing `out` first. Accumulation
/// order matches [`Tensor::weighted_sum`] (zero, then axpy in index
/// order) so results are bit-identical to the allocating path.
pub fn weighted_sum_into(out: &mut [f32], parts: &[&[f32]], w: &[f64]) {
    assert_eq!(parts.len(), w.len(), "weights/parts length mismatch");
    zero(out);
    for (p, &wk) in parts.iter().zip(w.iter()) {
        axpy(out, wk as f32, p);
    }
}

/// Fused `out = a * x + b * (sum_k w[k] * parts[k])` with a single pass
/// for the first term — the non-allocating twin of
/// [`Tensor::kernel_weighted_sum`].
pub fn fused_affine_sum_into(
    out: &mut [f32],
    a: f32,
    x: &[f32],
    b: f32,
    parts: &[&[f32]],
    w: &[f32],
) {
    assert_eq!(parts.len(), w.len());
    debug_assert_eq!(out.len(), x.len());
    match parts.first() {
        None => {
            for (o, &xv) in out.iter_mut().zip(x.iter()) {
                *o = a * xv;
            }
        }
        Some(p0) => {
            let bw0 = b * w[0];
            for ((o, &xv), &ev) in out.iter_mut().zip(x.iter()).zip(p0.iter()) {
                *o = a * xv + bw0 * ev;
            }
        }
    }
    for (pk, &wk) in parts.iter().zip(w.iter()).skip(1) {
        axpy(out, b * wk, pk);
    }
}

/// Mean per-row L2 distance between two `rows x cols` buffers — Eq. 15's
/// batch form, identical accumulation to [`Tensor::mean_row_dist`]
/// (f64 row sums, per-row sqrt, f64 mean) without touching the heap.
pub fn mean_row_dist(a: &[f32], b: &[f32], rows: usize, cols: usize) -> f32 {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(b.len(), rows * cols);
    if rows == 0 {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for r in 0..rows {
        let (ra, rb) = (&a[r * cols..(r + 1) * cols], &b[r * cols..(r + 1) * cols]);
        let s: f64 = ra
            .iter()
            .zip(rb.iter())
            .map(|(&x, &y)| {
                let d = (x - y) as f64;
                d * d
            })
            .sum();
        acc += s.sqrt();
    }
    (acc / rows as f64) as f32
}

/// Classifier-free guidance combination, in place over the cond half:
/// `cond[i] = uncond[i] + scale * (cond[i] - uncond[i])`.
///
/// The guided workload evaluates each solver step as paired rows (cond
/// rows then uncond rows in one slab); the wrapper splits the model
/// output down the middle and collapses it here — one pass, no
/// allocation, the cond half becomes the guided eps.
#[inline]
pub fn guided_combine(cond: &mut [f32], uncond: &[f32], scale: f32) {
    debug_assert_eq!(cond.len(), uncond.len());
    for (c, &u) in cond.iter_mut().zip(uncond.iter()) {
        *c = u + scale * (*c - u);
    }
}

/// Append rows `[start, start + n)` of `src` onto `dst` — one contiguous
/// memcpy per call (the rows of a row-major tensor are adjacent), used
/// by the batcher to gather request segments into fused slabs.
pub fn gather_rows(dst: &mut Vec<f32>, src: &Tensor, start: usize, n: usize) {
    dst.extend_from_slice(src.row_span(start, n));
}

/// Copy rows `[src_row, src_row + n)` of `src` into `dst` starting at
/// `dst_row` — the scatter half: slab outputs land directly in the
/// per-request eps buffer, no intermediate slice tensors.
pub fn scatter_rows(dst: &mut Tensor, dst_row: usize, src: &Tensor, src_row: usize, n: usize) {
    assert_eq!(dst.cols(), src.cols(), "scatter_rows column mismatch");
    assert!(dst_row + n <= dst.rows(), "scatter_rows dst overflow");
    assert!(src_row + n <= src.rows(), "scatter_rows src overflow");
    dst.row_span_mut(dst_row, n).copy_from_slice(src.row_span(src_row, n));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_scale_zero() {
        let mut out = vec![1.0, 2.0, 3.0];
        axpy(&mut out, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(out, vec![3.0, 4.0, 5.0]);
        scale(&mut out, 0.5);
        assert_eq!(out, vec![1.5, 2.0, 2.5]);
        zero(&mut out);
        assert_eq!(out, vec![0.0; 3]);
    }

    #[test]
    fn affine_matches_tensor_path() {
        let e = [1.0f32, -1.0, 0.5, 2.0];
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut t = Tensor::from_vec(a.clone(), 2, 2);
        affine_inplace(&mut a, 0.9, -0.2, &e);
        t.affine_inplace(0.9, -0.2, &Tensor::from_vec(e.to_vec(), 2, 2));
        assert_eq!(a.as_slice(), t.as_slice());

        let x = [0.3f32, 0.7, -0.1, 1.1];
        let mut out = vec![0.0f32; 4];
        affine_into(&mut out, 2.0, &x, 3.0, &e);
        for i in 0..4 {
            assert!((out[i] - (2.0 * x[i] + 3.0 * e[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn weighted_sum_into_matches_tensor_weighted_sum() {
        let a = Tensor::from_vec(vec![1.0, 0.0, 2.0, -1.0], 2, 2);
        let b = Tensor::from_vec(vec![0.5, 2.0, -0.5, 1.0], 2, 2);
        let w = [0.75, -1.25];
        let want = Tensor::weighted_sum(&[&a, &b], &w);
        let mut out = vec![9.0f32; 4]; // stale contents must be overwritten
        weighted_sum_into(&mut out, &[a.as_slice(), b.as_slice()], &w);
        assert_eq!(out.as_slice(), want.as_slice());
    }

    #[test]
    fn fused_affine_sum_matches_kernel_weighted_sum() {
        let x = Tensor::from_vec(vec![1.0, -2.0, 0.5, 4.0], 2, 2);
        let e1 = Tensor::from_vec(vec![0.1, 0.2, 0.3, 0.4], 2, 2);
        let e2 = Tensor::from_vec(vec![-1.0, 1.0, -1.0, 1.0], 2, 2);
        let w32 = [2.0f32, -0.5];
        let want = Tensor::kernel_weighted_sum(&x, 0.9, 0.3, &[&e1, &e2], &w32);
        let mut out = vec![0.0f32; 4];
        fused_affine_sum_into(
            &mut out,
            0.9,
            x.as_slice(),
            0.3,
            &[e1.as_slice(), e2.as_slice()],
            &w32,
        );
        assert_eq!(out.as_slice(), want.as_slice());

        // Empty part list degenerates to out = a * x.
        fused_affine_sum_into(&mut out, 0.5, x.as_slice(), 1.0, &[], &[]);
        for (o, &xv) in out.iter().zip(x.as_slice()) {
            assert_eq!(*o, 0.5 * xv);
        }
    }

    #[test]
    fn mean_row_dist_matches_tensor() {
        let a = Tensor::from_vec(vec![3.0, 4.0, 1.0, 1.0, 0.0, 2.0], 3, 2);
        let b = Tensor::from_vec(vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0], 3, 2);
        let got = mean_row_dist(a.as_slice(), b.as_slice(), 3, 2);
        assert_eq!(got, a.mean_row_dist(&b));
        assert_eq!(mean_row_dist(&[], &[], 0, 2), 0.0);
    }

    #[test]
    fn guided_combine_interpolates_and_hits_endpoints() {
        let uncond = [1.0f32, -2.0, 0.5, 4.0];
        // scale 1 recovers cond up to the lerp arithmetic (u + (c - u)).
        let mut c = [3.0f32, 0.0, -1.0, 2.0];
        let cond_orig = c;
        guided_combine(&mut c, &uncond, 1.0);
        for (got, (co, u)) in c.iter().zip(cond_orig.iter().zip(uncond.iter())) {
            assert_eq!(*got, u + (co - u));
        }
        // scale 0 collapses to uncond exactly.
        let mut c0 = cond_orig;
        guided_combine(&mut c0, &uncond, 0.0);
        assert_eq!(c0, uncond);
        // Generic scale matches the manual expression.
        let mut c2 = cond_orig;
        guided_combine(&mut c2, &uncond, 2.5);
        for i in 0..4 {
            assert_eq!(c2[i], uncond[i] + 2.5 * (cond_orig[i] - uncond[i]));
        }
    }

    #[test]
    fn gather_then_scatter_roundtrips() {
        let src = Tensor::from_vec((0..12).map(|v| v as f32).collect(), 4, 3);
        let mut flat = Vec::new();
        gather_rows(&mut flat, &src, 1, 2);
        assert_eq!(flat, &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let gathered = Tensor::from_vec(flat, 2, 3);
        let mut dst = Tensor::zeros(4, 3);
        scatter_rows(&mut dst, 2, &gathered, 0, 2);
        assert_eq!(dst.row(2), src.row(1));
        assert_eq!(dst.row(3), src.row(2));
        assert_eq!(dst.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn scatter_rows_checks_bounds() {
        let src = Tensor::zeros(2, 2);
        let mut dst = Tensor::zeros(2, 2);
        scatter_rows(&mut dst, 1, &src, 0, 2);
    }
}
