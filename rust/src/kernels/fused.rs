//! In-place fused f32 slice kernels for the solver hot path.
//!
//! Every op writes into a caller-owned buffer — no allocation, one pass
//! where fusion allows it. The kernels come in two tiers behind one
//! public API:
//!
//! * [`scalar`] — the always-built reference implementations. Iterator
//!   zips (not indexed loops) keep the bounds checks out of the inner
//!   loops so the compiler auto-vectorises; the arithmetic and
//!   accumulation order mirror the original [`crate::tensor::Tensor`]
//!   methods exactly.
//! * `sse2` (the `simd` cargo feature, x86_64 only) — explicit 4-lane
//!   SSE2 intrinsics. Every vector op is per-lane IEEE-identical to its
//!   scalar counterpart: the kernels are elementwise (one rounding per
//!   op, no FMA contraction, no reassociation), and the one reduction
//!   ([`scalar::row_sq_dist`]) folds its vectorised squares back into
//!   the accumulator in index order. Remainder tails run the scalar
//!   code. Results are therefore **bitwise-equal** to the scalar tier —
//!   pinned by `tests/golden_trajectories.rs` and the simd-vs-scalar
//!   sweeps below — so the feature changes performance, never numerics.
//!
//! The third dispatch tier, device-resident lane state, lives above
//! these kernels: see [`crate::runtime::resident`] and DESIGN.md.

use crate::tensor::Tensor;

/// Always-built reference implementations. Public so benches and tests
/// can compare the dispatched kernels against them directly.
pub mod scalar {
    /// `out[i] += s * x[i]`.
    #[inline]
    pub fn axpy(out: &mut [f32], s: f32, x: &[f32]) {
        debug_assert_eq!(out.len(), x.len());
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o += s * v;
        }
    }

    /// `out[i] = a * out[i] + b * e[i]`.
    #[inline]
    pub fn affine_inplace(out: &mut [f32], a: f32, b: f32, e: &[f32]) {
        debug_assert_eq!(out.len(), e.len());
        for (o, &v) in out.iter_mut().zip(e.iter()) {
            *o = a * *o + b * v;
        }
    }

    /// `out[i] = a * x[i] + b * e[i]`.
    #[inline]
    pub fn affine_into(out: &mut [f32], a: f32, x: &[f32], b: f32, e: &[f32]) {
        debug_assert_eq!(out.len(), x.len());
        debug_assert_eq!(out.len(), e.len());
        for ((o, &xv), &ev) in out.iter_mut().zip(x.iter()).zip(e.iter()) {
            *o = a * xv + b * ev;
        }
    }

    /// `cond[i] = uncond[i] + scale * (cond[i] - uncond[i])`.
    #[inline]
    pub fn guided_combine(cond: &mut [f32], uncond: &[f32], scale: f32) {
        debug_assert_eq!(cond.len(), uncond.len());
        for (c, &u) in cond.iter_mut().zip(uncond.iter()) {
            *c = u + scale * (*c - u);
        }
    }

    /// `sum_i ((a[i] - b[i]) as f64)^2`, folded sequentially in index
    /// order from `0.0` — the row term of Eq. 15. The fold order is
    /// load-bearing: f64 addition is not associative, and both the
    /// SSE2 twin and the engine-resident `delta_eps` path reproduce
    /// this exact sequence to stay bitwise-equal.
    #[inline]
    pub fn row_sq_dist(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0.0f64;
        for (&x, &y) in a.iter().zip(b.iter()) {
            let d = (x - y) as f64;
            acc += d * d;
        }
        acc
    }
}

/// Explicit 4-lane SSE2 implementations. SSE2 is baseline on x86_64,
/// so no runtime feature detection is needed; the module exists only
/// when the `simd` feature is on and the target can run it.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod sse2 {
    use super::scalar;
    use core::arch::x86_64::*;

    const LANES: usize = 4;

    #[inline]
    pub fn axpy(out: &mut [f32], s: f32, x: &[f32]) {
        debug_assert_eq!(out.len(), x.len());
        let n = out.len() / LANES * LANES;
        unsafe {
            let sv = _mm_set1_ps(s);
            let mut i = 0;
            while i < n {
                let o = _mm_loadu_ps(out.as_ptr().add(i));
                let v = _mm_loadu_ps(x.as_ptr().add(i));
                _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_add_ps(o, _mm_mul_ps(sv, v)));
                i += LANES;
            }
        }
        scalar::axpy(&mut out[n..], s, &x[n..]);
    }

    #[inline]
    pub fn affine_inplace(out: &mut [f32], a: f32, b: f32, e: &[f32]) {
        debug_assert_eq!(out.len(), e.len());
        let n = out.len() / LANES * LANES;
        unsafe {
            let av = _mm_set1_ps(a);
            let bv = _mm_set1_ps(b);
            let mut i = 0;
            while i < n {
                let o = _mm_loadu_ps(out.as_ptr().add(i));
                let v = _mm_loadu_ps(e.as_ptr().add(i));
                let r = _mm_add_ps(_mm_mul_ps(av, o), _mm_mul_ps(bv, v));
                _mm_storeu_ps(out.as_mut_ptr().add(i), r);
                i += LANES;
            }
        }
        scalar::affine_inplace(&mut out[n..], a, b, &e[n..]);
    }

    #[inline]
    pub fn affine_into(out: &mut [f32], a: f32, x: &[f32], b: f32, e: &[f32]) {
        debug_assert_eq!(out.len(), x.len());
        debug_assert_eq!(out.len(), e.len());
        let n = out.len() / LANES * LANES;
        unsafe {
            let av = _mm_set1_ps(a);
            let bv = _mm_set1_ps(b);
            let mut i = 0;
            while i < n {
                let xv = _mm_loadu_ps(x.as_ptr().add(i));
                let ev = _mm_loadu_ps(e.as_ptr().add(i));
                let r = _mm_add_ps(_mm_mul_ps(av, xv), _mm_mul_ps(bv, ev));
                _mm_storeu_ps(out.as_mut_ptr().add(i), r);
                i += LANES;
            }
        }
        scalar::affine_into(&mut out[n..], a, &x[n..], b, &e[n..]);
    }

    #[inline]
    pub fn guided_combine(cond: &mut [f32], uncond: &[f32], scale: f32) {
        debug_assert_eq!(cond.len(), uncond.len());
        let n = cond.len() / LANES * LANES;
        unsafe {
            let sv = _mm_set1_ps(scale);
            let mut i = 0;
            while i < n {
                let c = _mm_loadu_ps(cond.as_ptr().add(i));
                let u = _mm_loadu_ps(uncond.as_ptr().add(i));
                let r = _mm_add_ps(u, _mm_mul_ps(sv, _mm_sub_ps(c, u)));
                _mm_storeu_ps(cond.as_mut_ptr().add(i), r);
                i += LANES;
            }
        }
        scalar::guided_combine(&mut cond[n..], &uncond[n..], scale);
    }

    /// Vectorises the f32 subtraction, f64 widening, and f64 squaring,
    /// then folds the four squares into the accumulator **in index
    /// order** — the identical f64 addition sequence as
    /// [`scalar::row_sq_dist`], so the result is bitwise-equal.
    #[inline]
    pub fn row_sq_dist(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len() / LANES * LANES;
        let mut acc = 0.0f64;
        unsafe {
            let mut sq = [0.0f64; LANES];
            let mut i = 0;
            while i < n {
                let av = _mm_loadu_ps(a.as_ptr().add(i));
                let bv = _mm_loadu_ps(b.as_ptr().add(i));
                let d = _mm_sub_ps(av, bv);
                let lo = _mm_cvtps_pd(d);
                let hi = _mm_cvtps_pd(_mm_movehl_ps(d, d));
                _mm_storeu_pd(sq.as_mut_ptr(), _mm_mul_pd(lo, lo));
                _mm_storeu_pd(sq.as_mut_ptr().add(2), _mm_mul_pd(hi, hi));
                acc += sq[0];
                acc += sq[1];
                acc += sq[2];
                acc += sq[3];
                i += LANES;
            }
        }
        // Fold the tail elements directly into the running accumulator
        // (summing them separately and adding the partial would round
        // differently).
        for (&x, &y) in a[n..].iter().zip(b[n..].iter()) {
            let d = (x - y) as f64;
            acc += d * d;
        }
        acc
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use sse2 as fast;
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
use scalar as fast;

/// `out[i] += s * x[i]`.
#[inline]
pub fn axpy(out: &mut [f32], s: f32, x: &[f32]) {
    fast::axpy(out, s, x);
}

/// `out[i] *= s`.
#[inline]
pub fn scale(out: &mut [f32], s: f32) {
    for o in out.iter_mut() {
        *o *= s;
    }
}

/// `out[i] = 0`.
#[inline]
pub fn zero(out: &mut [f32]) {
    out.fill(0.0);
}

/// `out[i] = a * out[i] + b * e[i]` — the DDIM transition, in place.
#[inline]
pub fn affine_inplace(out: &mut [f32], a: f32, b: f32, e: &[f32]) {
    fast::affine_inplace(out, a, b, e);
}

/// `out[i] = a * x[i] + b * e[i]` — the DDIM transition into a scratch
/// buffer (predicted eval points, DPM intermediate stages).
#[inline]
pub fn affine_into(out: &mut [f32], a: f32, x: &[f32], b: f32, e: &[f32]) {
    fast::affine_into(out, a, x, b, e);
}

/// `out = sum_k w[k] * parts[k]`, zeroing `out` first. Accumulation
/// order matches [`Tensor::weighted_sum`] (zero, then axpy in index
/// order) so results are bit-identical to the allocating path.
pub fn weighted_sum_into(out: &mut [f32], parts: &[&[f32]], w: &[f64]) {
    assert_eq!(parts.len(), w.len(), "weights/parts length mismatch");
    zero(out);
    for (p, &wk) in parts.iter().zip(w.iter()) {
        axpy(out, wk as f32, p);
    }
}

/// Fused `out = a * x + b * (sum_k w[k] * parts[k])` with a single pass
/// for the first term — the non-allocating twin of
/// [`Tensor::kernel_weighted_sum`]. Weights arrive as `f64` (the
/// [`crate::kernels::TrajectoryPlan`] native dtype, shared with
/// [`weighted_sum_into`]) and are narrowed to f32 here, at the same
/// point the callers used to narrow them.
pub fn fused_affine_sum_into(
    out: &mut [f32],
    a: f32,
    x: &[f32],
    b: f32,
    parts: &[&[f32]],
    w: &[f64],
) {
    assert_eq!(parts.len(), w.len());
    debug_assert_eq!(out.len(), x.len());
    match parts.first() {
        None => {
            for (o, &xv) in out.iter_mut().zip(x.iter()) {
                *o = a * xv;
            }
        }
        Some(p0) => {
            let bw0 = b * (w[0] as f32);
            affine_into(out, a, x, bw0, p0);
        }
    }
    for (pk, &wk) in parts.iter().zip(w.iter()).skip(1) {
        axpy(out, b * (wk as f32), pk);
    }
}

/// Mean per-row L2 distance between two `rows x cols` buffers — Eq. 15's
/// batch form, identical accumulation to [`Tensor::mean_row_dist`]
/// (f64 row sums, per-row sqrt, f64 mean) without touching the heap.
pub fn mean_row_dist(a: &[f32], b: &[f32], rows: usize, cols: usize) -> f32 {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(b.len(), rows * cols);
    if rows == 0 {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for r in 0..rows {
        let (ra, rb) = (&a[r * cols..(r + 1) * cols], &b[r * cols..(r + 1) * cols]);
        acc += fast::row_sq_dist(ra, rb).sqrt();
    }
    (acc / rows as f64) as f32
}

/// Per-row L2 distances between two `rows x cols` buffers, appended to
/// `out` — the engine-resident half of Eq. 15. Each pushed value is one
/// row's term from [`mean_row_dist`] (same f64 fold, same sqrt), so a
/// host that averages a member's span of these in index order and casts
/// through f32 reproduces `mean_row_dist` bitwise.
pub fn row_l2_dists_into(a: &[f32], b: &[f32], rows: usize, cols: usize, out: &mut Vec<f64>) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(b.len(), rows * cols);
    out.reserve_exact(rows);
    for r in 0..rows {
        let (ra, rb) = (&a[r * cols..(r + 1) * cols], &b[r * cols..(r + 1) * cols]);
        out.push(fast::row_sq_dist(ra, rb).sqrt());
    }
}

/// Classifier-free guidance combination, in place over the cond half:
/// `cond[i] = uncond[i] + scale * (cond[i] - uncond[i])`.
///
/// The guided workload evaluates each solver step as paired rows (cond
/// rows then uncond rows in one slab); the wrapper splits the model
/// output down the middle and collapses it here — one pass, no
/// allocation, the cond half becomes the guided eps.
#[inline]
pub fn guided_combine(cond: &mut [f32], uncond: &[f32], scale: f32) {
    fast::guided_combine(cond, uncond, scale);
}

/// Append rows `[start, start + n)` of `src` onto `dst` — one contiguous
/// memcpy per call (the rows of a row-major tensor are adjacent), used
/// by the batcher to gather request segments into fused slabs. Reserves
/// the exact span up front so the gather never reallocates mid-copy
/// (and never over-grows a recycled slab buffer past its high-water
/// mark).
pub fn gather_rows(dst: &mut Vec<f32>, src: &Tensor, start: usize, n: usize) {
    dst.reserve_exact(n * src.cols());
    dst.extend_from_slice(src.row_span(start, n));
}

/// Copy rows `[src_row, src_row + n)` of `src` into `dst` starting at
/// `dst_row` — the scatter half: slab outputs land directly in the
/// per-request eps buffer, no intermediate slice tensors.
pub fn scatter_rows(dst: &mut Tensor, dst_row: usize, src: &Tensor, src_row: usize, n: usize) {
    assert_eq!(dst.cols(), src.cols(), "scatter_rows column mismatch");
    assert!(dst_row + n <= dst.rows(), "scatter_rows dst overflow");
    assert!(src_row + n <= src.rows(), "scatter_rows src overflow");
    dst.row_span_mut(dst_row, n).copy_from_slice(src.row_span(src_row, n));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_scale_zero() {
        let mut out = vec![1.0, 2.0, 3.0];
        axpy(&mut out, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(out, vec![3.0, 4.0, 5.0]);
        scale(&mut out, 0.5);
        assert_eq!(out, vec![1.5, 2.0, 2.5]);
        zero(&mut out);
        assert_eq!(out, vec![0.0; 3]);
    }

    #[test]
    fn affine_matches_tensor_path() {
        let e = [1.0f32, -1.0, 0.5, 2.0];
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut t = Tensor::from_vec(a.clone(), 2, 2);
        affine_inplace(&mut a, 0.9, -0.2, &e);
        t.affine_inplace(0.9, -0.2, &Tensor::from_vec(e.to_vec(), 2, 2));
        assert_eq!(a.as_slice(), t.as_slice());

        let x = [0.3f32, 0.7, -0.1, 1.1];
        let mut out = vec![0.0f32; 4];
        affine_into(&mut out, 2.0, &x, 3.0, &e);
        for i in 0..4 {
            assert!((out[i] - (2.0 * x[i] + 3.0 * e[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn weighted_sum_into_matches_tensor_weighted_sum() {
        let a = Tensor::from_vec(vec![1.0, 0.0, 2.0, -1.0], 2, 2);
        let b = Tensor::from_vec(vec![0.5, 2.0, -0.5, 1.0], 2, 2);
        let w = [0.75, -1.25];
        let want = Tensor::weighted_sum(&[&a, &b], &w);
        let mut out = vec![9.0f32; 4]; // stale contents must be overwritten
        weighted_sum_into(&mut out, &[a.as_slice(), b.as_slice()], &w);
        assert_eq!(out.as_slice(), want.as_slice());
    }

    #[test]
    fn fused_affine_sum_matches_kernel_weighted_sum() {
        let x = Tensor::from_vec(vec![1.0, -2.0, 0.5, 4.0], 2, 2);
        let e1 = Tensor::from_vec(vec![0.1, 0.2, 0.3, 0.4], 2, 2);
        let e2 = Tensor::from_vec(vec![-1.0, 1.0, -1.0, 1.0], 2, 2);
        let w = [2.0f64, -0.5];
        let want = Tensor::kernel_weighted_sum(&x, 0.9, 0.3, &[&e1, &e2], &w);
        let mut out = vec![0.0f32; 4];
        fused_affine_sum_into(
            &mut out,
            0.9,
            x.as_slice(),
            0.3,
            &[e1.as_slice(), e2.as_slice()],
            &w,
        );
        assert_eq!(out.as_slice(), want.as_slice());

        // Empty part list degenerates to out = a * x.
        fused_affine_sum_into(&mut out, 0.5, x.as_slice(), 1.0, &[], &[]);
        for (o, &xv) in out.iter().zip(x.as_slice()) {
            assert_eq!(*o, 0.5 * xv);
        }
    }

    #[test]
    fn mean_row_dist_matches_tensor() {
        let a = Tensor::from_vec(vec![3.0, 4.0, 1.0, 1.0, 0.0, 2.0], 3, 2);
        let b = Tensor::from_vec(vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0], 3, 2);
        let got = mean_row_dist(a.as_slice(), b.as_slice(), 3, 2);
        assert_eq!(got, a.mean_row_dist(&b));
        assert_eq!(mean_row_dist(&[], &[], 0, 2), 0.0);
    }

    #[test]
    fn row_l2_dists_match_mean_row_dist() {
        // Aggregating the per-row distances the way the resident-state
        // scheduler does (sequential f64 sum over a member's span, mean,
        // f32 narrowing) must reproduce mean_row_dist bitwise.
        let (rows, cols) = (5, 7);
        let a: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.61).cos()).collect();
        let mut dists = Vec::new();
        row_l2_dists_into(&a, &b, rows, cols, &mut dists);
        assert_eq!(dists.len(), rows);
        for (start, n) in [(0usize, rows), (1, 3), (4, 1)] {
            let mut acc = 0.0f64;
            for &d in &dists[start..start + n] {
                acc += d;
            }
            let got = (acc / n as f64) as f32;
            let span = |buf: &[f32]| buf[start * cols..(start + n) * cols].to_vec();
            assert_eq!(got, mean_row_dist(&span(&a), &span(&b), n, cols));
        }
    }

    #[test]
    fn guided_combine_interpolates_and_hits_endpoints() {
        let uncond = [1.0f32, -2.0, 0.5, 4.0];
        // scale 1 recovers cond up to the lerp arithmetic (u + (c - u)).
        let mut c = [3.0f32, 0.0, -1.0, 2.0];
        let cond_orig = c;
        guided_combine(&mut c, &uncond, 1.0);
        for (got, (co, u)) in c.iter().zip(cond_orig.iter().zip(uncond.iter())) {
            assert_eq!(*got, u + (co - u));
        }
        // scale 0 collapses to uncond exactly.
        let mut c0 = cond_orig;
        guided_combine(&mut c0, &uncond, 0.0);
        assert_eq!(c0, uncond);
        // Generic scale matches the manual expression.
        let mut c2 = cond_orig;
        guided_combine(&mut c2, &uncond, 2.5);
        for i in 0..4 {
            assert_eq!(c2[i], uncond[i] + 2.5 * (cond_orig[i] - uncond[i]));
        }
    }

    #[test]
    fn gather_then_scatter_roundtrips() {
        let src = Tensor::from_vec((0..12).map(|v| v as f32).collect(), 4, 3);
        let mut flat = Vec::new();
        gather_rows(&mut flat, &src, 1, 2);
        assert_eq!(flat, &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let gathered = Tensor::from_vec(flat, 2, 3);
        let mut dst = Tensor::zeros(4, 3);
        scatter_rows(&mut dst, 2, &gathered, 0, 2);
        assert_eq!(dst.row(2), src.row(1));
        assert_eq!(dst.row(3), src.row(2));
        assert_eq!(dst.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn gather_rows_reserves_exactly_once() {
        let src = Tensor::from_vec((0..64).map(|v| v as f32).collect(), 16, 4);
        let mut dst = Vec::new();
        gather_rows(&mut dst, &src, 2, 5);
        // reserve_exact before the copy: capacity is the span itself,
        // not a doubling-growth overshoot.
        assert_eq!(dst.len(), 20);
        assert_eq!(dst.capacity(), 20);
        // A pre-reserved buffer (the recycled-slab path) is untouched.
        let mut pre = Vec::with_capacity(64);
        gather_rows(&mut pre, &src, 0, 4);
        assert_eq!(pre.capacity(), 64);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn scatter_rows_checks_bounds() {
        let src = Tensor::zeros(2, 2);
        let mut dst = Tensor::zeros(2, 2);
        scatter_rows(&mut dst, 1, &src, 0, 2);
    }

    /// Drive every dispatched kernel against its scalar reference over
    /// odd lengths, unaligned offsets, and remainder tails. With the
    /// `simd` feature off this is an identity check; with it on it is
    /// the bitwise scalar/SSE2 equivalence sweep.
    #[test]
    fn dispatched_kernels_match_scalar_reference_bitwise() {
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 1.0
        };
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 31, 63, 64, 67, 128, 257] {
            for off in [0usize, 1, 2, 3] {
                let n = len + off;
                let xs: Vec<f32> = (0..n).map(|_| next()).collect();
                let es: Vec<f32> = (0..n).map(|_| next()).collect();
                let base: Vec<f32> = (0..n).map(|_| next()).collect();
                let (x, e, b0) = (&xs[off..], &es[off..], &base[off..]);

                let mut got = b0.to_vec();
                let mut want = b0.to_vec();
                axpy(&mut got, 1.7, x);
                scalar::axpy(&mut want, 1.7, x);
                assert_eq!(got, want, "axpy len={len} off={off}");

                got.copy_from_slice(b0);
                want.copy_from_slice(b0);
                affine_inplace(&mut got, 0.93, -0.41, e);
                scalar::affine_inplace(&mut want, 0.93, -0.41, e);
                assert_eq!(got, want, "affine_inplace len={len} off={off}");

                affine_into(&mut got, -0.37, x, 1.19, e);
                scalar::affine_into(&mut want, -0.37, x, 1.19, e);
                assert_eq!(got, want, "affine_into len={len} off={off}");

                got.copy_from_slice(b0);
                want.copy_from_slice(b0);
                guided_combine(&mut got, x, 3.25);
                scalar::guided_combine(&mut want, x, 3.25);
                assert_eq!(got, want, "guided_combine len={len} off={off}");

                let got_d = {
                    let mut v = Vec::new();
                    row_l2_dists_into(x, e, 1, len, &mut v);
                    v[0]
                };
                assert_eq!(
                    got_d.to_bits(),
                    scalar::row_sq_dist(x, e).sqrt().to_bits(),
                    "row_sq_dist len={len} off={off}"
                );
            }
        }
    }
}
