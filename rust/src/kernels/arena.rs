//! Per-solver scratch memory: a recycling arena for step buffers and a
//! bounded newest-first history ring.
//!
//! Both exist so a solver's steady-state step touches the allocator
//! zero times: scratch tensors are taken once and given back (or held
//! as named fields), and history slots adopt the model's output tensors
//! by move, handing evicted slots back for reuse as the next scratch.

use std::collections::VecDeque;

use crate::tensor::Tensor;

/// A pool of equally-shaped scratch tensors. `take` pops a recycled
/// buffer (or allocates on first use), `give` returns it for reuse.
/// Shape is fixed at construction — solvers know their batch geometry
/// up front.
pub struct ScratchArena {
    rows: usize,
    cols: usize,
    free: Vec<Tensor>,
    allocated: usize,
}

impl ScratchArena {
    pub fn new(rows: usize, cols: usize) -> ScratchArena {
        ScratchArena { rows, cols, free: Vec::new(), allocated: 0 }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Tensors handed out over the arena's lifetime that required a
    /// fresh allocation (steady state: stops growing after warmup).
    pub fn allocations(&self) -> usize {
        self.allocated
    }

    /// Pop a scratch tensor (contents unspecified — callers overwrite).
    pub fn take(&mut self) -> Tensor {
        match self.free.pop() {
            Some(t) => t,
            None => {
                self.allocated += 1;
                Tensor::zeros(self.rows, self.cols)
            }
        }
    }

    /// Return a tensor for reuse. Shape-checked: recycling a foreign
    /// buffer would corrupt every later `take`.
    pub fn give(&mut self, t: Tensor) {
        assert_eq!(
            (t.rows(), t.cols()),
            (self.rows, self.cols),
            "arena given a tensor of the wrong shape"
        );
        self.free.push(t);
    }
}

/// Bounded newest-first tensor history (the Adams multistep window).
///
/// `push` adopts the tensor by move and returns the evicted oldest slot
/// once the ring is full — callers reuse it as their next scratch
/// buffer, closing the allocation loop. Index 0 is the newest entry.
pub struct HistoryRing {
    slots: VecDeque<Tensor>,
    cap: usize,
}

impl HistoryRing {
    pub fn new(cap: usize) -> HistoryRing {
        assert!(cap >= 1, "history ring needs at least one slot");
        // +1: push_front momentarily holds cap+1 before pop_back.
        HistoryRing { slots: VecDeque::with_capacity(cap + 1), cap }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Newest-first entry (`get(0)` is the most recent push).
    pub fn get(&self, newest_back: usize) -> &Tensor {
        &self.slots[newest_back]
    }

    /// Newest-first iteration.
    pub fn iter(&self) -> impl Iterator<Item = &Tensor> {
        self.slots.iter()
    }

    /// Push the newest entry; returns the evicted oldest one when full.
    pub fn push(&mut self, t: Tensor) -> Option<Tensor> {
        self.slots.push_front(t);
        if self.slots.len() > self.cap {
            self.slots.pop_back()
        } else {
            None
        }
    }
}

/// Shape-keyed tensor free lists for the lane engine's struct-of-arrays
/// state: lane splits and member retirement allocate/free stacked
/// tensors of varying row counts, and this pool recycles them so churny
/// admission/cancel traffic stops touching the allocator once warm.
///
/// Unlike [`ScratchArena`] (one fixed shape per solver), shapes here
/// vary with lane membership, so the free lists are keyed by
/// `(rows, cols)` and bounded in total (a load spike must not pin
/// memory forever).
pub struct TensorPool {
    free: std::collections::BTreeMap<(usize, usize), Vec<Tensor>>,
    held: usize,
    cap: usize,
    allocated: usize,
}

impl TensorPool {
    /// Pool retaining at most `cap` free tensors across all shapes.
    pub fn new(cap: usize) -> TensorPool {
        TensorPool { free: std::collections::BTreeMap::new(), held: 0, cap, allocated: 0 }
    }

    /// Tensors handed out that required a fresh allocation.
    pub fn allocations(&self) -> usize {
        self.allocated
    }

    /// Free tensors currently retained.
    pub fn held(&self) -> usize {
        self.held
    }

    /// Pop a `(rows, cols)` tensor. Contents are unspecified — callers
    /// overwrite every element they read.
    pub fn take(&mut self, rows: usize, cols: usize) -> Tensor {
        match self.free.get_mut(&(rows, cols)).and_then(|v| v.pop()) {
            Some(t) => {
                self.held -= 1;
                t
            }
            None => {
                self.allocated += 1;
                Tensor::zeros(rows, cols)
            }
        }
    }

    /// Return a tensor for reuse (dropped when the pool is at capacity
    /// or the tensor is degenerate).
    pub fn give(&mut self, t: Tensor) {
        if self.held >= self.cap || t.rows() == 0 || t.cols() == 0 {
            return;
        }
        self.free.entry((t.rows(), t.cols())).or_default().push(t);
        self.held += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_pool_recycles_by_shape_and_bounds_retention() {
        let mut p = TensorPool::new(2);
        let a = p.take(3, 2);
        let b = p.take(4, 2);
        assert_eq!(p.allocations(), 2);
        p.give(a);
        p.give(b);
        assert_eq!(p.held(), 2);
        let a2 = p.take(3, 2);
        assert_eq!((a2.rows(), a2.cols()), (3, 2));
        assert_eq!(p.allocations(), 2, "shape hit must not allocate");
        // At capacity the give is dropped, not retained.
        p.give(a2);
        p.give(Tensor::zeros(9, 9));
        assert_eq!(p.held(), 2);
        // Degenerate shapes are never retained.
        p.take(3, 2);
        p.give(Tensor::zeros(0, 2));
        assert_eq!(p.held(), 1);
    }

    #[test]
    fn arena_recycles() {
        let mut a = ScratchArena::new(2, 3);
        let t1 = a.take();
        let t2 = a.take();
        assert_eq!(a.allocations(), 2);
        a.give(t1);
        a.give(t2);
        let _t3 = a.take();
        let _t4 = a.take();
        assert_eq!(a.allocations(), 2, "recycled takes must not allocate");
        assert_eq!((_t3.rows(), _t3.cols()), (2, 3));
    }

    #[test]
    #[should_panic(expected = "wrong shape")]
    fn arena_rejects_foreign_shapes() {
        let mut a = ScratchArena::new(2, 3);
        a.give(Tensor::zeros(3, 2));
    }

    #[test]
    fn ring_orders_newest_first_and_evicts() {
        let mut r = HistoryRing::new(3);
        for v in 0..3 {
            assert!(r.push(Tensor::from_vec(vec![v as f32], 1, 1)).is_none());
        }
        assert_eq!(r.len(), 3);
        let evicted = r.push(Tensor::from_vec(vec![3.0], 1, 1)).expect("full ring evicts");
        assert_eq!(evicted.as_slice(), &[0.0]);
        assert_eq!(r.get(0).as_slice(), &[3.0]);
        assert_eq!(r.get(2).as_slice(), &[1.0]);
        let newest_first: Vec<f32> = r.iter().map(|t| t.as_slice()[0]).collect();
        assert_eq!(newest_first, vec![3.0, 2.0, 1.0]);
    }
}
