//! Zero-copy solver kernel layer: the allocation-free primitives behind
//! every solver step, plus the shared per-trajectory plan cache.
//!
//! The sampling hot path used to pay three avoidable costs per step:
//! full-iterate clones into [`crate::solvers::EvalRequest`], re-derived
//! schedule/coefficient math (DDIM transfer coefficients, Adams–Moulton
//! weights, DPM exponential-integrator coefficients, Lagrange basis
//! weights) that depends only on `(solver kind, grid, schedule)`, and
//! per-row copies when the batcher assembled fused slabs. This module
//! removes all three:
//!
//! * [`fused`] — in-place fused f32 slice ops (axpy chains, k-way affine
//!   combinations, scaled-diff error norms, row-slab gather/scatter).
//!   They are the Rust-native mirror of the `solver_combine` Pallas
//!   kernel family: one pass over the output, no intermediate tensors.
//! * [`arena`] — [`ScratchArena`] (recycled step buffers),
//!   [`HistoryRing`] (bounded newest-first history that moves model
//!   outputs in and hands evicted slots back for reuse) and
//!   [`TensorPool`] (shape-keyed free lists backing the lane engine's
//!   stacked state across splits and compaction), so solvers run with
//!   zero steady-state heap allocations per step.
//! * [`plan`] — [`TrajectoryPlan`]: the grid, VP-schedule samples,
//!   per-transition DDIM coefficients, AM corrector weights, per-step
//!   DPM coefficients and a concurrent per-`(step, indices)` Lagrange
//!   weight memo, computed once per `(solver kind, NFE, grid kind,
//!   schedule, t_end)` and shared across requests and coordinator
//!   shards through [`PlanCache`].
//!
//! Solvers own their iterate as `Arc<Tensor>`; `EvalRequest` hands out a
//! reference-counted view instead of a deep clone, and the batcher ships
//! the `Arc` itself through to the engine when a request's rows form a
//! whole slab (the true zero-copy path).

pub mod arena;
pub mod fused;
pub mod plan;

pub use arena::{HistoryRing, ScratchArena, TensorPool};
pub use plan::{DpmStepPlan, PlanCache, PlanKey, PlanView, TrajectoryPlan};
