//! Minimal JSON substrate (parser + writer).
//!
//! The offline registry has no serde; the artifact manifest, the serving
//! wire protocol and the results files are all JSON, so we implement the
//! subset of RFC 8259 they need: objects, arrays, strings with the common
//! escapes, f64 numbers (including exponents), booleans and null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array of numbers -> Vec<f64>; None if any element is non-numeric.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    // -- construction ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        }
    }

    // -- serialisation -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    /// Serialise into an existing buffer (appends; never clears). The
    /// pooled-encode-buffer reply path uses this to avoid a fresh
    /// `String` per frame.
    pub fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_f64(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

/// The one number-formatting rule for every JSON byte this crate emits:
/// integral finite values print as integers, everything else via Rust's
/// shortest-round-trip float formatting, non-finite as `null`. Exposed so
/// the allocation-free reply writers in `server/protocol.rs` produce bytes
/// identical to the `Json` tree path.
pub fn write_f64(n: f64, out: &mut String) {
    use std::fmt::Write as _;
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{}", n);
        }
    } else {
        // JSON has no Inf/NaN; null is the conventional escape.
        out.push_str("null");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not produced by
                            // our writers); map to replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes at once (fast path for the
                    // long float arrays in the manifest).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(
                        |_| self.err("invalid utf-8 in string"),
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(parse("-1e-3").unwrap(), Json::Num(-0.001));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3e2],"b":false,"s":"a\"b\\c","z":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn float_array_helpers() {
        let v = parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.5, 3.0]);
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0f32, 2.5, 3.0]);
        assert!(parse("[1, \"x\"]").unwrap().as_f64_vec().is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn writer_escapes_control_chars() {
        let s = Json::Str("a\x01b".into()).to_string();
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("a\x01b".into()));
    }

    #[test]
    fn writer_integers_stay_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn large_numeric_array_parses() {
        let xs: Vec<f64> = (0..10_000).map(|i| i as f64 * 0.1).collect();
        let text = Json::arr_f64(&xs).to_string();
        let back = parse(&text).unwrap().as_f64_vec().unwrap();
        assert_eq!(back.len(), xs.len());
        assert!((back[9_999] - 999.9).abs() < 1e-9);
    }
}
