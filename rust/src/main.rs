//! `era-serve` — the serving leader: PJRT engine + sharded worker pool
//! of continuous-batching coordinators + TCP JSON-lines front end.
//!
//! ```text
//! era-serve --artifacts artifacts --addr 127.0.0.1:7437 \
//!           --warmup gmm8,checkerboard --shards 4 --placement affinity \
//!           --executors 2 --pipeline-depth 2 \
//!           --deadline-ms 2000 --max-active 64
//! ```
//!
//! `--executors`/`--pipeline-depth` shape each shard's pipelined
//! scheduler: E engine-executor threads per shard and up to D dispatch
//! rounds in flight (D = 1 reproduces the serialized pre-pipeline
//! scheduling exactly; results are bit-identical at any setting).
//!
//! Clients speak the one-JSON-object-per-line protocol of
//! [`era_solver::server`]; `examples/quickstart.rs` and
//! `examples/serve_bench.rs` are reference clients. `sample` ops accept
//! per-request workload fields (`guidance_scale`/`guide_class`,
//! `strength` + `init`, `churn` — DESIGN.md §8); guided requests are
//! admission-charged as paired rows, and the heartbeat summary reports
//! the running guided/img2img/sde mix plus per-stage latency p50/p99.
//!
//! QoS (DESIGN.md §12): `sample` ops accept `qos`/`min_nfe`/
//! `conv_threshold`; `--conv-threshold` sets the convergence default
//! inherited by non-strict requests that did not set their own.
//!
//! Observability (DESIGN.md §11): the `metrics` wire op returns the
//! same Prometheus page `--metrics <path>` refreshes on each heartbeat,
//! and `trace <tag>` dumps a tagged request's flight-recorder spans.
//!
//! Front end (DESIGN.md §13): the default is the portable blocking
//! thread-per-connection server; `--gateway` (Linux) serves the same
//! wire protocol from a fixed pool of `--io-threads` epoll event
//! loops, multiplexing thousands of connections. Both front ends speak
//! the negotiated binary sample encoding (`"encoding":"bin"`: JSON
//! header line + counted little-endian f32 payload, DESIGN.md §6)
//! alongside the default JSON rows.

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, ModelBank};
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::runtime::PjRtEngine;
use era_solver::server::{Server, ServerConfig};

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "addr", value: Some("host:port"), help: "bind address (default: 127.0.0.1:7437)" },
    OptSpec { name: "warmup", value: Some("ds,ds"), help: "datasets to pre-compile (default: all)" },
    OptSpec { name: "shards", value: Some("n"), help: "coordinator shards (default: 1)" },
    OptSpec { name: "executors", value: Some("n"), help: "engine executors per shard (default: 1)" },
    OptSpec { name: "pipeline-depth", value: Some("n"), help: "dispatch rounds kept in flight per shard; 1 = serialized (default: 2)" },
    OptSpec { name: "placement", value: Some("policy"), help: "round-robin | least-loaded | affinity (default: least-loaded)" },
    OptSpec { name: "deadline-ms", value: Some("ms"), help: "default per-request deadline, 0 = none (default: 0)" },
    OptSpec { name: "max-inflight-rows", value: Some("n"), help: "global admission cap in rows, 0 = unbounded (default: 0)" },
    OptSpec { name: "max-active", value: Some("n"), help: "running-batch request cap per shard (default: 64)" },
    OptSpec { name: "queue", value: Some("n"), help: "admission queue bound per shard (default: 256)" },
    OptSpec { name: "max-rows", value: Some("n"), help: "rows per fused eval (default: 256)" },
    OptSpec { name: "min-rows", value: Some("n"), help: "linger threshold rows (default: 32)" },
    OptSpec { name: "max-wait-ms", value: Some("ms"), help: "linger budget (default: 2)" },
    OptSpec { name: "max-conns", value: Some("n"), help: "connection cap (default: 64 blocking, 1024 gateway)" },
    OptSpec { name: "gateway", value: None, help: "serve with the epoll readiness gateway (Linux) instead of a thread per connection" },
    OptSpec { name: "io-threads", value: Some("n"), help: "gateway event-loop threads (default: 2)" },
    OptSpec { name: "conv-threshold", value: Some("x"), help: "convergence default for non-strict requests without their own, 0 = off (default: 0)" },
    OptSpec { name: "metrics", value: Some("path"), help: "write a Prometheus text-exposition page here on every heartbeat" },
];

fn run() -> Result<(), String> {
    let args = Args::parse("era-serve: ERA-Solver diffusion sampling server", OPTS)?;

    let artifacts = args.str_or("artifacts", "artifacts");
    let engine = Arc::new(PjRtEngine::new(&artifacts)?);
    let manifest = engine.manifest().clone();
    eprintln!(
        "[era-serve] loaded manifest: {} datasets, buckets {:?}",
        manifest.datasets.len(),
        manifest.batch_buckets
    );

    let warmup: Vec<String> = match args.present("warmup") {
        true => args.list_or("warmup", &[]),
        false => manifest.datasets.keys().cloned().collect(),
    };
    for ds in &warmup {
        let t0 = std::time::Instant::now();
        engine.warmup(ds, &manifest.batch_buckets)?;
        eprintln!("[era-serve] warmed {ds} in {:?}", t0.elapsed());
    }

    let deadline_ms = args.u64_or("deadline-ms", 0)?;
    let shard_config = CoordinatorConfig {
        max_active: args.usize_or("max-active", 64)?,
        queue_capacity: args.usize_or("queue", 256)?,
        policy: BatchPolicy {
            max_rows: args.usize_or("max-rows", 256)?,
            min_rows: args.usize_or("min-rows", 32)?,
            max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)?),
        },
        default_deadline: match deadline_ms {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        executors_per_shard: args.usize_or("executors", 1)?.max(1),
        pipeline_depth: args.usize_or("pipeline-depth", 2)?.max(1),
    };
    let placement_name = args.str_or("placement", "least-loaded");
    let pool_config = PoolConfig {
        shards: args.usize_or("shards", 1)?.max(1),
        placement: PlacementPolicy::parse(&placement_name)
            .ok_or_else(|| format!("unknown placement policy '{placement_name}'"))?,
        shard: shard_config,
        max_inflight_rows: args.usize_or("max-inflight-rows", 0)?,
    };
    eprintln!(
        "[era-serve] pool: {} shard(s) x {} executor(s), pipeline depth {}, placement {}",
        pool_config.shards,
        pool_config.shard.executors_per_shard,
        pool_config.shard.pipeline_depth,
        pool_config.placement.label()
    );
    let bank: Arc<dyn ModelBank> = engine;
    let pool = Arc::new(WorkerPool::start(bank, pool_config));

    let conv_threshold = args.f64_or("conv-threshold", 0.0)?;
    if !(conv_threshold.is_finite() && conv_threshold >= 0.0) {
        return Err(format!("--conv-threshold {conv_threshold} out of range"));
    }
    // Keep whichever front end we started alive for the life of the
    // process (dropping it would stop accepting).
    let mut _server: Option<Server> = None;
    #[cfg(target_os = "linux")]
    let mut _gateway: Option<era_solver::server::gateway::Gateway> = None;
    let addr = args.str_or("addr", "127.0.0.1:7437");
    if args.present("gateway") {
        #[cfg(target_os = "linux")]
        {
            use era_solver::server::gateway::{Gateway, GatewayConfig};
            let io_threads = args.usize_or("io-threads", 2)?.max(1);
            let gateway_cfg = GatewayConfig {
                addr,
                max_connections: args.usize_or("max-conns", 1024)?,
                default_conv_threshold: conv_threshold,
                io_threads,
                ..GatewayConfig::default()
            };
            let gateway =
                Gateway::start(pool.clone(), gateway_cfg).map_err(|e| e.to_string())?;
            eprintln!(
                "[era-serve] gateway listening on {} ({io_threads} io thread(s))",
                gateway.local_addr()
            );
            _gateway = Some(gateway);
        }
        #[cfg(not(target_os = "linux"))]
        return Err("--gateway requires Linux (epoll readiness transport)".into());
    } else {
        let server_cfg = ServerConfig {
            addr,
            max_connections: args.usize_or("max-conns", 64)?,
            default_conv_threshold: conv_threshold,
        };
        let server = Server::start(pool.clone(), server_cfg).map_err(|e| e.to_string())?;
        eprintln!("[era-serve] listening on {}", server.local_addr());
        _server = Some(server);
    }

    // Periodic telemetry heartbeat until killed. With --metrics, each
    // beat also atomically refreshes a Prometheus text-exposition file
    // (write temp, rename) for a node-exporter-style scrape.
    let metrics_path = match args.present("metrics") {
        true => Some(args.str_or("metrics", "")),
        false => None,
    };
    loop {
        std::thread::sleep(std::time::Duration::from_secs(30));
        let stats = pool.stats();
        eprintln!("[era-serve] {}", stats.summary());
        if let Some(path) = &metrics_path {
            let tmp = format!("{path}.tmp");
            if let Err(e) = std::fs::write(&tmp, stats.prometheus())
                .and_then(|_| std::fs::rename(&tmp, path))
            {
                eprintln!("[era-serve] metrics write to {path} failed: {e}");
            }
        }
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}
