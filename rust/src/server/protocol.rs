//! Wire protocol: JSON-line <-> typed request/response mapping.

use crate::coordinator::{RequestSpec, SamplingResult};
use crate::json::{self, Json};

/// Parsed client request.
#[derive(Debug)]
pub enum Request {
    Ping,
    Stats,
    Sample { spec: RequestSpec, return_samples: bool },
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = json::parse(line).map_err(|e| format!("{e:?}"))?;
    let op = j.get("op").as_str().ok_or("missing op")?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "sample" => {
            let d = RequestSpec::default();
            let spec = RequestSpec {
                dataset: j.get("dataset").as_str().unwrap_or(&d.dataset).to_string(),
                solver: j.get("solver").as_str().unwrap_or(&d.solver).to_string(),
                nfe: j.get("nfe").as_usize().unwrap_or(d.nfe),
                n_samples: j.get("n_samples").as_usize().unwrap_or(d.n_samples),
                grid: j.get("grid").as_str().unwrap_or(&d.grid).to_string(),
                t_end: j.get("t_end").as_f64().unwrap_or(d.t_end),
                seed: j.get("seed").as_f64().unwrap_or(0.0) as u64,
            };
            let return_samples = j.get("return_samples").as_bool().unwrap_or(false);
            Ok(Request::Sample { spec, return_samples })
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

/// Serialise a finished request. Samples are included row-by-row only on
/// demand (they dominate the payload for large batches).
pub fn result_to_json(res: &SamplingResult, return_samples: bool) -> Json {
    let mut obj = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::Num(res.id as f64)),
        ("nfe", Json::Num(res.nfe as f64)),
        ("rows", Json::Num(res.samples.rows() as f64)),
        ("dim", Json::Num(res.samples.cols() as f64)),
        ("queue_ms", Json::Num(1e3 * res.queue_seconds)),
        ("total_ms", Json::Num(1e3 * res.total_seconds)),
    ]);
    if return_samples {
        let rows: Vec<Json> = (0..res.samples.rows())
            .map(|r| Json::arr_f32(res.samples.row(r)))
            .collect();
        obj.set("samples", Json::Arr(rows));
    }
    obj
}

/// Parse a response's samples back into a tensor (client side).
pub fn samples_from_json(j: &Json) -> Result<crate::tensor::Tensor, String> {
    let rows = j.get("rows").as_usize().ok_or("rows")?;
    let dim = j.get("dim").as_usize().ok_or("dim")?;
    let arr = j.get("samples").as_arr().ok_or("samples missing")?;
    if arr.len() != rows {
        return Err(format!("expected {rows} rows, got {}", arr.len()));
    }
    let mut data = Vec::with_capacity(rows * dim);
    for row in arr {
        let v = row.as_f32_vec().ok_or("bad row")?;
        if v.len() != dim {
            return Err("row dim mismatch".into());
        }
        data.extend(v);
    }
    Ok(crate::tensor::Tensor::from_vec(data, rows, dim))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_request_with_defaults() {
        let r = parse_request(r#"{"op":"sample","solver":"era-5@15","nfe":20}"#).unwrap();
        match r {
            Request::Sample { spec, return_samples } => {
                assert_eq!(spec.solver, "era-5@15");
                assert_eq!(spec.nfe, 20);
                assert_eq!(spec.dataset, "gmm8");
                assert!(!return_samples);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parses_ping_and_stats() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#), Ok(Request::Ping)));
        assert!(matches!(parse_request(r#"{"op":"stats"}"#), Ok(Request::Stats)));
        assert!(parse_request(r#"{"op":"selfdestruct"}"#).is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"nop":"ping"}"#).is_err());
    }

    #[test]
    fn result_roundtrip_with_samples() {
        let res = SamplingResult {
            id: 5,
            samples: crate::tensor::Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2),
            nfe: 10,
            queue_seconds: 0.001,
            total_seconds: 0.05,
        };
        let j = result_to_json(&res, true);
        let text = j.to_string();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("ok").as_bool(), Some(true));
        assert_eq!(back.get("nfe").as_usize(), Some(10));
        let t = samples_from_json(&back).unwrap();
        assert_eq!(t.as_slice(), res.samples.as_slice());
    }

    #[test]
    fn result_omits_samples_by_default() {
        let res = SamplingResult {
            id: 1,
            samples: crate::tensor::Tensor::zeros(4, 2),
            nfe: 10,
            queue_seconds: 0.0,
            total_seconds: 0.0,
        };
        let j = result_to_json(&res, false);
        assert!(samples_from_json(&j).is_err());
        assert_eq!(j.get("rows").as_usize(), Some(4));
    }
}
