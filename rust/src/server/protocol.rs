//! Wire protocol: JSON-line <-> typed request/response mapping.

use crate::coordinator::{QosClass, RequestSpec, SamplingResult};
use crate::json::{self, Json};
use crate::solvers::TaskSpec;
use crate::tensor::Tensor;

/// Parsed client request.
#[derive(Debug)]
pub enum Request {
    Ping,
    Stats,
    /// Per-shard telemetry breakdown of the serving pool.
    Shards,
    /// Cancel the in-flight request registered under `tag` (see the
    /// `tag` field of `sample`). Any connection may cancel any tag.
    Cancel { tag: u64 },
    /// Full pool telemetry in Prometheus text exposition format
    /// (returned as the `text` field of the JSON response).
    Metrics,
    /// Replay the flight-recorder span events of the request submitted
    /// under `tag` (admission → queue wait → lane → slabs → per-step
    /// ERA diagnostics → finalize/cancel). Works after completion, as
    /// long as the tag route and the shard's ring retain the history.
    Trace { tag: u64 },
    Sample { spec: RequestSpec, return_samples: bool, tag: Option<u64> },
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = json::parse(line).map_err(|e| format!("{e:?}"))?;
    let op = j.get("op").as_str().ok_or("missing op")?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "shards" => Ok(Request::Shards),
        "cancel" => {
            let tag = j.get("tag").as_usize().ok_or("cancel needs a numeric tag")? as u64;
            Ok(Request::Cancel { tag })
        }
        "metrics" => Ok(Request::Metrics),
        "trace" => {
            let tag = j.get("tag").as_usize().ok_or("trace needs a numeric tag")? as u64;
            Ok(Request::Trace { tag })
        }
        "sample" => {
            let d = RequestSpec::default();
            let init = match j.get("init") {
                Json::Null => None,
                rows => Some(tensor_from_rows(rows)?),
            };
            let task = TaskSpec {
                guidance_scale: j.get("guidance_scale").as_f64().unwrap_or(0.0),
                guide_class: j.get("guide_class").as_usize().unwrap_or(0),
                strength: j.get("strength").as_f64().unwrap_or(1.0),
                init,
                churn: j.get("churn").as_f64().unwrap_or(0.0),
            };
            let qos = match j.get("qos") {
                Json::Null => d.qos,
                v => {
                    let s = v.as_str().ok_or("qos must be a string")?;
                    QosClass::parse(s).ok_or_else(|| format!("unknown qos class '{s}'"))?
                }
            };
            let spec = RequestSpec {
                dataset: j.get("dataset").as_str().unwrap_or(&d.dataset).to_string(),
                solver: j.get("solver").as_str().unwrap_or(&d.solver).to_string(),
                nfe: j.get("nfe").as_usize().unwrap_or(d.nfe),
                n_samples: j.get("n_samples").as_usize().unwrap_or(d.n_samples),
                grid: j.get("grid").as_str().unwrap_or(&d.grid).to_string(),
                t_end: j.get("t_end").as_f64().unwrap_or(d.t_end),
                seed: j.get("seed").as_f64().unwrap_or(0.0) as u64,
                deadline_ms: j.get("deadline_ms").as_usize().map(|v| v as u64),
                task,
                qos,
                min_nfe: j.get("min_nfe").as_usize().unwrap_or(d.min_nfe),
                conv_threshold: j.get("conv_threshold").as_f64().unwrap_or(d.conv_threshold),
                degraded: false,
            };
            let return_samples = j.get("return_samples").as_bool().unwrap_or(false);
            let tag = j.get("tag").as_usize().map(|v| v as u64);
            Ok(Request::Sample { spec, return_samples, tag })
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

/// Parse a raw `[[f32,...],...]` row array (the `init` payload of
/// img2img sample requests) into a tensor. Rows must be nonempty and of
/// equal length.
pub fn tensor_from_rows(j: &Json) -> Result<Tensor, String> {
    let arr = j.as_arr().ok_or("init must be an array of rows")?;
    if arr.is_empty() {
        return Err("init has no rows".into());
    }
    let first = arr[0].as_f32_vec().ok_or("init rows must be numeric arrays")?;
    let dim = first.len();
    if dim == 0 {
        return Err("init rows are empty".into());
    }
    let mut data = Vec::with_capacity(arr.len() * dim);
    data.extend(first);
    for row in &arr[1..] {
        let v = row.as_f32_vec().ok_or("init rows must be numeric arrays")?;
        if v.len() != dim {
            return Err("init row dim mismatch".into());
        }
        data.extend(v);
    }
    Ok(Tensor::from_vec(data, arr.len(), dim))
}

/// Serialise a tensor as the raw row array `tensor_from_rows` parses
/// (client-side `init` payloads).
pub fn rows_to_json(t: &Tensor) -> Json {
    Json::Arr((0..t.rows()).map(|r| Json::arr_f32(t.row(r))).collect())
}

/// Serialise a finished request. Samples are included row-by-row only on
/// demand (they dominate the payload for large batches). A `cancelled`
/// response still carries `ok:true` — the partial iterate and the NFE
/// actually consumed are real data. ERA requests additionally report
/// `delta_eps`, the final error-robust error measure (Eq. 15), so
/// clients can observe the error-robust selection working; other
/// solvers omit the field.
pub fn result_to_json(res: &SamplingResult, return_samples: bool) -> Json {
    let mut obj = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::Num(res.id as f64)),
        ("nfe", Json::Num(res.nfe as f64)),
        ("rows", Json::Num(res.samples.rows() as f64)),
        ("dim", Json::Num(res.samples.cols() as f64)),
        ("cancelled", Json::Bool(res.cancelled)),
        ("early_stop", Json::Bool(res.early_stop)),
        ("queue_ms", Json::Num(1e3 * res.queue_seconds)),
        ("total_ms", Json::Num(1e3 * res.total_seconds)),
    ]);
    if let Some(d) = res.delta_eps {
        obj.set("delta_eps", Json::Num(d));
    }
    if return_samples {
        let rows: Vec<Json> = (0..res.samples.rows())
            .map(|r| Json::arr_f32(res.samples.row(r)))
            .collect();
        obj.set("samples", Json::Arr(rows));
    }
    obj
}

/// Parse a response's samples back into a tensor (client side).
pub fn samples_from_json(j: &Json) -> Result<crate::tensor::Tensor, String> {
    let rows = j.get("rows").as_usize().ok_or("rows")?;
    let dim = j.get("dim").as_usize().ok_or("dim")?;
    let arr = j.get("samples").as_arr().ok_or("samples missing")?;
    if arr.len() != rows {
        return Err(format!("expected {rows} rows, got {}", arr.len()));
    }
    let mut data = Vec::with_capacity(rows * dim);
    for row in arr {
        let v = row.as_f32_vec().ok_or("bad row")?;
        if v.len() != dim {
            return Err("row dim mismatch".into());
        }
        data.extend(v);
    }
    Ok(crate::tensor::Tensor::from_vec(data, rows, dim))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_request_with_defaults() {
        let r = parse_request(r#"{"op":"sample","solver":"era-5@15","nfe":20}"#).unwrap();
        match r {
            Request::Sample { spec, return_samples, tag } => {
                assert_eq!(spec.solver, "era-5@15");
                assert_eq!(spec.nfe, 20);
                assert_eq!(spec.dataset, "gmm8");
                assert_eq!(spec.deadline_ms, None);
                assert!(!return_samples);
                assert_eq!(tag, None);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parses_deadline_and_tag() {
        let r = parse_request(
            r#"{"op":"sample","solver":"era","deadline_ms":250,"tag":7}"#,
        )
        .unwrap();
        match r {
            Request::Sample { spec, tag, .. } => {
                assert_eq!(spec.deadline_ms, Some(250));
                assert_eq!(tag, Some(7));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parses_task_fields_with_defaults() {
        // Absent task fields resolve to the plain unconditional task.
        let r = parse_request(r#"{"op":"sample","solver":"era"}"#).unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.task, TaskSpec::default());
                assert_eq!(spec.admission_rows(), spec.n_samples);
            }
            _ => panic!("wrong variant"),
        }
        // Full workload request: guidance + img2img init + churn.
        let r = parse_request(
            r#"{"op":"sample","solver":"era","guidance_scale":2.5,"guide_class":3,
                "strength":0.5,"churn":0.3,"init":[[1.0,2.0],[3.0,4.0]]}"#,
        )
        .unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.task.guidance_scale, 2.5);
                assert_eq!(spec.task.guide_class, 3);
                assert_eq!(spec.task.strength, 0.5);
                assert_eq!(spec.task.churn, 0.3);
                let init = spec.task.init.as_ref().unwrap();
                assert_eq!((init.rows(), init.cols()), (2, 2));
                assert_eq!(init.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
                assert_eq!(spec.admission_rows(), 2 * spec.n_samples);
            }
            _ => panic!("wrong variant"),
        }
        // Malformed init payloads are rejected, not defaulted.
        assert!(parse_request(r#"{"op":"sample","init":[[1.0],[2.0,3.0]]}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","init":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","init":[]}"#).is_err());
    }

    #[test]
    fn parses_qos_fields_with_defaults() {
        // Absent QoS fields resolve to strict / fixed-NFE behavior.
        let r = parse_request(r#"{"op":"sample","solver":"era"}"#).unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.qos, QosClass::Strict);
                assert_eq!(spec.min_nfe, 0);
                assert_eq!(spec.conv_threshold, 0.0);
                assert!(!spec.degraded);
            }
            _ => panic!("wrong variant"),
        }
        let r = parse_request(
            r#"{"op":"sample","solver":"era","qos":"besteffort","min_nfe":6,
                "conv_threshold":0.05}"#,
        )
        .unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.qos, QosClass::BestEffort);
                assert_eq!(spec.min_nfe, 6);
                assert_eq!(spec.conv_threshold, 0.05);
            }
            _ => panic!("wrong variant"),
        }
        // An unknown class is rejected, not silently defaulted.
        assert!(parse_request(r#"{"op":"sample","qos":"turbo"}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","qos":3}"#).is_err());
    }

    #[test]
    fn init_rows_roundtrip() {
        let t = crate::tensor::Tensor::from_vec(vec![1.0, -2.0, 0.5, 4.0, 0.0, 9.0], 3, 2);
        let j = rows_to_json(&t);
        let back = tensor_from_rows(&j).unwrap();
        assert_eq!(back.as_slice(), t.as_slice());
        assert_eq!((back.rows(), back.cols()), (3, 2));
    }

    #[test]
    fn parses_ping_and_stats() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#), Ok(Request::Ping)));
        assert!(matches!(parse_request(r#"{"op":"stats"}"#), Ok(Request::Stats)));
        assert!(parse_request(r#"{"op":"selfdestruct"}"#).is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"nop":"ping"}"#).is_err());
    }

    #[test]
    fn parses_shards_and_cancel() {
        assert!(matches!(parse_request(r#"{"op":"shards"}"#), Ok(Request::Shards)));
        match parse_request(r#"{"op":"cancel","tag":42}"#).unwrap() {
            Request::Cancel { tag } => assert_eq!(tag, 42),
            _ => panic!("wrong variant"),
        }
        // A cancel without a tag is malformed.
        assert!(parse_request(r#"{"op":"cancel"}"#).is_err());
    }

    #[test]
    fn parses_metrics_and_trace() {
        assert!(matches!(parse_request(r#"{"op":"metrics"}"#), Ok(Request::Metrics)));
        match parse_request(r#"{"op":"trace","tag":31}"#).unwrap() {
            Request::Trace { tag } => assert_eq!(tag, 31),
            _ => panic!("wrong variant"),
        }
        // A trace without a tag is malformed.
        assert!(parse_request(r#"{"op":"trace"}"#).is_err());
    }

    #[test]
    fn result_roundtrip_with_samples() {
        let res = SamplingResult {
            id: 5,
            samples: crate::tensor::Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2),
            nfe: 10,
            queue_seconds: 0.001,
            total_seconds: 0.05,
            cancelled: false,
            early_stop: false,
            delta_eps: Some(0.25),
        };
        let j = result_to_json(&res, true);
        let text = j.to_string();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("ok").as_bool(), Some(true));
        assert_eq!(back.get("nfe").as_usize(), Some(10));
        assert_eq!(back.get("cancelled").as_bool(), Some(false));
        // ERA diagnostics ride the frame when present.
        assert_eq!(back.get("delta_eps").as_f64(), Some(0.25));
        assert_eq!(back.get("early_stop").as_bool(), Some(false));
        let t = samples_from_json(&back).unwrap();
        assert_eq!(t.as_slice(), res.samples.as_slice());
    }

    #[test]
    fn result_omits_samples_by_default() {
        let res = SamplingResult {
            id: 1,
            samples: crate::tensor::Tensor::zeros(4, 2),
            nfe: 10,
            queue_seconds: 0.0,
            total_seconds: 0.0,
            cancelled: false,
            early_stop: true,
            delta_eps: None,
        };
        let j = result_to_json(&res, false);
        assert!(samples_from_json(&j).is_err());
        assert_eq!(j.get("rows").as_usize(), Some(4));
        // Non-ERA results omit the diagnostics field entirely.
        assert!(j.get("delta_eps").as_f64().is_none());
        // Convergence-controller retirement marker rides every frame.
        assert_eq!(j.get("early_stop").as_bool(), Some(true));
    }

    #[test]
    fn cancelled_result_marks_flag_and_partial_nfe() {
        let res = SamplingResult {
            id: 9,
            samples: crate::tensor::Tensor::zeros(4, 2),
            nfe: 3,
            queue_seconds: 0.0,
            total_seconds: 0.01,
            cancelled: true,
            early_stop: false,
            delta_eps: None,
        };
        let j = result_to_json(&res, false);
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("cancelled").as_bool(), Some(true));
        assert_eq!(j.get("nfe").as_usize(), Some(3));
    }
}
