//! Wire protocol: JSON-line <-> typed request/response mapping, plus
//! the counted-binary-payload negotiation (DESIGN.md §6): a `sample`
//! request carrying `"encoding":"bin"` gets its reply as a JSON header
//! line followed by `payload_bytes` of raw little-endian f32 — and may
//! itself upload `init` as a counted payload (`init_rows`+`init_bytes`
//! on the request line, raw bytes after it).

use crate::coordinator::{QosClass, RequestSpec, SamplingResult};
use crate::json::{self, Json};
use crate::solvers::TaskSpec;
use crate::tensor::Tensor;

/// Negotiated reply encoding for `sample` requests. Control ops always
/// answer in JSON; only the sample tensor payload is negotiable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Encoding {
    /// Decimal-text rows inside the JSON reply (`"samples":[[...]]`).
    #[default]
    Json,
    /// JSON header line + counted raw little-endian f32 payload —
    /// bitwise-exact, no decimal round-trip.
    Bin,
}

impl Encoding {
    pub fn parse(s: &str) -> Option<Encoding> {
        match s {
            "json" => Some(Encoding::Json),
            "bin" => Some(Encoding::Bin),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Encoding::Json => "json",
            Encoding::Bin => "bin",
        }
    }
}

/// Parsed client request.
#[derive(Debug)]
pub enum Request {
    Ping,
    Stats,
    /// Per-shard telemetry breakdown of the serving pool.
    Shards,
    /// Cancel the in-flight request registered under `tag` (see the
    /// `tag` field of `sample`). Any connection may cancel any tag.
    Cancel { tag: u64 },
    /// Full pool telemetry in Prometheus text exposition format
    /// (returned as the `text` field of the JSON response).
    Metrics,
    /// Replay the flight-recorder span events of the request submitted
    /// under `tag` (admission → queue wait → lane → slabs → per-step
    /// ERA diagnostics → finalize/cancel). Works after completion, as
    /// long as the tag route and the shard's ring retain the history.
    Trace { tag: u64 },
    Sample { spec: RequestSpec, return_samples: bool, tag: Option<u64>, encoding: Encoding },
}

/// The counted payload a request line announces, if any: a `sample` op
/// with a positive `init_bytes`. The framing layer calls this on every
/// decoded line to decide whether to switch into counted mode before
/// the request can be dispatched.
pub fn announced_payload(j: &Json) -> Option<usize> {
    if j.get("op").as_str() != Some("sample") {
        return None;
    }
    j.get("init_bytes").as_usize().filter(|&n| n > 0)
}

/// Parse one request line (no counted payload attached).
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = json::parse(line).map_err(|e| format!("{e:?}"))?;
    request_from_json(&j, None)
}

/// Build a request from an already-parsed header object plus the
/// counted init payload the header announced (if any).
pub fn request_from_json(j: &Json, payload: Option<&[u8]>) -> Result<Request, String> {
    let op = j.get("op").as_str().ok_or("missing op")?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "shards" => Ok(Request::Shards),
        "cancel" => {
            let tag = j.get("tag").as_usize().ok_or("cancel needs a numeric tag")? as u64;
            Ok(Request::Cancel { tag })
        }
        "metrics" => Ok(Request::Metrics),
        "trace" => {
            let tag = j.get("tag").as_usize().ok_or("trace needs a numeric tag")? as u64;
            Ok(Request::Trace { tag })
        }
        "sample" => {
            let d = RequestSpec::default();
            let init = match (payload, j.get("init")) {
                (Some(_), rows) if *rows != Json::Null => {
                    return Err("init and init_bytes are mutually exclusive".into());
                }
                (Some(bytes), _) => {
                    let rows =
                        j.get("init_rows").as_usize().ok_or("init_bytes needs init_rows")?;
                    Some(tensor_from_le_payload(bytes, rows)?)
                }
                (None, _) if announced_payload(j).is_some() => {
                    return Err("init_bytes announced but no payload delivered".into());
                }
                (None, Json::Null) => None,
                (None, rows) => Some(tensor_from_rows(rows)?),
            };
            let task = TaskSpec {
                guidance_scale: j.get("guidance_scale").as_f64().unwrap_or(0.0),
                guide_class: j.get("guide_class").as_usize().unwrap_or(0),
                strength: j.get("strength").as_f64().unwrap_or(1.0),
                init,
                churn: j.get("churn").as_f64().unwrap_or(0.0),
            };
            let qos = match j.get("qos") {
                Json::Null => d.qos,
                v => {
                    let s = v.as_str().ok_or("qos must be a string")?;
                    QosClass::parse(s).ok_or_else(|| format!("unknown qos class '{s}'"))?
                }
            };
            let spec = RequestSpec {
                dataset: j.get("dataset").as_str().unwrap_or(&d.dataset).to_string(),
                solver: j.get("solver").as_str().unwrap_or(&d.solver).to_string(),
                nfe: j.get("nfe").as_usize().unwrap_or(d.nfe),
                n_samples: j.get("n_samples").as_usize().unwrap_or(d.n_samples),
                grid: j.get("grid").as_str().unwrap_or(&d.grid).to_string(),
                t_end: j.get("t_end").as_f64().unwrap_or(d.t_end),
                seed: j.get("seed").as_f64().unwrap_or(0.0) as u64,
                deadline_ms: j.get("deadline_ms").as_usize().map(|v| v as u64),
                task,
                qos,
                min_nfe: j.get("min_nfe").as_usize().unwrap_or(d.min_nfe),
                conv_threshold: j.get("conv_threshold").as_f64().unwrap_or(d.conv_threshold),
                degraded: false,
            };
            let return_samples = j.get("return_samples").as_bool().unwrap_or(false);
            let tag = j.get("tag").as_usize().map(|v| v as u64);
            let encoding = match j.get("encoding") {
                Json::Null => Encoding::Json,
                v => {
                    let s = v.as_str().ok_or("encoding must be a string")?;
                    Encoding::parse(s).ok_or_else(|| format!("unknown encoding '{s}'"))?
                }
            };
            Ok(Request::Sample { spec, return_samples, tag, encoding })
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

/// Parse a raw `[[f32,...],...]` row array (the `init` payload of
/// img2img sample requests) into a tensor. Rows must be nonempty and of
/// equal length.
pub fn tensor_from_rows(j: &Json) -> Result<Tensor, String> {
    let arr = j.as_arr().ok_or("init must be an array of rows")?;
    if arr.is_empty() {
        return Err("init has no rows".into());
    }
    let first = arr[0].as_f32_vec().ok_or("init rows must be numeric arrays")?;
    let dim = first.len();
    if dim == 0 {
        return Err("init rows are empty".into());
    }
    let mut data = Vec::with_capacity(arr.len() * dim);
    data.extend(first);
    for row in &arr[1..] {
        let v = row.as_f32_vec().ok_or("init rows must be numeric arrays")?;
        if v.len() != dim {
            return Err("init row dim mismatch".into());
        }
        data.extend(v);
    }
    Ok(Tensor::from_vec(data, arr.len(), dim))
}

/// Parse a counted little-endian `init` payload (the binary sibling of
/// [`tensor_from_rows`]). The row count comes from the header's
/// `init_rows`; the dim is derived from the byte count.
pub fn tensor_from_le_payload(bytes: &[u8], rows: usize) -> Result<Tensor, String> {
    if rows == 0 {
        return Err("init_rows must be positive".into());
    }
    if bytes.is_empty() {
        return Err("init payload is empty".into());
    }
    if bytes.len() % 4 != 0 {
        return Err(format!("init payload length {} is not a multiple of 4", bytes.len()));
    }
    let vals = bytes.len() / 4;
    if vals % rows != 0 {
        return Err(format!("init payload holds {vals} f32s, not divisible by {rows} rows"));
    }
    Tensor::from_le_bytes(bytes, rows, vals / rows)
}

/// Serialise a tensor as the raw row array `tensor_from_rows` parses
/// (client-side `init` payloads).
pub fn rows_to_json(t: &Tensor) -> Json {
    Json::Arr((0..t.rows()).map(|r| Json::arr_f32(t.row(r))).collect())
}

/// Serialise a finished request. Samples are included row-by-row only on
/// demand (they dominate the payload for large batches). A `cancelled`
/// response still carries `ok:true` — the partial iterate and the NFE
/// actually consumed are real data. ERA requests additionally report
/// `delta_eps`, the final error-robust error measure (Eq. 15), so
/// clients can observe the error-robust selection working; other
/// solvers omit the field.
pub fn result_to_json(res: &SamplingResult, return_samples: bool) -> Json {
    let mut obj = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::Num(res.id as f64)),
        ("nfe", Json::Num(res.nfe as f64)),
        ("rows", Json::Num(res.samples.rows() as f64)),
        ("dim", Json::Num(res.samples.cols() as f64)),
        ("cancelled", Json::Bool(res.cancelled)),
        ("early_stop", Json::Bool(res.early_stop)),
        ("queue_ms", Json::Num(1e3 * res.queue_seconds)),
        ("total_ms", Json::Num(1e3 * res.total_seconds)),
    ]);
    if let Some(d) = res.delta_eps {
        obj.set("delta_eps", Json::Num(d));
    }
    if return_samples {
        let rows: Vec<Json> = (0..res.samples.rows())
            .map(|r| Json::arr_f32(res.samples.row(r)))
            .collect();
        obj.set("samples", Json::Arr(rows));
    }
    obj
}

/// Serialise a finished request straight into `out` — byte-identical to
/// `result_to_json(res, return_samples).to_string()` (golden-pinned)
/// but without the intermediate `Json` tree (one `Json::Arr` node per
/// row) or a fresh output `String` per reply. The session reply path
/// appends into a pooled encode buffer instead.
///
/// The `Json` object serialiser iterates a `BTreeMap`, so fields go out
/// in sorted key order; this writer hard-codes that order.
pub fn write_result_json(res: &SamplingResult, return_samples: bool, out: &mut String) {
    write_result_with(res, return_samples, None, out);
}

/// Serialise the binary-delivery header line (without the trailing
/// `\n`): the same diagnostics as the JSON reply, plus `payload_bytes`
/// announcing the counted raw little-endian f32 payload that follows —
/// and never an inline `samples` array.
pub fn write_result_header(res: &SamplingResult, payload_bytes: usize, out: &mut String) {
    write_result_with(res, false, Some(payload_bytes), out);
}

fn write_result_with(
    res: &SamplingResult,
    return_samples: bool,
    payload_bytes: Option<usize>,
    out: &mut String,
) {
    out.push_str("{\"cancelled\":");
    out.push_str(if res.cancelled { "true" } else { "false" });
    if let Some(d) = res.delta_eps {
        out.push_str(",\"delta_eps\":");
        json::write_f64(d, out);
    }
    out.push_str(",\"dim\":");
    json::write_f64(res.samples.cols() as f64, out);
    out.push_str(",\"early_stop\":");
    out.push_str(if res.early_stop { "true" } else { "false" });
    out.push_str(",\"id\":");
    json::write_f64(res.id as f64, out);
    out.push_str(",\"nfe\":");
    json::write_f64(res.nfe as f64, out);
    out.push_str(",\"ok\":true");
    if let Some(n) = payload_bytes {
        out.push_str(",\"payload_bytes\":");
        json::write_f64(n as f64, out);
    }
    out.push_str(",\"queue_ms\":");
    json::write_f64(1e3 * res.queue_seconds, out);
    out.push_str(",\"rows\":");
    json::write_f64(res.samples.rows() as f64, out);
    if return_samples {
        // Shortest-round-trip f32 text tops out well under 14 chars;
        // one reserve up front keeps the samples loop growth-free.
        out.reserve(res.samples.rows() * (14 * res.samples.cols() + 3) + 16);
        out.push_str(",\"samples\":[");
        for r in 0..res.samples.rows() {
            if r > 0 {
                out.push(',');
            }
            out.push('[');
            for (i, v) in res.samples.row(r).iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json::write_f64(f64::from(*v), out);
            }
            out.push(']');
        }
        out.push(']');
    }
    out.push_str(",\"total_ms\":");
    json::write_f64(1e3 * res.total_seconds, out);
    out.push('}');
}

/// Parse a response's samples back into a tensor (client side).
pub fn samples_from_json(j: &Json) -> Result<crate::tensor::Tensor, String> {
    let rows = j.get("rows").as_usize().ok_or("rows")?;
    let dim = j.get("dim").as_usize().ok_or("dim")?;
    let arr = j.get("samples").as_arr().ok_or("samples missing")?;
    if arr.len() != rows {
        return Err(format!("expected {rows} rows, got {}", arr.len()));
    }
    let mut data = Vec::with_capacity(rows * dim);
    for row in arr {
        let v = row.as_f32_vec().ok_or("bad row")?;
        if v.len() != dim {
            return Err("row dim mismatch".into());
        }
        data.extend(v);
    }
    Ok(crate::tensor::Tensor::from_vec(data, rows, dim))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sample_request_with_defaults() {
        let r = parse_request(r#"{"op":"sample","solver":"era-5@15","nfe":20}"#).unwrap();
        match r {
            Request::Sample { spec, return_samples, tag, encoding } => {
                assert_eq!(spec.solver, "era-5@15");
                assert_eq!(spec.nfe, 20);
                assert_eq!(spec.dataset, "gmm8");
                assert_eq!(spec.deadline_ms, None);
                assert!(!return_samples);
                assert_eq!(tag, None);
                assert_eq!(encoding, Encoding::Json);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parses_encoding_negotiation() {
        let r = parse_request(r#"{"op":"sample","encoding":"bin"}"#).unwrap();
        match r {
            Request::Sample { encoding, .. } => assert_eq!(encoding, Encoding::Bin),
            _ => panic!("wrong variant"),
        }
        let r = parse_request(r#"{"op":"sample","encoding":"json"}"#).unwrap();
        match r {
            Request::Sample { encoding, .. } => assert_eq!(encoding, Encoding::Json),
            _ => panic!("wrong variant"),
        }
        // Unknown encodings are rejected, not silently defaulted.
        assert!(parse_request(r#"{"op":"sample","encoding":"xml"}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","encoding":7}"#).is_err());
    }

    #[test]
    fn announced_payload_reads_sample_init_bytes() {
        let j = json::parse(r#"{"op":"sample","init_rows":2,"init_bytes":16}"#).unwrap();
        assert_eq!(announced_payload(&j), Some(16));
        // Control ops never announce payloads, nor does a zero count.
        let j = json::parse(r#"{"op":"ping","init_bytes":16}"#).unwrap();
        assert_eq!(announced_payload(&j), None);
        let j = json::parse(r#"{"op":"sample","init_bytes":0}"#).unwrap();
        assert_eq!(announced_payload(&j), None);
    }

    #[test]
    fn binary_init_upload_roundtrips_bitwise() {
        let t = crate::tensor::Tensor::from_vec(vec![1.5, -2.25, 0.1, 4.0, 0.0, 9.75], 3, 2);
        let bytes = t.to_le_bytes();
        let j = json::parse(r#"{"op":"sample","init_rows":3,"init_bytes":24}"#).unwrap();
        match request_from_json(&j, Some(&bytes)).unwrap() {
            Request::Sample { spec, .. } => {
                let init = spec.task.init.as_ref().unwrap();
                assert_eq!((init.rows(), init.cols()), (3, 2));
                assert_eq!(init.as_slice(), t.as_slice());
            }
            _ => panic!("wrong variant"),
        }
        // Malformed binary uploads are rejected with specific errors.
        let j = json::parse(r#"{"op":"sample","init_bytes":24}"#).unwrap();
        assert!(request_from_json(&j, Some(&bytes)).unwrap_err().contains("init_rows"));
        let j = json::parse(r#"{"op":"sample","init_rows":5,"init_bytes":24}"#).unwrap();
        assert!(request_from_json(&j, Some(&bytes)).is_err());
        let j = json::parse(r#"{"op":"sample","init_rows":3,"init_bytes":23}"#).unwrap();
        assert!(request_from_json(&j, Some(&bytes[..23])).is_err());
        // Both init forms at once are ambiguous.
        let j = json::parse(
            r#"{"op":"sample","init":[[1.0,2.0]],"init_rows":3,"init_bytes":24}"#,
        )
        .unwrap();
        assert!(request_from_json(&j, Some(&bytes)).unwrap_err().contains("exclusive"));
        // An announce without a delivered payload cannot dispatch.
        let j = json::parse(r#"{"op":"sample","init_rows":3,"init_bytes":24}"#).unwrap();
        assert!(request_from_json(&j, None).is_err());
    }

    #[test]
    fn parses_deadline_and_tag() {
        let r = parse_request(
            r#"{"op":"sample","solver":"era","deadline_ms":250,"tag":7}"#,
        )
        .unwrap();
        match r {
            Request::Sample { spec, tag, .. } => {
                assert_eq!(spec.deadline_ms, Some(250));
                assert_eq!(tag, Some(7));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parses_task_fields_with_defaults() {
        // Absent task fields resolve to the plain unconditional task.
        let r = parse_request(r#"{"op":"sample","solver":"era"}"#).unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.task, TaskSpec::default());
                assert_eq!(spec.admission_rows(), spec.n_samples);
            }
            _ => panic!("wrong variant"),
        }
        // Full workload request: guidance + img2img init + churn.
        let r = parse_request(
            r#"{"op":"sample","solver":"era","guidance_scale":2.5,"guide_class":3,
                "strength":0.5,"churn":0.3,"init":[[1.0,2.0],[3.0,4.0]]}"#,
        )
        .unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.task.guidance_scale, 2.5);
                assert_eq!(spec.task.guide_class, 3);
                assert_eq!(spec.task.strength, 0.5);
                assert_eq!(spec.task.churn, 0.3);
                let init = spec.task.init.as_ref().unwrap();
                assert_eq!((init.rows(), init.cols()), (2, 2));
                assert_eq!(init.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
                assert_eq!(spec.admission_rows(), 2 * spec.n_samples);
            }
            _ => panic!("wrong variant"),
        }
        // Malformed init payloads are rejected, not defaulted.
        assert!(parse_request(r#"{"op":"sample","init":[[1.0],[2.0,3.0]]}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","init":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","init":[]}"#).is_err());
    }

    #[test]
    fn parses_qos_fields_with_defaults() {
        // Absent QoS fields resolve to strict / fixed-NFE behavior.
        let r = parse_request(r#"{"op":"sample","solver":"era"}"#).unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.qos, QosClass::Strict);
                assert_eq!(spec.min_nfe, 0);
                assert_eq!(spec.conv_threshold, 0.0);
                assert!(!spec.degraded);
            }
            _ => panic!("wrong variant"),
        }
        let r = parse_request(
            r#"{"op":"sample","solver":"era","qos":"besteffort","min_nfe":6,
                "conv_threshold":0.05}"#,
        )
        .unwrap();
        match r {
            Request::Sample { spec, .. } => {
                assert_eq!(spec.qos, QosClass::BestEffort);
                assert_eq!(spec.min_nfe, 6);
                assert_eq!(spec.conv_threshold, 0.05);
            }
            _ => panic!("wrong variant"),
        }
        // An unknown class is rejected, not silently defaulted.
        assert!(parse_request(r#"{"op":"sample","qos":"turbo"}"#).is_err());
        assert!(parse_request(r#"{"op":"sample","qos":3}"#).is_err());
    }

    #[test]
    fn init_rows_roundtrip() {
        let t = crate::tensor::Tensor::from_vec(vec![1.0, -2.0, 0.5, 4.0, 0.0, 9.0], 3, 2);
        let j = rows_to_json(&t);
        let back = tensor_from_rows(&j).unwrap();
        assert_eq!(back.as_slice(), t.as_slice());
        assert_eq!((back.rows(), back.cols()), (3, 2));
    }

    #[test]
    fn parses_ping_and_stats() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#), Ok(Request::Ping)));
        assert!(matches!(parse_request(r#"{"op":"stats"}"#), Ok(Request::Stats)));
        assert!(parse_request(r#"{"op":"selfdestruct"}"#).is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"nop":"ping"}"#).is_err());
    }

    #[test]
    fn parses_shards_and_cancel() {
        assert!(matches!(parse_request(r#"{"op":"shards"}"#), Ok(Request::Shards)));
        match parse_request(r#"{"op":"cancel","tag":42}"#).unwrap() {
            Request::Cancel { tag } => assert_eq!(tag, 42),
            _ => panic!("wrong variant"),
        }
        // A cancel without a tag is malformed.
        assert!(parse_request(r#"{"op":"cancel"}"#).is_err());
    }

    #[test]
    fn parses_metrics_and_trace() {
        assert!(matches!(parse_request(r#"{"op":"metrics"}"#), Ok(Request::Metrics)));
        match parse_request(r#"{"op":"trace","tag":31}"#).unwrap() {
            Request::Trace { tag } => assert_eq!(tag, 31),
            _ => panic!("wrong variant"),
        }
        // A trace without a tag is malformed.
        assert!(parse_request(r#"{"op":"trace"}"#).is_err());
    }

    #[test]
    fn result_roundtrip_with_samples() {
        let res = SamplingResult {
            id: 5,
            samples: crate::tensor::Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2),
            nfe: 10,
            queue_seconds: 0.001,
            total_seconds: 0.05,
            cancelled: false,
            early_stop: false,
            delta_eps: Some(0.25),
        };
        let j = result_to_json(&res, true);
        let text = j.to_string();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("ok").as_bool(), Some(true));
        assert_eq!(back.get("nfe").as_usize(), Some(10));
        assert_eq!(back.get("cancelled").as_bool(), Some(false));
        // ERA diagnostics ride the frame when present.
        assert_eq!(back.get("delta_eps").as_f64(), Some(0.25));
        assert_eq!(back.get("early_stop").as_bool(), Some(false));
        let t = samples_from_json(&back).unwrap();
        assert_eq!(t.as_slice(), res.samples.as_slice());
    }

    #[test]
    fn result_omits_samples_by_default() {
        let res = SamplingResult {
            id: 1,
            samples: crate::tensor::Tensor::zeros(4, 2),
            nfe: 10,
            queue_seconds: 0.0,
            total_seconds: 0.0,
            cancelled: false,
            early_stop: true,
            delta_eps: None,
        };
        let j = result_to_json(&res, false);
        assert!(samples_from_json(&j).is_err());
        assert_eq!(j.get("rows").as_usize(), Some(4));
        // Non-ERA results omit the diagnostics field entirely.
        assert!(j.get("delta_eps").as_f64().is_none());
        // Convergence-controller retirement marker rides every frame.
        assert_eq!(j.get("early_stop").as_bool(), Some(true));
    }

    fn golden_result(delta: Option<f64>) -> SamplingResult {
        SamplingResult {
            id: 5,
            samples: crate::tensor::Tensor::from_vec(vec![1.0, 2.5, -3.0, 0.125], 2, 2),
            nfe: 10,
            queue_seconds: 0.0015,
            total_seconds: 0.05,
            cancelled: false,
            early_stop: true,
            delta_eps: delta,
        }
    }

    #[test]
    fn result_writer_matches_json_tree_bytes() {
        // The allocation-free writer must stay byte-identical to the
        // `Json` tree path for every field combination.
        for return_samples in [false, true] {
            for delta in [None, Some(0.25), Some(1e-7)] {
                let res = golden_result(delta);
                let mut fast = String::from("prefix|");
                write_result_json(&res, return_samples, &mut fast);
                let tree = result_to_json(&res, return_samples).to_string();
                assert_eq!(fast, format!("prefix|{tree}"));
            }
        }
    }

    #[test]
    fn result_writer_golden_pin() {
        // Pinned literal: any byte-level drift in the legacy JSON reply
        // is a wire-format break, caught here before it reaches peers.
        let mut out = String::new();
        write_result_json(&golden_result(Some(0.25)), true, &mut out);
        assert_eq!(
            out,
            "{\"cancelled\":false,\"delta_eps\":0.25,\"dim\":2,\"early_stop\":true,\
             \"id\":5,\"nfe\":10,\"ok\":true,\"queue_ms\":1.5,\"rows\":2,\
             \"samples\":[[1,2.5],[-3,0.125]],\"total_ms\":50}"
        );
    }

    #[test]
    fn result_header_announces_payload_and_omits_samples() {
        let res = golden_result(None);
        let mut out = String::new();
        write_result_header(&res, 16, &mut out);
        assert_eq!(
            out,
            "{\"cancelled\":false,\"dim\":2,\"early_stop\":true,\"id\":5,\"nfe\":10,\
             \"ok\":true,\"payload_bytes\":16,\"queue_ms\":1.5,\"rows\":2,\"total_ms\":50}"
        );
        // The header parses as ordinary JSON and carries the shape the
        // client needs to size its payload read.
        let j = json::parse(&out).unwrap();
        assert_eq!(j.get("payload_bytes").as_usize(), Some(16));
        assert_eq!(j.get("rows").as_usize(), Some(2));
        assert_eq!(j.get("dim").as_usize(), Some(2));
        assert!(j.get("samples").as_arr().is_none());
    }

    #[test]
    fn cancelled_result_marks_flag_and_partial_nfe() {
        let res = SamplingResult {
            id: 9,
            samples: crate::tensor::Tensor::zeros(4, 2),
            nfe: 3,
            queue_seconds: 0.0,
            total_seconds: 0.01,
            cancelled: true,
            early_stop: false,
            delta_eps: None,
        };
        let j = result_to_json(&res, false);
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("cancelled").as_bool(), Some(true));
        assert_eq!(j.get("nfe").as_usize(), Some(3));
    }
}
