//! Readiness-based gateway: the same JSON-lines protocol as
//! [`super::Server`], served by a small fixed pool of epoll event
//! loops instead of a thread per connection (DESIGN.md §13).
//!
//! Layering: this module owns sockets and readiness only. Framing
//! lives in [`super::codec`], per-connection protocol state in
//! [`super::session`], and the epoll wrapper in [`super::transport`] —
//! so the gateway is wire-identical to the blocking path by
//! construction and the stock [`super::client::Client`] drives either.
//!
//! Shape: `io_threads` event loops, each with its own [`Epoll`], a
//! cross-thread inbox, and a [`Waker`]. Loop 0 owns the (nonblocking,
//! level-triggered) listener and deals accepted connections round-robin
//! across loops. Connections are edge-triggered (`EPOLLET`): every
//! readable event reads to `WouldBlock`, every write flushes to
//! `WouldBlock`, and `EPOLLOUT` is armed only while unflushed output
//! remains. A completed request fires its [`CompletionNotify`] on the
//! shard's loop thread, which enqueues a `Done` token on the owning
//! event loop's inbox and wakes it — the event loop never blocks on a
//! ticket, and no thread is parked per request.
//!
//! Backpressure (two distinct mechanisms):
//! * per-connection: when a session's bounded write queue fills, its
//!   read interest is parked (`backpressure_stalls` counts the
//!   transitions) until the peer drains replies — a reader that stops
//!   reading stops being read from, with O(write_queue_cap) memory.
//! * admission-aware accept throttling: while the pool's global
//!   in-flight row cap is met, the listener's interest is parked and
//!   new connections queue in the kernel backlog instead of being
//!   accepted and immediately shed with `busy` errors.
//!
//! Connections over `max_connections` are still accepted and politely
//! refused with the same `server overloaded` line the blocking path
//! sends (counted in `rejected_total`).

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::ConnCounters;
use crate::pool::WorkerPool;

use super::codec::MAX_FRAME_LEN;
use super::reject_overloaded;
use super::session::{EncodePool, ReadyFn, Session, SessionConfig};
use super::transport::{
    writev_fd, Epoll, EpollEvent, Waker, EPOLLERR, EPOLLET, EPOLLHUP, EPOLLIN, EPOLLOUT,
    EPOLLRDHUP, MAX_IOVECS,
};

/// Gateway configuration.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind address, e.g. "127.0.0.1:7437" (port 0 picks a free port).
    pub addr: String,
    /// Connections over this cap are accepted and refused with the
    /// `server overloaded` error line (same wire behaviour as the
    /// blocking server's cap).
    pub max_connections: usize,
    /// See [`super::ServerConfig::default_conv_threshold`].
    pub default_conv_threshold: f64,
    /// Event-loop threads. Two saturate a multi-gigabit NIC for this
    /// protocol; the work lives in the pool's shards, not here.
    pub io_threads: usize,
    /// Per-connection cap on one unterminated request line.
    pub max_frame_len: usize,
    /// Per-connection outgoing-queue bound; above it the connection's
    /// read interest is parked (see module docs).
    pub write_queue_cap: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: 1024,
            default_conv_threshold: 0.0,
            io_threads: 2,
            max_frame_len: MAX_FRAME_LEN,
            write_queue_cap: 256 * 1024,
        }
    }
}

/// Epoll token of each loop's waker / loop 0's listener. Connection
/// ids count up from 0 and cannot collide with these in any realistic
/// process lifetime.
const WAKER_TOKEN: u64 = u64::MAX;
const LISTENER_TOKEN: u64 = u64::MAX - 1;

enum LoopMsg {
    /// A freshly accepted connection dealt to this loop.
    Conn { id: u64, stream: TcpStream },
    /// Request `token` on connection `conn` completed; poll its ticket.
    Done { conn: u64, token: u64 },
}

/// Cross-thread mailbox of one event loop.
struct LoopInbox {
    queue: Mutex<VecDeque<LoopMsg>>,
    waker: Waker,
}

impl LoopInbox {
    fn push(&self, msg: LoopMsg) {
        self.queue.lock().unwrap().push_back(msg);
        self.waker.wake();
    }
}

struct Conn {
    /// Epoll token; re-registration (interest changes) must reuse it.
    id: u64,
    stream: TcpStream,
    session: Session,
    /// Interest bits currently registered (modulo `EPOLLET|EPOLLRDHUP`
    /// which are always set).
    interest: u32,
    /// Whether read interest is currently armed (tracked separately so
    /// park/unpark transitions can be counted and resumed correctly).
    reading: bool,
}

/// A running gateway; dropping it stops every event loop.
pub struct Gateway {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    inboxes: Vec<Arc<LoopInbox>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Gateway {
    /// Bind and start `config.io_threads` event loops.
    pub fn start(pool: Arc<WorkerPool>, config: GatewayConfig) -> io::Result<Gateway> {
        let listener = TcpListener::bind(&config.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(ConnCounters::new());
        pool.register_conn_counters(counters.clone());
        // One encode-buffer pool per gateway: reply buffers warm up
        // across connections and loops (DESIGN.md §6).
        let encode_pool = Arc::new(EncodePool::new());

        let io_threads = config.io_threads.max(1);
        let mut inboxes = Vec::with_capacity(io_threads);
        for _ in 0..io_threads {
            inboxes.push(Arc::new(LoopInbox {
                queue: Mutex::new(VecDeque::new()),
                waker: Waker::new()?,
            }));
        }
        let next_id = Arc::new(AtomicU64::new(0));

        let mut listener = Some(listener);
        let mut threads = Vec::with_capacity(io_threads);
        for index in 0..io_threads {
            let state = EventLoop {
                index,
                pool: pool.clone(),
                config: config.clone(),
                stop: stop.clone(),
                inboxes: inboxes.clone(),
                listener: if index == 0 { listener.take() } else { None },
                counters: counters.clone(),
                next_id: next_id.clone(),
                encode_pool: encode_pool.clone(),
            };
            threads.push(
                std::thread::Builder::new()
                    .name(format!("era-gw-{index}"))
                    .spawn(move || state.run())
                    .expect("spawn gateway loop"),
            );
        }

        Ok(Gateway { local_addr, stop, inboxes, threads })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop every event loop and join them. Open connections are
    /// dropped; their in-flight requests are cancelled.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for inbox in &self.inboxes {
            inbox.waker.wake();
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

struct EventLoop {
    index: usize,
    pool: Arc<WorkerPool>,
    config: GatewayConfig,
    stop: Arc<AtomicBool>,
    inboxes: Vec<Arc<LoopInbox>>,
    listener: Option<TcpListener>,
    counters: Arc<ConnCounters>,
    next_id: Arc<AtomicU64>,
    encode_pool: Arc<EncodePool>,
}

impl EventLoop {
    fn run(self) {
        let epoll = match Epoll::new() {
            Ok(e) => e,
            Err(_) => return,
        };
        let inbox = &self.inboxes[self.index];
        if epoll.add(inbox.waker.fd(), EPOLLIN, WAKER_TOKEN).is_err() {
            return;
        }
        // The listener is level-triggered so unaccepted connections
        // keep it signalled, and its interest can be parked outright
        // for admission throttling.
        let mut accept_armed = false;
        if let Some(l) = &self.listener {
            if epoll.add(l.as_raw_fd(), EPOLLIN, LISTENER_TOKEN).is_err() {
                return;
            }
            accept_armed = true;
        }

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut events = [EpollEvent::zeroed(); 256];
        let mut buf = [0u8; 16 * 1024];

        while !self.stop.load(Ordering::Relaxed) {
            // Admission-aware accept throttle, re-evaluated every tick
            // (the wait timeout bounds the re-check latency).
            if let Some(l) = &self.listener {
                let want = self.pool.has_admission_capacity();
                if want != accept_armed {
                    let interest = if want { EPOLLIN } else { 0 };
                    if epoll.modify(l.as_raw_fd(), interest, LISTENER_TOKEN).is_ok() {
                        accept_armed = want;
                    }
                }
            }

            let n = match epoll.wait(&mut events, 100) {
                Ok(n) => n,
                Err(_) => break,
            };
            for ev in events.iter().take(n) {
                // Copy packed fields to locals before use.
                let (bits, token) = (ev.events, ev.data);
                match token {
                    WAKER_TOKEN => inbox.waker.drain(),
                    LISTENER_TOKEN => self.accept_burst(&epoll, &mut conns, &mut buf),
                    id => {
                        let keep = match conns.get_mut(&id) {
                            None => continue, // already closed this tick
                            Some(conn) => {
                                let mut keep = true;
                                if bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0 {
                                    keep = read_pass(conn, &mut buf, &self.counters);
                                }
                                if bits & EPOLLERR != 0 {
                                    keep = false;
                                }
                                keep && pump(&epoll, &self.counters, conn, &mut buf)
                            }
                        };
                        if !keep {
                            if let Some(conn) = conns.remove(&id) {
                                drop_conn(&epoll, &self.counters, conn);
                            }
                        }
                    }
                }
            }

            // Drain the inbox after the events so a Done raced by its
            // connection's teardown is simply ignored.
            loop {
                let msg = inbox.queue.lock().unwrap().pop_front();
                let Some(msg) = msg else { break };
                match msg {
                    LoopMsg::Conn { id, stream } => {
                        self.install(&epoll, &mut conns, id, stream, &mut buf);
                    }
                    LoopMsg::Done { conn: id, token } => {
                        let keep = match conns.get_mut(&id) {
                            None => continue,
                            Some(conn) => {
                                conn.session.on_complete(token);
                                pump(&epoll, &self.counters, conn, &mut buf)
                            }
                        };
                        if !keep {
                            if let Some(conn) = conns.remove(&id) {
                                drop_conn(&epoll, &self.counters, conn);
                            }
                        }
                    }
                }
            }
        }

        for (_, conn) in conns.drain() {
            drop_conn(&epoll, &self.counters, conn);
        }
    }

    /// Accept until `WouldBlock`, dealing connections round-robin
    /// across loops by id.
    fn accept_burst(
        &self,
        epoll: &Epoll,
        conns: &mut HashMap<u64, Conn>,
        buf: &mut [u8],
    ) {
        let Some(listener) = &self.listener else { return };
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if self.counters.open_connections.load(Ordering::Relaxed)
                        >= self.config.max_connections
                    {
                        self.counters.rejected_total.fetch_add(1, Ordering::Relaxed);
                        let _ = reject_overloaded(&stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.counters.accepted_total.fetch_add(1, Ordering::Relaxed);
                    self.counters.open_connections.fetch_add(1, Ordering::Relaxed);
                    let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                    let target = (id % self.inboxes.len() as u64) as usize;
                    if target == self.index {
                        self.install(epoll, conns, id, stream, buf);
                    } else {
                        self.inboxes[target].push(LoopMsg::Conn { id, stream });
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break, // e.g. EMFILE: retry on the next tick
            }
        }
    }

    /// Register a dealt connection with this loop and run its first
    /// read pass (bytes may have landed before registration; with
    /// edge-triggering that edge is already spent).
    fn install(
        &self,
        epoll: &Epoll,
        conns: &mut HashMap<u64, Conn>,
        id: u64,
        stream: TcpStream,
        buf: &mut [u8],
    ) {
        let inbox = self.inboxes[self.index].clone();
        let ready: ReadyFn = Arc::new(move |token| inbox.push(LoopMsg::Done { conn: id, token }));
        let session_cfg = SessionConfig {
            max_frame_len: self.config.max_frame_len,
            write_queue_cap: self.config.write_queue_cap,
            default_conv_threshold: self.config.default_conv_threshold,
        };
        let session = Session::with_encode_pool(
            self.pool.clone(),
            &session_cfg,
            ready,
            self.encode_pool.clone(),
        );
        let interest = EPOLLIN | EPOLLRDHUP | EPOLLET;
        if epoll.add(stream.as_raw_fd(), interest, id).is_err() {
            self.counters.open_connections.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let mut conn = Conn { id, stream, session, interest, reading: true };
        let keep = read_pass(&mut conn, buf, &self.counters)
            && pump(epoll, &self.counters, &mut conn, buf);
        if keep {
            conns.insert(id, conn);
        } else {
            drop_conn(epoll, &self.counters, conn);
        }
    }
}

/// Read to `WouldBlock` (or until backpressure parks the session),
/// feeding the session. Returns false on EOF or a socket error.
fn read_pass(conn: &mut Conn, buf: &mut [u8], counters: &ConnCounters) -> bool {
    while conn.session.wants_read() {
        match (&conn.stream).read(buf) {
            Ok(0) => return false, // peer closed
            Ok(n) => {
                counters.bytes_in.fetch_add(n, Ordering::Relaxed);
                conn.session.on_bytes(&buf[..n]);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

/// Flush to `WouldBlock`, gathering queued segments — reply header,
/// zero-copy tensor payload, pipelined next frames — into a single
/// `writev` per syscall. Returns false on a socket error.
fn flush_pass(conn: &mut Conn, counters: &ConnCounters) -> bool {
    while conn.session.has_output() {
        let wrote = {
            let mut slices: [&[u8]; MAX_IOVECS] = [&[]; MAX_IOVECS];
            let n = conn.session.out_vectored(&mut slices);
            if n == 1 {
                (&conn.stream).write(slices[0])
            } else {
                writev_fd(conn.stream.as_raw_fd(), &slices[..n])
            }
        };
        match wrote {
            Ok(0) => return false,
            Ok(n) => {
                counters.bytes_out.fetch_add(n, Ordering::Relaxed);
                conn.session.consume_out(n);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

/// Settle a connection after any activity: flush, re-arm interest, and
/// resume reading when backpressure clears (the spent read edge is
/// re-run by hand). Returns false when the connection should close.
fn pump(epoll: &Epoll, counters: &ConnCounters, conn: &mut Conn, buf: &mut [u8]) -> bool {
    loop {
        if !flush_pass(conn, counters) {
            return false;
        }
        let wants_read = conn.session.wants_read();
        if wants_read && !conn.reading {
            // Backpressure cleared: interest was parked, so the kernel
            // buffer may hold bytes no future edge will announce.
            conn.reading = true;
            if !read_pass(conn, buf, counters) {
                return false;
            }
            continue; // the read may have enqueued more output
        }
        if !wants_read && conn.reading {
            conn.reading = false;
            counters.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
        }
        break;
    }
    if conn.session.should_close() {
        return false;
    }
    let mut want = EPOLLRDHUP | EPOLLET;
    if conn.reading {
        want |= EPOLLIN;
    }
    if conn.session.has_output() {
        want |= EPOLLOUT;
    }
    if want != conn.interest {
        if epoll.modify(conn.stream.as_raw_fd(), want, conn.id).is_err() {
            return false;
        }
        conn.interest = want;
    }
    true
}

fn drop_conn(epoll: &Epoll, counters: &ConnCounters, mut conn: Conn) {
    let _ = epoll.delete(conn.stream.as_raw_fd());
    conn.session.abort();
    counters.open_connections.fetch_sub(1, Ordering::Relaxed);
}
