//! TCP JSON-lines serving front end over the sharded worker pool.
//!
//! Wire protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"op":"sample","dataset":"gmm8","solver":"era","nfe":10,
//!     "n_samples":64,"grid":"logsnr","t_end":0.001,"seed":7,
//!     "return_samples":true,"deadline_ms":500,"tag":42}
//! <- {"ok":true,"id":3,"nfe":10,"rows":64,"dim":2,"cancelled":false,
//!     "queue_ms":0.1,"total_ms":41.0,"delta_eps":0.21,
//!     "samples":[[..],[..],...]}
//!     (`delta_eps` — the final error-robust error measure — appears
//!     for ERA solvers only)
//!
//! -> {"op":"cancel","tag":42}
//! <- {"ok":true,"cancelled":true}
//!
//! -> {"op":"stats"}
//! <- {"ok":true,"shards":4,"executors_per_shard":2,"pipeline_depth":2,
//!     "finished":12,"evals":180,"executor_busy_frac":0.83,
//!     "inflight_slabs":3,"depth_hist":[40,12,0,...],
//!     "lanes":2,"lane_occ_hist":[5,1,0,...],"mean_delta_eps":0.2,...}
//!
//! -> {"op":"shards"}
//! <- {"ok":true,"shards":4,"placement":"least-loaded",
//!     "per_shard":[{"shard":0,"admitted":3,"inflight_slabs":1,
//!                   "executor_busy_frac":0.8,"depth_hist":[...],...},...]}
//!
//! -> {"op":"ping"}            <- {"ok":true,"pong":true}
//!
//! -> {"op":"metrics"}
//! <- {"ok":true,"text":"# HELP era_requests_admitted_total ...\n..."}
//!     (`text` is a full Prometheus text-exposition page: counters,
//!     gauges, depth/lane-occupancy histograms, and per-stage latency
//!     histograms — DESIGN.md §11)
//!
//! -> {"op":"trace","tag":42}
//! <- {"ok":true,"tag":42,"shard":1,"trace":3,
//!     "events":[{"kind":"admitted","at_ns":120,"rows":64},...]}
//!     (the owning shard's flight recorder dumped as typed span-event
//!     JSON; the tag must have been registered via a tagged `sample`)
//! ```
//!
//! `deadline_ms` bounds one request's wall time; the owning shard
//! retires it mid-trajectory when it expires. `tag` registers the
//! request in the pool's cancellation registry so *any* connection can
//! cancel it — the blocked submitter then receives its partial,
//! `cancelled:true` result.
//!
//! `sample` also accepts workload fields (DESIGN.md §8):
//! `guidance_scale` + `guide_class` (classifier-free guidance; the
//! request is admission-charged as paired rows and `nfe` in the reply
//! counts both halves), `strength` + `init` (img2img partial
//! trajectory over a suffix of the shared plan; `init` is a raw
//! `[[f32,...],...]` row array of shape `n_samples x dim`), and
//! `churn` (stochastic ERA). All default to the plain unconditional
//! trajectory.
//!
//! **Binary encoding (counted payloads).** A `sample` request may set
//! `"encoding":"bin"`: with `return_samples`, the reply becomes a JSON
//! header line — the usual diagnostics plus `payload_bytes`, and no
//! inline `samples` — followed by exactly `payload_bytes` of raw
//! little-endian f32s (row-major `rows x dim`), bitwise-identical to
//! the computed iterate. Symmetrically, an img2img init batch may be
//! uploaded as `init_rows` + `init_bytes` (mutually exclusive with the
//! JSON `init` rows) followed by `init_bytes` of raw little-endian
//! f32s. Counted payloads are consumed by byte count and may contain
//! newlines; every other frame — control ops, errors, JSON replies —
//! stays a plain JSON line, and the encoding is negotiated per request
//! so one connection may pipeline both (DESIGN.md §6).
//!
//! QoS fields (DESIGN.md §12): `qos` (`"strict"` default, `"balanced"`,
//! `"besteffort"`), `min_nfe` (early-stop floor; 0 = the solver's
//! structural minimum), and `conv_threshold` (relative `delta_eps`
//! change per scored step below which the convergence controller
//! retires the request early; 0 = fixed NFE). `strict` requests always
//! run their full budget bitwise-reproducibly; non-strict requests with
//! `conv_threshold` 0 inherit the server's `--conv-threshold` default.
//! The reply's `early_stop` flag marks convergence-controller
//! retirement (`nfe` then reports the evals actually consumed).
//!
//! Two front ends share one protocol implementation, no async runtime
//! (the offline registry closure carries no tokio):
//!
//! * [`Server`] — the classic thread-per-connection path: a blocking
//!   acceptor, one handler thread per connection, handlers block on
//!   their request's ticket. Simple and portable; its per-connection
//!   thread cost caps it at tens of connections.
//! * [`gateway::Gateway`] (Linux) — the readiness-based path: a small
//!   fixed pool of epoll event loops multiplexes thousands of
//!   connections with no blocking reads, bounded per-connection write
//!   queues that park read interest when full, and admission-aware
//!   accept throttling (DESIGN.md §13).
//!
//! The layering keeps exactly one protocol on the wire: [`codec`]
//! frames bytes into JSON lines and counted binary payloads,
//! [`protocol`] parses headers (and serialises replies through
//! pre-sized writers — no intermediate `Json` tree on the reply hot
//! path), [`dispatch_parsed`] routes ops to the [`WorkerPool`] (the
//! blocking [`dispatch`] wraps it), and [`session`] is the
//! per-connection state machine the gateway's [`transport`] layer
//! drives with vectored (`writev`) flushes. Both paths answer
//! byte-identically, so the stock [`client::Client`] cannot tell them
//! apart — including cross-connection `cancel`/`trace` tag routing.

pub mod client;
pub mod codec;
#[cfg(target_os = "linux")]
pub mod gateway;
pub mod protocol;
pub mod session;
#[cfg(target_os = "linux")]
pub mod transport;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::coordinator::{
    CancelHandle, CompletionNotify, ConnCounters, QosClass, SamplingResult, SubmitError,
};
use crate::json::{self, Json};
use crate::pool::{PoolTicket, WorkerPool};
use codec::{CodecError, MAX_FRAME_LEN};
use protocol::{
    announced_payload, request_from_json, result_to_json, write_result_header,
    write_result_json, Encoding, Request,
};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7437" (port 0 picks a free port).
    pub addr: String,
    /// Cap on simultaneously served connections.
    pub max_connections: usize,
    /// Convergence threshold applied to non-strict requests that did
    /// not set their own `conv_threshold` (0 disables the default:
    /// such requests run fixed-NFE).
    pub default_conv_threshold: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 64, default_conv_threshold: 0.0 }
    }
}

/// A running server; dropping it stops the acceptor.
pub struct Server {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on a background acceptor thread.
    pub fn start(pool: Arc<WorkerPool>, config: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&config.addr)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let counters = Arc::new(ConnCounters::new());
        pool.register_conn_counters(counters.clone());

        let acceptor = std::thread::Builder::new()
            .name("era-acceptor".into())
            .spawn(move || {
                // The accept is blocking (no poll/sleep spin; shutdown
                // wakes it with a dummy connect). Finished handlers
                // report their id on `done_rx` and are joined on the
                // next accept, so the handler map cannot grow past the
                // connection cap plus the not-yet-reaped stragglers.
                let (done_tx, done_rx) = mpsc::channel::<u64>();
                let mut handlers: HashMap<u64, std::thread::JoinHandle<()>> = HashMap::new();
                let mut next_conn: u64 = 0;
                loop {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if stop2.load(Ordering::Relaxed) {
                                break; // the shutdown wake-up connect
                            }
                            while let Ok(id) = done_rx.try_recv() {
                                if let Some(h) = handlers.remove(&id) {
                                    let _ = h.join();
                                }
                            }
                            if counters.open_connections.load(Ordering::Relaxed)
                                >= config.max_connections
                            {
                                counters.rejected_total.fetch_add(1, Ordering::Relaxed);
                                let _ = reject_overloaded(&stream);
                                continue;
                            }
                            counters.accepted_total.fetch_add(1, Ordering::Relaxed);
                            counters.open_connections.fetch_add(1, Ordering::Relaxed);
                            let id = next_conn;
                            next_conn += 1;
                            let pool = pool.clone();
                            let counters2 = counters.clone();
                            let stop3 = stop2.clone();
                            let done = done_tx.clone();
                            let conv_threshold = config.default_conv_threshold;
                            let handle = std::thread::Builder::new()
                                .name("era-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(
                                        stream,
                                        &pool,
                                        &stop3,
                                        conv_threshold,
                                        &counters2,
                                    );
                                    counters2.open_connections.fetch_sub(1, Ordering::Relaxed);
                                    let _ = done.send(id);
                                })
                                .expect("spawn handler");
                            handlers.insert(id, handle);
                        }
                        Err(_) => break,
                    }
                }
                for (_, h) in handlers {
                    let _ = h.join();
                }
            })
            .expect("spawn acceptor");

        Ok(Server { local_addr, stop, acceptor: Some(acceptor) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting and join the acceptor (open connections finish
    /// their in-flight line and exit on the next read).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept; the acceptor sees the stop flag
        // before spawning a handler for this dummy connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn reject_overloaded(mut stream: &TcpStream) -> std::io::Result<()> {
    let msg = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("server overloaded".into())),
    ]);
    writeln!(stream, "{}", msg.to_string())
}

fn handle_connection(
    stream: TcpStream,
    pool: &WorkerPool,
    stop: &AtomicBool,
    default_conv_threshold: f64,
    counters: &ConnCounters,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    // Bounded reads so an idle connection cannot pin the acceptor's join
    // at shutdown: on timeout we re-check the stop flag and keep reading.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // Reused across requests: reply serialisation and payload staging.
    let mut reply_buf = String::new();
    let mut payload_buf = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(n) => {
                counters.bytes_in.fetch_add(n, Ordering::Relaxed);
                if line.trim().is_empty() {
                    continue;
                }
                let header = match json::parse(&line) {
                    Ok(j) => j,
                    Err(e) => {
                        let reply = err_json(&format!("bad request: {e:?}"));
                        write_reply_json(&mut writer, &reply, &mut reply_buf, counters)?;
                        continue;
                    }
                };
                let payload = match announced_payload(&header) {
                    None => None,
                    Some(n) if n > MAX_FRAME_LEN => {
                        // The stream cannot be resynchronised past an
                        // unread payload: reply once and close.
                        let e = CodecError::Oversized { len: n, cap: MAX_FRAME_LEN };
                        let reply = err_json(&format!("bad request: {e}"));
                        write_reply_json(&mut writer, &reply, &mut reply_buf, counters)?;
                        break;
                    }
                    Some(n) => {
                        payload_buf.resize(n, 0);
                        read_exact_tolerant(&mut reader, &mut payload_buf, stop)?;
                        counters.bytes_in.fetch_add(n, Ordering::Relaxed);
                        Some(&payload_buf[..])
                    }
                };
                match dispatch_parsed(&header, payload, pool, default_conv_threshold, None) {
                    Dispatched::Immediate(reply) => {
                        write_reply_json(&mut writer, &reply, &mut reply_buf, counters)?;
                    }
                    Dispatched::Pending { ticket, return_samples, tag, handle, encoding } => {
                        let out = ticket.wait();
                        // Identity-checked: a tag re-used by a newer
                        // request in the meantime is not evicted.
                        if let Some(tag) = tag {
                            pool.deregister_tag(tag, &handle);
                        }
                        match out {
                            Err(e) => write_reply_json(
                                &mut writer,
                                &err_json(&e),
                                &mut reply_buf,
                                counters,
                            )?,
                            Ok(res) => {
                                reply_buf.clear();
                                let mut written = 0;
                                if encoding == Encoding::Bin && return_samples {
                                    let payload_bytes = res.samples.len() * 4;
                                    write_result_header(&res, payload_bytes, &mut reply_buf);
                                    reply_buf.push('\n');
                                    writer.write_all(reply_buf.as_bytes())?;
                                    #[cfg(target_endian = "little")]
                                    writer.write_all(res.samples.as_le_bytes())?;
                                    #[cfg(not(target_endian = "little"))]
                                    writer.write_all(&res.samples.to_le_bytes())?;
                                    written += reply_buf.len() + payload_bytes;
                                } else {
                                    write_result_json(&res, return_samples, &mut reply_buf);
                                    reply_buf.push('\n');
                                    writer.write_all(reply_buf.as_bytes())?;
                                    written += reply_buf.len();
                                }
                                writer.flush()?;
                                counters.bytes_out.fetch_add(written, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn write_reply_json(
    writer: &mut TcpStream,
    reply: &Json,
    buf: &mut String,
    counters: &ConnCounters,
) -> std::io::Result<()> {
    buf.clear();
    reply.write_to(buf);
    buf.push('\n');
    writer.write_all(buf.as_bytes())?;
    writer.flush()?;
    counters.bytes_out.fetch_add(buf.len(), Ordering::Relaxed);
    Ok(())
}

/// `read_exact` tolerant of the connection's 200 ms read timeout: on
/// timeout the stop flag is re-checked and the read resumes, so a slow
/// payload upload does not error out mid-transfer. A peer closing
/// mid-payload is `UnexpectedEof`.
fn read_exact_tolerant<R: std::io::Read>(
    reader: &mut R,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> std::io::Result<()> {
    let mut filled = 0;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed mid-payload",
                ));
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::Interrupted,
                        "server stopping",
                    ));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Outcome of dispatching one protocol line without blocking.
pub(crate) enum Dispatched {
    /// The reply is ready now (control ops, parse and submit errors).
    Immediate(Json),
    /// A sample was admitted; the reply arrives through the ticket
    /// (its submit-time [`CompletionNotify`] fires when it lands) and
    /// must be rendered in the negotiated encoding.
    Pending {
        ticket: PoolTicket,
        return_samples: bool,
        tag: Option<u64>,
        handle: CancelHandle,
        encoding: Encoding,
    },
}

/// Render a finished sample's reply (shared by both server paths).
pub(crate) fn sample_reply(out: Result<SamplingResult, String>, return_samples: bool) -> Json {
    match out {
        Err(e) => err_json(&e),
        Ok(res) => result_to_json(&res, return_samples),
    }
}

/// Handle one protocol line. Split out for direct unit testing. JSON
/// replies only — encoding negotiation lives in the connection
/// handlers, which see [`dispatch_parsed`] directly.
/// `default_conv_threshold` is the server-level convergence default
/// inherited by non-strict requests that did not set their own.
pub fn dispatch(line: &str, pool: &WorkerPool, default_conv_threshold: f64) -> Json {
    match dispatch_async(line, pool, default_conv_threshold, None) {
        Dispatched::Immediate(json) => json,
        Dispatched::Pending { ticket, return_samples, tag, handle, .. } => {
            let out = ticket.wait();
            // Identity-checked: a tag re-used by a newer request
            // in the meantime is not evicted.
            if let Some(tag) = tag {
                pool.deregister_tag(tag, &handle);
            }
            sample_reply(out, return_samples)
        }
    }
}

/// The non-blocking line-level core of [`dispatch`]: parses the line,
/// then routes through [`dispatch_parsed`] (no counted payload).
pub(crate) fn dispatch_async(
    line: &str,
    pool: &WorkerPool,
    default_conv_threshold: f64,
    notify: Option<CompletionNotify>,
) -> Dispatched {
    match json::parse(line) {
        Err(e) => Dispatched::Immediate(err_json(&format!("bad request: {e:?}"))),
        Ok(j) => dispatch_parsed(&j, None, pool, default_conv_threshold, notify),
    }
}

/// Route one parsed request header (plus its counted init payload, if
/// the header announced one): control ops answer immediately; an
/// admitted `sample` comes back as [`Dispatched::Pending`] with
/// `notify` armed to fire once its result lands in the ticket (the
/// event-loop path polls, never parks).
pub(crate) fn dispatch_parsed(
    header: &Json,
    payload: Option<&[u8]>,
    pool: &WorkerPool,
    default_conv_threshold: f64,
    notify: Option<CompletionNotify>,
) -> Dispatched {
    let reply = match request_from_json(header, payload) {
        Err(e) => err_json(&format!("bad request: {e}")),
        Ok(Request::Ping) => {
            Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])
        }
        Ok(Request::Stats) => pool.stats().to_json(),
        Ok(Request::Shards) => {
            let stats = pool.stats();
            let per_shard: Vec<Json> = stats.per_shard.iter().map(|s| s.to_json()).collect();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("shards", Json::Num(stats.shards() as f64)),
                ("placement", Json::Str(stats.placement.to_string())),
                ("connections", stats.conn.to_json()),
                ("per_shard", Json::Arr(per_shard)),
            ])
        }
        Ok(Request::Metrics) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("text", Json::Str(pool.stats().prometheus())),
        ]),
        Ok(Request::Trace { tag }) => match pool.trace_events(tag) {
            None => err_json(&format!("unknown trace tag {tag}")),
            Some((shard, trace, events)) => {
                let events: Vec<Json> =
                    events.iter().map(crate::obs::trace::event_to_json).collect();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("tag", Json::Num(tag as f64)),
                    ("shard", Json::Num(shard as f64)),
                    ("trace", Json::Num(trace as f64)),
                    ("events", Json::Arr(events)),
                ])
            }
        },
        Ok(Request::Cancel { tag }) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("cancelled", Json::Bool(pool.cancel_tag(tag))),
        ]),
        Ok(Request::Sample { mut spec, return_samples, tag, encoding }) => {
            if spec.conv_threshold == 0.0
                && spec.qos != QosClass::Strict
                && default_conv_threshold > 0.0
            {
                spec.conv_threshold = default_conv_threshold;
            }
            match pool.submit_tagged_notify(spec, tag, notify) {
                Err(SubmitError::QueueFull) => err_json("busy: queue full"),
                Err(SubmitError::Shutdown) => err_json("shutting down"),
                Err(SubmitError::Invalid(e)) => err_json(&format!("invalid: {e}")),
                Ok(ticket) => {
                    let handle = ticket.cancel_handle();
                    return Dispatched::Pending { ticket, return_samples, tag, handle, encoding };
                }
            }
        }
    };
    Dispatched::Immediate(reply)
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}
