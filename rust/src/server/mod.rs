//! TCP JSON-lines serving front end over the sharded worker pool.
//!
//! Wire protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"op":"sample","dataset":"gmm8","solver":"era","nfe":10,
//!     "n_samples":64,"grid":"logsnr","t_end":0.001,"seed":7,
//!     "return_samples":true,"deadline_ms":500,"tag":42}
//! <- {"ok":true,"id":3,"nfe":10,"rows":64,"dim":2,"cancelled":false,
//!     "queue_ms":0.1,"total_ms":41.0,"delta_eps":0.21,
//!     "samples":[[..],[..],...]}
//!     (`delta_eps` — the final error-robust error measure — appears
//!     for ERA solvers only)
//!
//! -> {"op":"cancel","tag":42}
//! <- {"ok":true,"cancelled":true}
//!
//! -> {"op":"stats"}
//! <- {"ok":true,"shards":4,"executors_per_shard":2,"pipeline_depth":2,
//!     "finished":12,"evals":180,"executor_busy_frac":0.83,
//!     "inflight_slabs":3,"depth_hist":[40,12,0,...],
//!     "lanes":2,"lane_occ_hist":[5,1,0,...],"mean_delta_eps":0.2,...}
//!
//! -> {"op":"shards"}
//! <- {"ok":true,"shards":4,"placement":"least-loaded",
//!     "per_shard":[{"shard":0,"admitted":3,"inflight_slabs":1,
//!                   "executor_busy_frac":0.8,"depth_hist":[...],...},...]}
//!
//! -> {"op":"ping"}            <- {"ok":true,"pong":true}
//!
//! -> {"op":"metrics"}
//! <- {"ok":true,"text":"# HELP era_requests_admitted_total ...\n..."}
//!     (`text` is a full Prometheus text-exposition page: counters,
//!     gauges, depth/lane-occupancy histograms, and per-stage latency
//!     histograms — DESIGN.md §11)
//!
//! -> {"op":"trace","tag":42}
//! <- {"ok":true,"tag":42,"shard":1,"trace":3,
//!     "events":[{"kind":"admitted","at_ns":120,"rows":64},...]}
//!     (the owning shard's flight recorder dumped as typed span-event
//!     JSON; the tag must have been registered via a tagged `sample`)
//! ```
//!
//! `deadline_ms` bounds one request's wall time; the owning shard
//! retires it mid-trajectory when it expires. `tag` registers the
//! request in the pool's cancellation registry so *any* connection can
//! cancel it — the blocked submitter then receives its partial,
//! `cancelled:true` result.
//!
//! `sample` also accepts workload fields (DESIGN.md §8):
//! `guidance_scale` + `guide_class` (classifier-free guidance; the
//! request is admission-charged as paired rows and `nfe` in the reply
//! counts both halves), `strength` + `init` (img2img partial
//! trajectory over a suffix of the shared plan; `init` is a raw
//! `[[f32,...],...]` row array of shape `n_samples x dim`), and
//! `churn` (stochastic ERA). All default to the plain unconditional
//! trajectory.
//!
//! QoS fields (DESIGN.md §12): `qos` (`"strict"` default, `"balanced"`,
//! `"besteffort"`), `min_nfe` (early-stop floor; 0 = the solver's
//! structural minimum), and `conv_threshold` (relative `delta_eps`
//! change per scored step below which the convergence controller
//! retires the request early; 0 = fixed NFE). `strict` requests always
//! run their full budget bitwise-reproducibly; non-strict requests with
//! `conv_threshold` 0 inherit the server's `--conv-threshold` default.
//! The reply's `early_stop` flag marks convergence-controller
//! retirement (`nfe` then reports the evals actually consumed).
//!
//! Threads + channels, no async runtime (the offline registry closure
//! carries no tokio): one acceptor, one handler thread per connection,
//! all sharing the [`WorkerPool`] handle. Handler threads block on
//! their request's ticket, so slow requests never head-of-line-block
//! other connections; the pool's global admission control and the
//! per-shard queues are the shared backpressure points.

pub mod client;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::{QosClass, SubmitError};
use crate::json::Json;
use crate::pool::WorkerPool;
use protocol::{parse_request, result_to_json, Request};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7437" (port 0 picks a free port).
    pub addr: String,
    /// Cap on simultaneously served connections.
    pub max_connections: usize,
    /// Convergence threshold applied to non-strict requests that did
    /// not set their own `conv_threshold` (0 disables the default:
    /// such requests run fixed-NFE).
    pub default_conv_threshold: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: 64, default_conv_threshold: 0.0 }
    }
}

/// A running server; dropping it stops the acceptor.
pub struct Server {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on a background acceptor thread.
    pub fn start(pool: Arc<WorkerPool>, config: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&config.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let live = Arc::new(AtomicUsize::new(0));

        let acceptor = std::thread::Builder::new()
            .name("era-acceptor".into())
            .spawn(move || {
                let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if live.load(Ordering::Relaxed) >= config.max_connections {
                                let _ = reject_overloaded(&stream);
                                continue;
                            }
                            live.fetch_add(1, Ordering::Relaxed);
                            let pool = pool.clone();
                            let live2 = live.clone();
                            let stop3 = stop2.clone();
                            let conv_threshold = config.default_conv_threshold;
                            handlers.push(
                                std::thread::Builder::new()
                                    .name("era-conn".into())
                                    .spawn(move || {
                                        let _ = handle_connection(
                                            stream,
                                            &pool,
                                            &stop3,
                                            conv_threshold,
                                        );
                                        live2.fetch_sub(1, Ordering::Relaxed);
                                    })
                                    .expect("spawn handler"),
                            );
                            handlers.retain(|h| !h.is_finished());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for h in handlers {
                    let _ = h.join();
                }
            })
            .expect("spawn acceptor");

        Ok(Server { local_addr, stop, acceptor: Some(acceptor) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting and join the acceptor (open connections finish
    /// their in-flight line and exit on the next read).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

fn reject_overloaded(mut stream: &TcpStream) -> std::io::Result<()> {
    let msg = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("server overloaded".into())),
    ]);
    writeln!(stream, "{}", msg.to_string())
}

fn handle_connection(
    stream: TcpStream,
    pool: &WorkerPool,
    stop: &AtomicBool,
    default_conv_threshold: f64,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    // Bounded reads so an idle connection cannot pin the acceptor's join
    // at shutdown: on timeout we re-check the stop flag and keep reading.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    while !stop.load(Ordering::Relaxed) {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                if line.trim().is_empty() {
                    continue;
                }
                let response = dispatch(&line, pool, default_conv_threshold);
                writeln!(writer, "{}", response.to_string())?;
                writer.flush()?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Handle one protocol line. Split out for direct unit testing.
/// `default_conv_threshold` is the server-level convergence default
/// inherited by non-strict requests that did not set their own.
pub fn dispatch(line: &str, pool: &WorkerPool, default_conv_threshold: f64) -> Json {
    match parse_request(line) {
        Err(e) => err_json(&format!("bad request: {e}")),
        Ok(Request::Ping) => {
            Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])
        }
        Ok(Request::Stats) => pool.stats().to_json(),
        Ok(Request::Shards) => {
            let stats = pool.stats();
            let per_shard: Vec<Json> = stats.per_shard.iter().map(|s| s.to_json()).collect();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("shards", Json::Num(stats.shards() as f64)),
                ("placement", Json::Str(stats.placement.to_string())),
                ("per_shard", Json::Arr(per_shard)),
            ])
        }
        Ok(Request::Metrics) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("text", Json::Str(pool.stats().prometheus())),
        ]),
        Ok(Request::Trace { tag }) => match pool.trace_events(tag) {
            None => err_json(&format!("unknown trace tag {tag}")),
            Some((shard, trace, events)) => {
                let events: Vec<Json> =
                    events.iter().map(crate::obs::trace::event_to_json).collect();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("tag", Json::Num(tag as f64)),
                    ("shard", Json::Num(shard as f64)),
                    ("trace", Json::Num(trace as f64)),
                    ("events", Json::Arr(events)),
                ])
            }
        },
        Ok(Request::Cancel { tag }) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("cancelled", Json::Bool(pool.cancel_tag(tag))),
        ]),
        Ok(Request::Sample { mut spec, return_samples, tag }) => {
            if spec.conv_threshold == 0.0
                && spec.qos != QosClass::Strict
                && default_conv_threshold > 0.0
            {
                spec.conv_threshold = default_conv_threshold;
            }
            match pool.submit_tagged(spec, tag) {
                Err(SubmitError::QueueFull) => err_json("busy: queue full"),
                Err(SubmitError::Shutdown) => err_json("shutting down"),
                Err(SubmitError::Invalid(e)) => err_json(&format!("invalid: {e}")),
                Ok(ticket) => {
                    let handle = ticket.cancel_handle();
                    let out = ticket.wait();
                    // Identity-checked: a tag re-used by a newer request
                    // in the meantime is not evicted.
                    if let Some(tag) = tag {
                        pool.deregister_tag(tag, &handle);
                    }
                    match out {
                        Err(e) => err_json(&e),
                        Ok(res) => result_to_json(&res, return_samples),
                    }
                }
            }
        }
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}
