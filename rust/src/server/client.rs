//! Blocking TCP client + a multi-threaded load generator for the
//! serving benches (Tab. 7).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{QosClass, RequestSpec};
use crate::json::{self, Json};
use crate::server::protocol::{samples_from_json, Encoding};
use crate::tensor::Tensor;

/// One client connection (one JSON line per call, blocking).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    encoding: Encoding,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader, encoding: Encoding::Json })
    }

    /// Wire encoding for subsequent `sample` calls. [`Encoding::Bin`]
    /// negotiates counted binary frames both ways — init uploads go as
    /// raw little-endian f32 payloads and sample replies come back as a
    /// JSON header line plus a counted payload. Control ops stay JSON.
    pub fn set_encoding(&mut self, encoding: Encoding) {
        self.encoding = encoding;
    }

    fn call(&mut self, req: &Json) -> Result<Json, String> {
        writeln!(self.stream, "{}", req.to_string()).map_err(|e| e.to_string())?;
        self.stream.flush().map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        let j = json::parse(&line).map_err(|e| format!("{e:?}"))?;
        if j.get("ok").as_bool() != Some(true) {
            return Err(j.get("error").as_str().unwrap_or("unknown error").to_string());
        }
        Ok(j)
    }

    pub fn ping(&mut self) -> Result<(), String> {
        self.call(&Json::obj(vec![("op", Json::Str("ping".into()))])).map(|_| ())
    }

    pub fn stats(&mut self) -> Result<Json, String> {
        self.call(&Json::obj(vec![("op", Json::Str("stats".into()))]))
    }

    /// Per-shard telemetry breakdown of the serving pool.
    pub fn shards(&mut self) -> Result<Json, String> {
        self.call(&Json::obj(vec![("op", Json::Str("shards".into()))]))
    }

    /// Prometheus text-exposition page of pool-wide metrics: counters,
    /// gauges, depth/lane-occupancy histograms, and per-stage latency
    /// histograms (DESIGN.md §11).
    pub fn metrics(&mut self) -> Result<String, String> {
        let resp = self.call(&Json::obj(vec![("op", Json::Str("metrics".into()))]))?;
        resp.get("text")
            .as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| "metrics reply missing text".into())
    }

    /// Dump the span events recorded for the request registered under
    /// `tag`: `{"ok":true,"tag":..,"shard":..,"trace":..,"events":[..]}`.
    /// Errors when the tag was never registered (or has been evicted
    /// from the route registry).
    pub fn trace(&mut self, tag: u64) -> Result<Json, String> {
        self.call(&Json::obj(vec![
            ("op", Json::Str("trace".into())),
            ("tag", Json::Num(tag as f64)),
        ]))
    }

    /// Cancel the request registered under `tag` (typically submitted by
    /// a *different* connection, whose blocked `sample` call then
    /// returns its partial result). Ok(false) when no such tag is live.
    pub fn cancel(&mut self, tag: u64) -> Result<bool, String> {
        let req = Json::obj(vec![
            ("op", Json::Str("cancel".into())),
            ("tag", Json::Num(tag as f64)),
        ]);
        let resp = self.call(&req)?;
        Ok(resp.get("cancelled").as_bool().unwrap_or(false))
    }

    /// Request samples; returns (samples, server-reported total seconds).
    pub fn sample(&mut self, spec: &RequestSpec) -> Result<(Tensor, f64), String> {
        let out = self.sample_tagged(spec, None)?;
        Ok((out.samples, out.seconds))
    }

    /// Request samples with an optional cancellation tag; returns the
    /// full outcome including the `cancelled` flag and NFE consumed.
    pub fn sample_tagged(
        &mut self,
        spec: &RequestSpec,
        tag: Option<u64>,
    ) -> Result<SampleOutcome, String> {
        let mut pairs = vec![
            ("op", Json::Str("sample".into())),
            ("dataset", Json::Str(spec.dataset.clone())),
            ("solver", Json::Str(spec.solver.clone())),
            ("nfe", Json::Num(spec.nfe as f64)),
            ("n_samples", Json::Num(spec.n_samples as f64)),
            ("grid", Json::Str(spec.grid.clone())),
            ("t_end", Json::Num(spec.t_end)),
            ("seed", Json::Num(spec.seed as f64)),
            ("return_samples", Json::Bool(true)),
        ];
        if let Some(ms) = spec.deadline_ms {
            pairs.push(("deadline_ms", Json::Num(ms as f64)));
        }
        if let Some(tag) = tag {
            pairs.push(("tag", Json::Num(tag as f64)));
        }
        // Workload fields ride only when they deviate from the plain
        // task, keeping the wire format of unconditional requests (and
        // old servers' view of them) unchanged.
        let task = &spec.task;
        if task.is_guided() {
            pairs.push(("guidance_scale", Json::Num(task.guidance_scale)));
            pairs.push(("guide_class", Json::Num(task.guide_class as f64)));
        }
        if task.is_img2img() {
            pairs.push(("strength", Json::Num(task.strength)));
        }
        let mut payload: Option<&Tensor> = None;
        if let Some(init) = &task.init {
            if self.encoding == Encoding::Bin {
                pairs.push(("init_rows", Json::Num(init.rows() as f64)));
                pairs.push(("init_bytes", Json::Num((init.len() * 4) as f64)));
                payload = Some(init);
            } else {
                pairs.push(("init", crate::server::protocol::rows_to_json(init)));
            }
        }
        if task.is_stochastic() {
            pairs.push(("churn", Json::Num(task.churn)));
        }
        // QoS fields likewise ride only when they deviate from the
        // strict fixed-NFE default.
        if spec.qos != QosClass::Strict {
            pairs.push(("qos", Json::Str(spec.qos.label().into())));
        }
        if spec.min_nfe != 0 {
            pairs.push(("min_nfe", Json::Num(spec.min_nfe as f64)));
        }
        if spec.conv_threshold != 0.0 {
            pairs.push(("conv_threshold", Json::Num(spec.conv_threshold)));
        }
        if self.encoding == Encoding::Bin {
            pairs.push(("encoding", Json::Str("bin".into())));
        }
        let resp = self.call_sample(&Json::obj(pairs), payload)?;
        let samples = match resp.get("payload_bytes").as_usize() {
            Some(n) => {
                let rows = resp.get("rows").as_usize().ok_or("binary reply missing rows")?;
                let dim = resp.get("dim").as_usize().ok_or("binary reply missing dim")?;
                let mut bytes = vec![0u8; n];
                self.reader.read_exact(&mut bytes).map_err(|e| e.to_string())?;
                Tensor::from_le_bytes(&bytes, rows, dim)?
            }
            None => samples_from_json(&resp)?,
        };
        Ok(SampleOutcome {
            samples,
            seconds: resp.get("total_ms").as_f64().unwrap_or(0.0) / 1e3,
            nfe: resp.get("nfe").as_usize().unwrap_or(0),
            cancelled: resp.get("cancelled").as_bool().unwrap_or(false),
            early_stop: resp.get("early_stop").as_bool().unwrap_or(false),
            delta_eps: resp.get("delta_eps").as_f64(),
        })
    }

    /// Send one `sample` request — header line plus an optional binary
    /// init payload — and read the reply header line. A binary samples
    /// payload, if announced, is left in the reader for the caller.
    fn call_sample(&mut self, req: &Json, payload: Option<&Tensor>) -> Result<Json, String> {
        let mut line = req.to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).map_err(|e| e.to_string())?;
        if let Some(init) = payload {
            #[cfg(target_endian = "little")]
            self.stream.write_all(init.as_le_bytes()).map_err(|e| e.to_string())?;
            #[cfg(not(target_endian = "little"))]
            self.stream.write_all(&init.to_le_bytes()).map_err(|e| e.to_string())?;
        }
        self.stream.flush().map_err(|e| e.to_string())?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply).map_err(|e| e.to_string())?;
        let j = json::parse(&reply).map_err(|e| format!("{e:?}"))?;
        if j.get("ok").as_bool() != Some(true) {
            return Err(j.get("error").as_str().unwrap_or("unknown error").to_string());
        }
        Ok(j)
    }
}

/// Full outcome of one `sample` call (cancellation-aware clients).
#[derive(Debug)]
pub struct SampleOutcome {
    pub samples: Tensor,
    /// Server-reported submit-to-finish seconds.
    pub seconds: f64,
    /// Network evaluations actually consumed (< budget when cancelled).
    pub nfe: usize,
    pub cancelled: bool,
    /// True when the convergence controller retired the request before
    /// its full fixed-NFE budget.
    pub early_stop: bool,
    /// Final error-robust error measure (ERA solvers only).
    pub delta_eps: Option<f64>,
}

/// Aggregate results of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub requests: usize,
    pub errors: usize,
    pub wall_seconds: f64,
    /// Client-observed latencies, seconds (sorted).
    pub latencies: Vec<f64>,
    /// Samples produced per wall-second.
    pub throughput_rows: f64,
}

impl LoadReport {
    pub fn percentile(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let idx = ((self.latencies.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        self.latencies[idx]
    }
}

/// Load-generator shape: how many closed-loop workers, how many
/// sequential requests each issues, and whether a worker keeps one
/// connection alive across them or reconnects per request.
#[derive(Clone, Debug)]
pub struct LoadOptions {
    pub concurrency: usize,
    pub requests_per_worker: usize,
    /// true (the default): each worker issues all its requests over one
    /// kept-alive connection. false: a fresh connect per request —
    /// the handshake-heavy profile the gateway bench contrasts.
    pub reuse: bool,
    /// Wire encoding every worker negotiates ([`Encoding::Json`] by
    /// default; [`Encoding::Bin`] for counted binary sample delivery).
    pub encoding: Encoding,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions {
            concurrency: 1,
            requests_per_worker: 1,
            reuse: true,
            encoding: Encoding::Json,
        }
    }
}

/// Closed-loop load generator: `concurrency` threads each issue
/// `requests_per_worker` sampling calls back-to-back over one
/// kept-alive connection each. See [`generate_load_with`] for the
/// reconnect-per-request variant.
pub fn generate_load(
    addr: std::net::SocketAddr,
    base_spec: &RequestSpec,
    concurrency: usize,
    requests_per_worker: usize,
) -> LoadReport {
    generate_load_with(
        addr,
        base_spec,
        &LoadOptions { concurrency, requests_per_worker, ..LoadOptions::default() },
    )
}

/// Closed-loop load generator with explicit connection-reuse control.
/// A worker whose connection errors drops it and reconnects for the
/// next request, so one refused connect costs one request, not the
/// worker's whole budget.
pub fn generate_load_with(
    addr: std::net::SocketAddr,
    base_spec: &RequestSpec,
    opts: &LoadOptions,
) -> LoadReport {
    let errors = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..opts.concurrency {
        let spec = base_spec.clone();
        let errors = errors.clone();
        let reuse = opts.reuse;
        let encoding = opts.encoding;
        let requests_per_worker = opts.requests_per_worker;
        handles.push(std::thread::spawn(move || {
            let mut lats = Vec::with_capacity(requests_per_worker);
            let mut rows = 0usize;
            let mut client: Option<Client> = None;
            for i in 0..requests_per_worker {
                if client.is_none() {
                    match Client::connect(addr) {
                        Ok(mut c) => {
                            c.set_encoding(encoding);
                            client = Some(c);
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_millis(2));
                            continue;
                        }
                    }
                }
                let mut s = spec.clone();
                s.seed = (w * 10_007 + i) as u64;
                let t = Instant::now();
                match client.as_mut().expect("connected above").sample(&s) {
                    Ok((samples, _)) => {
                        lats.push(t.elapsed().as_secs_f64());
                        rows += samples.rows();
                        if !reuse {
                            client = None;
                        }
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                        client = None; // reconnect after any error
                        // brief backoff on rejection
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            }
            (lats, rows)
        }));
    }
    let mut latencies = Vec::new();
    let mut rows = 0usize;
    for h in handles {
        let (l, r) = h.join().expect("load worker");
        latencies.extend(l);
        rows += r;
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    LoadReport {
        requests: latencies.len(),
        errors: errors.load(Ordering::Relaxed),
        wall_seconds: wall,
        throughput_rows: rows as f64 / wall,
        latencies,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_report_percentiles() {
        let r = LoadReport {
            requests: 3,
            errors: 0,
            wall_seconds: 1.0,
            latencies: vec![0.1, 0.2, 0.3],
            throughput_rows: 10.0,
        };
        assert_eq!(r.percentile(0.0), 0.1);
        assert_eq!(r.percentile(1.0), 0.3);
        assert_eq!(r.percentile(0.5), 0.2);
    }
}
