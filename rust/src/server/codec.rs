//! Framed-protocol codec: incremental extraction of newline-delimited
//! JSON frames from partial byte buffers.
//!
//! The wire format is JSON-lines (one request or response object per
//! `\n`-terminated line, see [`super::protocol`]). The blocking path
//! used to lean on `BufReader::read_line`, which couples framing to a
//! blocking socket; the readiness-based gateway needs the inverse: feed
//! whatever bytes the socket had, get back zero or more complete
//! frames, and a deterministic "need more" in between. [`FrameDecoder`]
//! is that state machine, shared by both server paths so there is
//! exactly one framing implementation on the wire.
//!
//! Robustness contract (exercised by `tests/proptests.rs`):
//!
//! - arbitrary split points reassemble the exact frame sequence;
//! - a truncated frame is `Ok(None)` ("need more"), never a partial
//!   frame and never an error — until its length exceeds the cap;
//! - a line longer than [`FrameDecoder::cap`] with no newline yet is
//!   [`CodecError::Oversized`] (the JSON-lines analog of a hostile
//!   length header) so a gateway can drop the peer instead of
//!   buffering without bound;
//! - invalid UTF-8 is replaced, not panicked on; JSON parsing rejects
//!   it downstream with an ordinary protocol error.

use std::fmt;

/// Default cap on a single unterminated line. Large enough for a
/// `return_samples` response on a big batch, small enough to bound a
/// hostile peer's buffer growth.
pub const MAX_FRAME_LEN: usize = 8 * 1024 * 1024;

/// Compact the consumed prefix away once it passes this size, so the
/// buffer does not creep upward across many small frames while staying
/// O(bytes) amortized (no per-frame `drain`).
const COMPACT_THRESHOLD: usize = 16 * 1024;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The current line has grown past the decoder's cap without a
    /// terminating newline. The connection cannot resync; close it.
    Oversized { len: usize, cap: usize },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Oversized { len, cap } => {
                write!(f, "frame exceeds {cap} bytes ({len} buffered without newline)")
            }
        }
    }
}

impl std::error::Error for CodecError {}

/// Incremental newline-frame decoder over an internal byte buffer.
///
/// `push` bytes in as they arrive; `next_frame` yields complete lines
/// (without the terminator, with a trailing `\r` stripped) until the
/// buffer runs dry. Already-scanned bytes are never rescanned, so total
/// decode cost is O(bytes received) regardless of how reads split.
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Start of the unconsumed region (bytes before it are delivered
    /// frames awaiting compaction).
    start: usize,
    /// Newline scan cursor within `buf`; always `>= start`.
    scanned: usize,
    cap: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::with_cap(MAX_FRAME_LEN)
    }

    pub fn with_cap(cap: usize) -> FrameDecoder {
        FrameDecoder { buf: Vec::new(), start: 0, scanned: 0, cap: cap.max(1) }
    }

    /// Bytes buffered but not yet delivered as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Feed freshly read bytes into the decoder.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
            self.scanned = 0;
        } else if self.start >= COMPACT_THRESHOLD {
            self.buf.drain(..self.start);
            self.scanned -= self.start;
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Extract the next complete frame, `Ok(None)` when more bytes are
    /// needed, or `Err` when the pending line exceeds the cap.
    pub fn next_frame(&mut self) -> Result<Option<String>, CodecError> {
        match self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(off) => {
                let nl = self.scanned + off;
                let mut end = nl;
                if end > self.start && self.buf[end - 1] == b'\r' {
                    end -= 1;
                }
                let frame = String::from_utf8_lossy(&self.buf[self.start..end]).into_owned();
                self.start = nl + 1;
                self.scanned = self.start;
                Ok(Some(frame))
            }
            None => {
                self.scanned = self.buf.len();
                let pending = self.buf.len() - self.start;
                if pending > self.cap {
                    Err(CodecError::Oversized { len: pending, cap: self.cap })
                } else {
                    Ok(None)
                }
            }
        }
    }
}

impl Default for FrameDecoder {
    fn default() -> FrameDecoder {
        FrameDecoder::new()
    }
}

/// Append one frame (line + terminator) to an outgoing byte queue.
pub fn encode_frame(line: &str, out: &mut Vec<u8>) {
    out.extend_from_slice(line.as_bytes());
    out.push(b'\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames(d: &mut FrameDecoder) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(f) = d.next_frame().expect("codec error") {
            out.push(f);
        }
        out
    }

    #[test]
    fn whole_frames_pass_through() {
        let mut d = FrameDecoder::new();
        d.push(b"{\"op\":\"ping\"}\n{\"op\":\"stats\"}\n");
        assert_eq!(frames(&mut d), vec!["{\"op\":\"ping\"}", "{\"op\":\"stats\"}"]);
        assert_eq!(d.buffered(), 0);
    }

    #[test]
    fn split_frame_needs_more_then_completes() {
        let mut d = FrameDecoder::new();
        d.push(b"{\"op\":\"pi");
        assert_eq!(d.next_frame().unwrap(), None);
        d.push(b"ng\"}\n");
        assert_eq!(d.next_frame().unwrap(), Some("{\"op\":\"ping\"}".to_string()));
        assert_eq!(d.next_frame().unwrap(), None);
    }

    #[test]
    fn byte_at_a_time_is_deterministic() {
        let src = b"first\nsecond\r\nthird\n";
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in src.iter() {
            d.push(&[b]);
            got.extend(frames(&mut d));
        }
        assert_eq!(got, vec!["first", "second", "third"]);
    }

    #[test]
    fn crlf_and_empty_lines() {
        let mut d = FrameDecoder::new();
        d.push(b"a\r\n\r\n\nb\n");
        assert_eq!(frames(&mut d), vec!["a", "", "", "b"]);
    }

    #[test]
    fn oversized_line_errors_and_stays_errored() {
        let mut d = FrameDecoder::with_cap(8);
        d.push(b"123456789");
        assert_eq!(d.next_frame(), Err(CodecError::Oversized { len: 9, cap: 8 }));
        d.push(b"more");
        assert!(d.next_frame().is_err());
    }

    #[test]
    fn frame_exactly_at_cap_is_fine() {
        let mut d = FrameDecoder::with_cap(4);
        d.push(b"abcd\n");
        assert_eq!(d.next_frame().unwrap(), Some("abcd".to_string()));
    }

    #[test]
    fn invalid_utf8_is_replaced_not_panicked() {
        let mut d = FrameDecoder::new();
        d.push(&[0xff, 0xfe, b'\n']);
        let f = d.next_frame().unwrap().unwrap();
        assert_eq!(f, "\u{FFFD}\u{FFFD}");
    }

    #[test]
    fn compaction_preserves_stream() {
        let mut d = FrameDecoder::new();
        let mut want = Vec::new();
        let mut got = Vec::new();
        for i in 0..5000 {
            let line = format!("frame-{i}");
            want.push(line.clone());
            d.push(line.as_bytes());
            d.push(b"\n");
            got.extend(frames(&mut d));
        }
        assert_eq!(got, want);
        assert!(d.buf.len() < 2 * COMPACT_THRESHOLD, "buffer failed to compact");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut bytes = Vec::new();
        encode_frame("{\"ok\":true}", &mut bytes);
        encode_frame("x", &mut bytes);
        let mut d = FrameDecoder::new();
        d.push(&bytes);
        assert_eq!(frames(&mut d), vec!["{\"ok\":true}", "x"]);
    }
}
