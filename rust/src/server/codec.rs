//! Framed-protocol codec: incremental extraction of newline-delimited
//! JSON frames — and counted binary payloads — from partial byte
//! buffers.
//!
//! The base wire format is JSON-lines (one request or response object
//! per `\n`-terminated line, see [`super::protocol`]). The blocking
//! path used to lean on `BufReader::read_line`, which couples framing
//! to a blocking socket; the readiness-based gateway needs the inverse:
//! feed whatever bytes the socket had, get back zero or more complete
//! frames, and a deterministic "need more" in between. [`FrameDecoder`]
//! is that state machine, shared by both server paths so there is
//! exactly one framing implementation on the wire.
//!
//! When a decoded line announces a counted payload (a binary `init`
//! upload's `init_bytes`, see DESIGN.md §6), the session calls
//! [`FrameDecoder::expect_payload`] and the decoder switches from
//! newline scanning to byte counting: the next `n` raw bytes are
//! delivered verbatim as [`Frame::Payload`] — they may contain `\n` —
//! and line scanning resumes after them.
//!
//! Robustness contract (exercised by `tests/proptests.rs`):
//!
//! - arbitrary split points reassemble the exact frame sequence, across
//!   line/payload boundaries included;
//! - a truncated frame is `Ok(None)` ("need more"), never a partial
//!   frame and never an error — until its length exceeds the cap;
//! - a line longer than [`FrameDecoder::cap`] with no newline yet is
//!   [`CodecError::Oversized`] (the JSON-lines analog of a hostile
//!   length header) so a gateway can drop the peer instead of
//!   buffering without bound; an *announced* payload length above the
//!   cap errors immediately and the error is sticky until [`reset`];
//! - invalid UTF-8 is replaced, not panicked on; JSON parsing rejects
//!   it downstream with an ordinary protocol error.
//!
//! [`reset`]: FrameDecoder::reset

use std::fmt;

/// Default cap on a single unterminated line or announced payload.
/// Large enough for a `return_samples` response on a big batch, small
/// enough to bound a hostile peer's buffer growth.
pub const MAX_FRAME_LEN: usize = 8 * 1024 * 1024;

/// Compact the consumed prefix away once it passes this size, so the
/// buffer does not creep upward across many small frames while staying
/// O(bytes) amortized (no per-frame `drain`).
const COMPACT_THRESHOLD: usize = 16 * 1024;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The current line has grown past the decoder's cap without a
    /// terminating newline, or a header announced a payload longer
    /// than the cap. The connection cannot resync; close it.
    Oversized { len: usize, cap: usize },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Oversized { len, cap } => {
                write!(f, "frame exceeds {cap} bytes ({len} buffered without newline)")
            }
        }
    }
}

impl std::error::Error for CodecError {}

/// One decoded wire unit: a text line or a counted raw payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Frame {
    /// A `\n`-terminated line (terminator and trailing `\r` stripped,
    /// invalid UTF-8 replaced).
    Line(String),
    /// Exactly the announced number of raw bytes, delivered after
    /// [`FrameDecoder::expect_payload`] armed counted mode.
    Payload(Vec<u8>),
}

/// Incremental frame decoder over an internal byte buffer.
///
/// `push` bytes in as they arrive; `next` yields complete frames until
/// the buffer runs dry. Already-scanned bytes are never rescanned, so
/// total decode cost is O(bytes received) regardless of how reads
/// split.
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Start of the unconsumed region (bytes before it are delivered
    /// frames awaiting compaction).
    start: usize,
    /// Newline scan cursor within `buf`; always `>= start`. Meaningless
    /// while in counted-payload mode.
    scanned: usize,
    cap: usize,
    /// `Some(n)` while the next `n` raw bytes belong to an announced
    /// payload rather than the line stream.
    pending_payload: Option<usize>,
    /// A hostile announced length poisons the decoder until `reset` —
    /// the byte stream after it cannot be resynchronised.
    failed: Option<CodecError>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::with_cap(MAX_FRAME_LEN)
    }

    pub fn with_cap(cap: usize) -> FrameDecoder {
        FrameDecoder {
            buf: Vec::new(),
            start: 0,
            scanned: 0,
            cap: cap.max(1),
            pending_payload: None,
            failed: None,
        }
    }

    /// Bytes buffered but not yet delivered as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// True while an announced payload is still being counted in.
    pub fn awaiting_payload(&self) -> bool {
        self.pending_payload.is_some()
    }

    /// Feed freshly read bytes into the decoder.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
            self.scanned = 0;
        } else if self.start >= COMPACT_THRESHOLD {
            self.buf.drain(..self.start);
            self.scanned -= self.start;
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Arm counted-payload mode: the next `n` raw bytes (which may
    /// include `\n`) form one [`Frame::Payload`]. An announced length
    /// above the cap is refused and poisons the decoder — the stream
    /// cannot be resynchronised past an un-consumed payload.
    pub fn expect_payload(&mut self, n: usize) -> Result<(), CodecError> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        if n > self.cap {
            let e = CodecError::Oversized { len: n, cap: self.cap };
            self.failed = Some(e.clone());
            return Err(e);
        }
        debug_assert!(self.pending_payload.is_none(), "payload already pending");
        self.pending_payload = Some(n);
        Ok(())
    }

    /// Extract the next complete frame (line or counted payload),
    /// `Ok(None)` when more bytes are needed, or `Err` when the pending
    /// line exceeds the cap / a hostile announce poisoned the decoder.
    pub fn next_any(&mut self) -> Result<Option<Frame>, CodecError> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        if let Some(n) = self.pending_payload {
            if self.buffered() < n {
                return Ok(None);
            }
            let payload = self.buf[self.start..self.start + n].to_vec();
            self.start += n;
            self.scanned = self.start;
            self.pending_payload = None;
            return Ok(Some(Frame::Payload(payload)));
        }
        match self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(off) => {
                let nl = self.scanned + off;
                let mut end = nl;
                if end > self.start && self.buf[end - 1] == b'\r' {
                    end -= 1;
                }
                let frame = String::from_utf8_lossy(&self.buf[self.start..end]).into_owned();
                self.start = nl + 1;
                self.scanned = self.start;
                Ok(Some(Frame::Line(frame)))
            }
            None => {
                self.scanned = self.buf.len();
                let pending = self.buf.len() - self.start;
                if pending > self.cap {
                    Err(CodecError::Oversized { len: pending, cap: self.cap })
                } else {
                    Ok(None)
                }
            }
        }
    }

    /// Line-only convenience used by callers that never arm payload
    /// mode; semantics identical to the pre-payload decoder.
    pub fn next_frame(&mut self) -> Result<Option<String>, CodecError> {
        debug_assert!(self.pending_payload.is_none(), "payload pending; use next_any()");
        match self.next_any()? {
            Some(Frame::Line(s)) => Ok(Some(s)),
            Some(Frame::Payload(_)) => unreachable!("payload frame without expect_payload"),
            None => Ok(None),
        }
    }

    /// Drop all buffered bytes and mode state. Sessions call this on
    /// `abort()` so a half-received payload or a sticky announce error
    /// never leaks into a pooled buffer's next life.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.start = 0;
        self.scanned = 0;
        self.pending_payload = None;
        self.failed = None;
    }
}

impl Default for FrameDecoder {
    fn default() -> FrameDecoder {
        FrameDecoder::new()
    }
}

/// Append one frame (line + terminator) to an outgoing byte queue.
pub fn encode_frame(line: &str, out: &mut Vec<u8>) {
    out.extend_from_slice(line.as_bytes());
    out.push(b'\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames(d: &mut FrameDecoder) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(f) = d.next_frame().expect("codec error") {
            out.push(f);
        }
        out
    }

    #[test]
    fn whole_frames_pass_through() {
        let mut d = FrameDecoder::new();
        d.push(b"{\"op\":\"ping\"}\n{\"op\":\"stats\"}\n");
        assert_eq!(frames(&mut d), vec!["{\"op\":\"ping\"}", "{\"op\":\"stats\"}"]);
        assert_eq!(d.buffered(), 0);
    }

    #[test]
    fn split_frame_needs_more_then_completes() {
        let mut d = FrameDecoder::new();
        d.push(b"{\"op\":\"pi");
        assert_eq!(d.next_frame().unwrap(), None);
        d.push(b"ng\"}\n");
        assert_eq!(d.next_frame().unwrap(), Some("{\"op\":\"ping\"}".to_string()));
        assert_eq!(d.next_frame().unwrap(), None);
    }

    #[test]
    fn byte_at_a_time_is_deterministic() {
        let src = b"first\nsecond\r\nthird\n";
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in src.iter() {
            d.push(&[b]);
            got.extend(frames(&mut d));
        }
        assert_eq!(got, vec!["first", "second", "third"]);
    }

    #[test]
    fn crlf_and_empty_lines() {
        let mut d = FrameDecoder::new();
        d.push(b"a\r\n\r\n\nb\n");
        assert_eq!(frames(&mut d), vec!["a", "", "", "b"]);
    }

    #[test]
    fn oversized_line_errors_and_stays_errored() {
        let mut d = FrameDecoder::with_cap(8);
        d.push(b"123456789");
        assert_eq!(d.next_frame(), Err(CodecError::Oversized { len: 9, cap: 8 }));
        d.push(b"more");
        assert!(d.next_frame().is_err());
    }

    #[test]
    fn frame_exactly_at_cap_is_fine() {
        let mut d = FrameDecoder::with_cap(4);
        d.push(b"abcd\n");
        assert_eq!(d.next_frame().unwrap(), Some("abcd".to_string()));
    }

    #[test]
    fn invalid_utf8_is_replaced_not_panicked() {
        let mut d = FrameDecoder::new();
        d.push(&[0xff, 0xfe, b'\n']);
        let f = d.next_frame().unwrap().unwrap();
        assert_eq!(f, "\u{FFFD}\u{FFFD}");
    }

    #[test]
    fn compaction_preserves_stream() {
        let mut d = FrameDecoder::new();
        let mut want = Vec::new();
        let mut got = Vec::new();
        for i in 0..5000 {
            let line = format!("frame-{i}");
            want.push(line.clone());
            d.push(line.as_bytes());
            d.push(b"\n");
            got.extend(frames(&mut d));
        }
        assert_eq!(got, want);
        assert!(d.buf.len() < 2 * COMPACT_THRESHOLD, "buffer failed to compact");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut bytes = Vec::new();
        encode_frame("{\"ok\":true}", &mut bytes);
        encode_frame("x", &mut bytes);
        let mut d = FrameDecoder::new();
        d.push(&bytes);
        assert_eq!(frames(&mut d), vec!["{\"ok\":true}", "x"]);
    }

    #[test]
    fn counted_payload_carries_newlines_verbatim() {
        let mut d = FrameDecoder::new();
        d.push(b"header\n\x01\n\x02\n\x03after\n");
        assert_eq!(d.next_any().unwrap(), Some(Frame::Line("header".into())));
        d.expect_payload(5).unwrap();
        assert_eq!(d.next_any().unwrap(), Some(Frame::Payload(b"\x01\n\x02\n\x03".to_vec())));
        assert_eq!(d.next_any().unwrap(), Some(Frame::Line("after".into())));
        assert_eq!(d.buffered(), 0);
    }

    #[test]
    fn truncated_payload_needs_more_then_completes() {
        let mut d = FrameDecoder::new();
        d.expect_payload(4).unwrap();
        d.push(b"ab");
        assert_eq!(d.next_any().unwrap(), None);
        assert!(d.awaiting_payload());
        d.push(b"cd");
        assert_eq!(d.next_any().unwrap(), Some(Frame::Payload(b"abcd".to_vec())));
        assert!(!d.awaiting_payload());
    }

    #[test]
    fn oversized_payload_announce_is_sticky_until_reset() {
        let mut d = FrameDecoder::with_cap(8);
        assert_eq!(d.expect_payload(9), Err(CodecError::Oversized { len: 9, cap: 8 }));
        d.push(b"x\n");
        assert!(d.next_any().is_err());
        assert_eq!(d.expect_payload(1), Err(CodecError::Oversized { len: 9, cap: 8 }));
        d.reset();
        assert_eq!(d.buffered(), 0);
        d.push(b"ok\n");
        assert_eq!(d.next_any().unwrap(), Some(Frame::Line("ok".into())));
    }

    #[test]
    fn reset_discards_half_received_payload() {
        let mut d = FrameDecoder::new();
        d.expect_payload(100).unwrap();
        d.push(b"partial payload bytes");
        assert_eq!(d.next_any().unwrap(), None);
        d.reset();
        assert!(!d.awaiting_payload());
        d.push(b"{\"op\":\"ping\"}\n");
        assert_eq!(d.next_frame().unwrap(), Some("{\"op\":\"ping\"}".to_string()));
    }

    #[test]
    fn payload_exactly_at_cap_is_fine() {
        let mut d = FrameDecoder::with_cap(4);
        d.expect_payload(4).unwrap();
        d.push(b"abcd");
        assert_eq!(d.next_any().unwrap(), Some(Frame::Payload(b"abcd".to_vec())));
    }
}
