//! Per-connection protocol session: a transport-agnostic state machine
//! between raw bytes and the worker pool.
//!
//! The gateway's event loops own sockets and readiness; they delegate
//! everything protocol-shaped to a [`Session`]: feed it whatever bytes
//! the socket had ([`Session::on_bytes`]), drain its outgoing segment
//! queue when the socket is writable ([`Session::out_vectored`] /
//! [`Session::consume_out`]), and poke it when a submitted request
//! completes ([`Session::on_complete`]). The session never blocks and
//! never touches a socket, so it unit-tests without any I/O and would
//! ride any future transport (TLS, Unix sockets) unchanged.
//!
//! Control ops (`ping`, `stats`, `cancel`, ...) answer immediately.
//! `sample` ops are submitted with a [`CompletionNotify`] that calls
//! the session's ready callback with a per-request token; the owning
//! loop routes that token back into [`Session::on_complete`], which
//! polls the ticket (guaranteed ready — the notify fires after the
//! result lands) and enqueues the reply. Several samples may be in
//! flight on one connection at once; replies are written in completion
//! order, which pipelining clients must match by their own bookkeeping
//! (the stock [`super::client::Client`] runs one request at a time and
//! never observes reordering).
//!
//! Zero-copy delivery (DESIGN.md §6): the outgoing queue is a queue of
//! *segments*, not a flat byte buffer. Text frames (control replies,
//! JSON results, binary headers) are `String`s drawn from a shared
//! [`EncodePool`] and returned to it once written; a binary sample
//! reply's payload segment holds the result tensor behind an `Arc` and
//! is written straight from the engine-owned allocation — the final
//! iterate's bytes go from lane engine to socket without a copy. The
//! owner gathers several segments per syscall via
//! [`Session::out_vectored`] + `writev`.
//!
//! Backpressure: the outgoing queue is bounded by
//! [`SessionConfig::write_queue_cap`]. While it is over the cap,
//! [`Session::wants_read`] turns false and the owner deregisters read
//! interest — a peer that stops draining replies stops being read,
//! instead of growing an unbounded buffer server-side.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::coordinator::{CancelHandle, CompletionNotify, SamplingResult};
use crate::json::{self, Json};
use crate::pool::{PoolTicket, WorkerPool};

use super::codec::{Frame, FrameDecoder, MAX_FRAME_LEN};
use super::protocol::{announced_payload, write_result_header, write_result_json, Encoding};
use super::{dispatch_parsed, err_json, Dispatched};

/// Per-session protocol limits (shared by every connection of one
/// gateway).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Cap on one unterminated request line or announced payload; a
    /// peer exceeding it gets one error reply and the connection closes
    /// (codec robustness contract — the connection cannot resync past
    /// an unframed blob).
    pub max_frame_len: usize,
    /// Outgoing-queue size above which the session parks read interest.
    pub write_queue_cap: usize,
    /// Server-level convergence default inherited by non-strict
    /// requests that did not set their own (see [`super::dispatch`]).
    pub default_conv_threshold: f64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            max_frame_len: MAX_FRAME_LEN,
            write_queue_cap: 256 * 1024,
            default_conv_threshold: 0.0,
        }
    }
}

/// Callback into the owning event loop: "the request with this token
/// finished; call [`Session::on_complete`] with it". Fired on the
/// shard's loop thread, so implementations must only enqueue-and-wake.
pub type ReadyFn = Arc<dyn Fn(u64) + Send + Sync>;

/// Shared pool of reusable encode buffers. Every text frame a session
/// emits is serialised into a `String` taken from here and returned
/// once the socket consumed it, so a warm gateway serialises replies
/// with no per-frame allocation. Bounded both ways: at most
/// [`POOL_MAX_BUFS`] buffers are retained, and a buffer that grew past
/// [`POOL_MAX_BUF_CAP`] (one giant `return_samples` reply) is dropped
/// rather than pinned forever.
#[derive(Default)]
pub struct EncodePool {
    bufs: Mutex<Vec<String>>,
}

/// Retention cap on pooled buffers (count).
pub const POOL_MAX_BUFS: usize = 64;
/// Retention cap on a single pooled buffer's capacity (bytes).
pub const POOL_MAX_BUF_CAP: usize = 1024 * 1024;

impl EncodePool {
    pub fn new() -> EncodePool {
        EncodePool::default()
    }

    /// Pop a cleared buffer, or a fresh one when the pool is dry.
    pub fn take(&self) -> String {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a written buffer for reuse (cleared here, capacity kept).
    pub fn put(&self, mut buf: String) {
        if buf.capacity() == 0 || buf.capacity() > POOL_MAX_BUF_CAP {
            return;
        }
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < POOL_MAX_BUFS {
            bufs.push(buf);
        }
    }

    /// Buffers currently parked in the pool (test observability).
    pub fn idle(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

struct PendingRequest {
    ticket: PoolTicket,
    return_samples: bool,
    tag: Option<u64>,
    handle: CancelHandle,
    encoding: Encoding,
}

/// One queued outgoing segment. Headers and JSON replies are pooled
/// text; a binary payload is the result tensor itself, viewed in place.
enum OutSeg {
    Text(String),
    #[cfg(target_endian = "little")]
    Samples(Arc<crate::tensor::Tensor>),
    /// Big-endian fallback: payloads must be byte-swapped into an owned
    /// buffer (the wire format is little-endian).
    #[cfg(not(target_endian = "little"))]
    Blob(Vec<u8>),
}

impl OutSeg {
    fn bytes(&self) -> &[u8] {
        match self {
            OutSeg::Text(s) => s.as_bytes(),
            #[cfg(target_endian = "little")]
            OutSeg::Samples(t) => t.as_le_bytes(),
            #[cfg(not(target_endian = "little"))]
            OutSeg::Blob(b) => b,
        }
    }
}

/// Outgoing segment queue. `front_pos` tracks the consumed prefix of
/// the front segment; fully consumed segments pop off and (for text)
/// return their buffer to the encode pool.
struct OutQueue {
    segs: VecDeque<OutSeg>,
    front_pos: usize,
    len: usize,
}

impl OutQueue {
    fn new() -> OutQueue {
        OutQueue { segs: VecDeque::new(), front_pos: 0, len: 0 }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn push(&mut self, seg: OutSeg) {
        let n = seg.bytes().len();
        if n == 0 {
            return;
        }
        self.len += n;
        self.segs.push_back(seg);
    }

    fn front_slice(&self) -> &[u8] {
        match self.segs.front() {
            Some(seg) => &seg.bytes()[self.front_pos..],
            None => &[],
        }
    }

    /// Fill `out` with up to `out.len()` unconsumed segment slices, in
    /// order; returns how many were filled.
    fn vectored<'a>(&'a self, out: &mut [&'a [u8]]) -> usize {
        let mut n = 0;
        for (i, seg) in self.segs.iter().enumerate() {
            if n == out.len() {
                break;
            }
            let bytes = seg.bytes();
            out[n] = if i == 0 { &bytes[self.front_pos..] } else { bytes };
            n += 1;
        }
        n
    }

    fn consume(&mut self, mut n: usize, pool: &EncodePool) {
        debug_assert!(n <= self.len);
        self.len -= n.min(self.len);
        while n > 0 {
            let Some(front) = self.segs.front() else { break };
            let remaining = front.bytes().len() - self.front_pos;
            if n < remaining {
                self.front_pos += n;
                return;
            }
            n -= remaining;
            self.front_pos = 0;
            if let Some(OutSeg::Text(buf)) = self.segs.pop_front() {
                pool.put(buf);
            }
        }
        debug_assert_eq!(n, 0);
    }

    /// Drop everything queued, recycling text buffers.
    fn clear(&mut self, pool: &EncodePool) {
        while let Some(seg) = self.segs.pop_front() {
            if let OutSeg::Text(buf) = seg {
                pool.put(buf);
            }
        }
        self.front_pos = 0;
        self.len = 0;
    }
}

/// One connection's protocol state. See the module docs for the
/// ownership contract with the event loop.
pub struct Session {
    pool: Arc<WorkerPool>,
    decoder: FrameDecoder,
    out: OutQueue,
    encode_pool: Arc<EncodePool>,
    /// Parsed request header whose announced `init` payload is still
    /// being counted in by the decoder.
    pending_header: Option<Json>,
    pending: HashMap<u64, PendingRequest>,
    next_token: u64,
    write_queue_cap: usize,
    default_conv_threshold: f64,
    on_ready: ReadyFn,
    /// Set on a codec error: the reply queue drains, then the owner
    /// closes the socket ([`Session::should_close`]).
    closed: bool,
}

impl Session {
    pub fn new(pool: Arc<WorkerPool>, config: &SessionConfig, on_ready: ReadyFn) -> Session {
        Session::with_encode_pool(pool, config, on_ready, Arc::new(EncodePool::new()))
    }

    /// Like [`Session::new`], but drawing encode buffers from a shared
    /// pool — the gateway passes one pool per process so buffers warm
    /// up across connections.
    pub fn with_encode_pool(
        pool: Arc<WorkerPool>,
        config: &SessionConfig,
        on_ready: ReadyFn,
        encode_pool: Arc<EncodePool>,
    ) -> Session {
        Session {
            pool,
            decoder: FrameDecoder::with_cap(config.max_frame_len),
            out: OutQueue::new(),
            encode_pool,
            pending_header: None,
            pending: HashMap::new(),
            next_token: 0,
            write_queue_cap: config.write_queue_cap.max(1),
            default_conv_threshold: config.default_conv_threshold,
            on_ready,
            closed: false,
        }
    }

    /// Feed freshly read bytes; dispatches every complete frame.
    pub fn on_bytes(&mut self, bytes: &[u8]) {
        if self.closed {
            return;
        }
        self.decoder.push(bytes);
        loop {
            match self.decoder.next_any() {
                Ok(Some(Frame::Line(frame))) => {
                    // Blank lines are keepalive noise on the blocking
                    // path too; skip without a reply.
                    if frame.trim().is_empty() {
                        continue;
                    }
                    let header = match json::parse(&frame) {
                        Ok(j) => j,
                        Err(e) => {
                            self.enqueue_json(&err_json(&format!("bad request: {e:?}")));
                            continue;
                        }
                    };
                    match announced_payload(&header) {
                        None => self.dispatch_request(header, None),
                        Some(n) => match self.decoder.expect_payload(n) {
                            Ok(()) => self.pending_header = Some(header),
                            Err(e) => {
                                // A hostile announce cannot be skipped
                                // past; reply once and close.
                                self.enqueue_json(&err_json(&format!("bad request: {e}")));
                                self.closed = true;
                                break;
                            }
                        },
                    }
                }
                Ok(Some(Frame::Payload(payload))) => {
                    let header = self
                        .pending_header
                        .take()
                        .expect("payload frame without a pending header");
                    self.dispatch_request(header, Some(&payload));
                }
                Ok(None) => break,
                Err(e) => {
                    self.enqueue_json(&err_json(&format!("bad request: {e}")));
                    self.closed = true;
                    break;
                }
            }
        }
    }

    fn dispatch_request(&mut self, header: Json, payload: Option<&[u8]>) {
        let token = self.next_token;
        self.next_token += 1;
        let on_ready = self.on_ready.clone();
        let notify: CompletionNotify = Arc::new(move || on_ready(token));
        match dispatch_parsed(
            &header,
            payload,
            &self.pool,
            self.default_conv_threshold,
            Some(notify),
        ) {
            Dispatched::Immediate(json) => self.enqueue_json(&json),
            Dispatched::Pending { ticket, return_samples, tag, handle, encoding } => {
                // The notify may already have fired (completion raced
                // the insert); that is fine — the wake is queued behind
                // this call on the owning loop, and `on_complete` finds
                // the entry once we insert it here.
                self.pending.insert(
                    token,
                    PendingRequest { ticket, return_samples, tag, handle, encoding },
                );
            }
        }
    }

    /// Route a completion token back into the session: polls the
    /// ticket and enqueues the reply. Spurious or duplicate tokens are
    /// ignored (the entry stays pending / is already gone).
    pub fn on_complete(&mut self, token: u64) {
        let Some(p) = self.pending.remove(&token) else { return };
        match p.ticket.try_result() {
            None => {
                // Spurious wake: result not landed yet; keep waiting.
                self.pending.insert(token, p);
            }
            Some(out) => {
                // Identity-checked: a tag re-used by a newer request in
                // the meantime is not evicted.
                if let Some(tag) = p.tag {
                    self.pool.deregister_tag(tag, &p.handle);
                }
                match out {
                    Err(e) => self.enqueue_json(&err_json(&e)),
                    Ok(res) => self.enqueue_result(res, p.return_samples, p.encoding),
                }
            }
        }
    }

    /// Serialise a control/error reply into a pooled buffer.
    fn enqueue_json(&mut self, reply: &Json) {
        let mut buf = self.encode_pool.take();
        reply.write_to(&mut buf);
        buf.push('\n');
        self.out.push(OutSeg::Text(buf));
    }

    /// Serialise a finished sample. Binary encoding with samples
    /// requested emits a header line plus the tensor itself as a
    /// zero-copy payload segment; everything else is a plain JSON
    /// frame written by the allocation-free result writer.
    fn enqueue_result(&mut self, res: SamplingResult, return_samples: bool, encoding: Encoding) {
        let mut buf = self.encode_pool.take();
        if encoding == Encoding::Bin && return_samples {
            let payload_bytes = res.samples.len() * 4;
            write_result_header(&res, payload_bytes, &mut buf);
            buf.push('\n');
            self.out.push(OutSeg::Text(buf));
            #[cfg(target_endian = "little")]
            self.out.push(OutSeg::Samples(Arc::new(res.samples)));
            #[cfg(not(target_endian = "little"))]
            self.out.push(OutSeg::Blob(res.samples.to_le_bytes()));
        } else {
            write_result_json(&res, return_samples, &mut buf);
            buf.push('\n');
            self.out.push(OutSeg::Text(buf));
        }
    }

    /// False while the write queue is over cap (or the session is
    /// closing): the owner should park read interest.
    pub fn wants_read(&self) -> bool {
        !self.closed && self.out.len() < self.write_queue_cap
    }

    pub fn has_output(&self) -> bool {
        self.out.len() > 0
    }

    /// The front segment's unconsumed bytes (the single-buffer write
    /// path; [`Session::out_vectored`] gathers across segments).
    pub fn out_slice(&self) -> &[u8] {
        self.out.front_slice()
    }

    /// Gather up to `out.len()` outgoing slices for one vectored write.
    pub fn out_vectored<'a>(&'a self, out: &mut [&'a [u8]]) -> usize {
        self.out.vectored(out)
    }

    /// Mark `n` outgoing bytes as written to the socket.
    pub fn consume_out(&mut self, n: usize) {
        self.out.consume(n, &self.encode_pool);
    }

    /// True once a fatal protocol error's reply has fully drained.
    pub fn should_close(&self) -> bool {
        self.closed && self.out.len() == 0
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.len()
    }

    /// Drop all in-flight state on disconnect: cancel pending tickets
    /// (their replies are undeliverable; freeing pool capacity early
    /// beats computing into the void), release their tags, recycle
    /// queued reply buffers, and reset the decoder — a half-received
    /// payload or sticky announce error must not poison shared state
    /// for the next connection drawing from the same pools.
    pub fn abort(&mut self) {
        for (_, p) in self.pending.drain() {
            if let Some(tag) = p.tag {
                self.pool.deregister_tag(tag, &p.handle);
            }
            p.ticket.cancel();
        }
        self.decoder.reset();
        self.pending_header = None;
        self.out.clear(&self.encode_pool);
        self.closed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{MockBank, ModelBank};
    use crate::pool::{PoolConfig, WorkerPool};
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::schedule::VpSchedule;
    use std::sync::mpsc;
    use std::time::Duration;

    fn pool() -> Arc<WorkerPool> {
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> =
            Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
        Arc::new(WorkerPool::start(bank, PoolConfig::default()))
    }

    fn drain_bytes(s: &mut Session) -> Vec<u8> {
        let mut bytes = Vec::new();
        while s.has_output() {
            let n = s.out_slice().len();
            bytes.extend_from_slice(s.out_slice());
            s.consume_out(n);
        }
        bytes
    }

    fn drain(s: &mut Session) -> Vec<String> {
        let text = String::from_utf8(drain_bytes(s)).unwrap();
        text.lines().map(|l| l.to_string()).collect()
    }

    fn ready_channel() -> (ReadyFn, mpsc::Receiver<u64>) {
        let (tx, rx) = mpsc::channel();
        (Arc::new(move |token| drop(tx.send(token))), rx)
    }

    #[test]
    fn control_ops_answer_immediately_across_split_reads() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(b"{\"op\":\"pi");
        assert!(!s.has_output(), "partial frame must not dispatch");
        s.on_bytes(b"ng\"}\n{\"op\":\"stats\"}\n");
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 2);
        assert!(replies[0].contains("\"pong\":true"), "{}", replies[0]);
        assert!(replies[1].contains("\"shards\":1"), "{}", replies[1]);
        assert!(s.wants_read());
        assert!(!s.should_close());
    }

    #[test]
    fn sample_completes_via_ready_token_and_try_result() {
        let p = pool();
        let (ready, rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":4,\"seed\":1}\n");
        assert_eq!(s.pending_requests(), 1);
        assert!(!s.has_output(), "sample reply must not be written before completion");
        let token = rx.recv_timeout(Duration::from_secs(10)).expect("completion notify");
        s.on_complete(token);
        assert_eq!(s.pending_requests(), 0);
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("\"ok\":true"), "{}", replies[0]);
        assert!(replies[0].contains("\"rows\":4"), "{}", replies[0]);
        // A duplicate wake for a retired token is a no-op.
        s.on_complete(token);
        assert!(!s.has_output());
    }

    #[test]
    fn binary_sample_reply_is_header_plus_bitwise_payload() {
        let p = pool();
        let (ready, rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(
            b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":4,\"seed\":1,\
              \"return_samples\":true,\"encoding\":\"bin\"}\n",
        );
        let token = rx.recv_timeout(Duration::from_secs(10)).expect("completion notify");
        s.on_complete(token);
        let bytes = drain_bytes(&mut s);
        let nl = bytes.iter().position(|&b| b == b'\n').expect("header line");
        let header = json::parse(std::str::from_utf8(&bytes[..nl]).unwrap()).unwrap();
        let rows = header.get("rows").as_usize().unwrap();
        let dim = header.get("dim").as_usize().unwrap();
        let payload = header.get("payload_bytes").as_usize().unwrap();
        assert_eq!((rows, dim), (4, 2));
        assert_eq!(payload, rows * dim * 4);
        assert_eq!(bytes.len(), nl + 1 + payload, "payload is counted, not framed");
        assert!(header.get("samples").as_arr().is_none(), "no inline samples in bin mode");
        let t = crate::tensor::Tensor::from_le_bytes(&bytes[nl + 1..], rows, dim).unwrap();
        assert!(t.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn binary_init_upload_splits_across_reads() {
        let p = pool();
        let (ready, rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        let init = crate::tensor::Tensor::from_vec(vec![0.5f32; 8], 4, 2);
        let payload = init.to_le_bytes();
        s.on_bytes(
            b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":4,\"seed\":3,\
              \"strength\":0.5,\"init_rows\":4,\"init_bytes\":32,\
              \"return_samples\":true}\n",
        );
        assert_eq!(s.pending_requests(), 0, "request must wait for its payload");
        s.on_bytes(&payload[..13]);
        assert_eq!(s.pending_requests(), 0);
        s.on_bytes(&payload[13..]);
        assert_eq!(s.pending_requests(), 1, "payload completion dispatches the request");
        let token = rx.recv_timeout(Duration::from_secs(10)).expect("completion notify");
        s.on_complete(token);
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("\"ok\":true"), "{}", replies[0]);
        assert!(replies[0].contains("\"rows\":4"), "{}", replies[0]);
    }

    #[test]
    fn bad_request_line_gets_error_reply() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let mut s = Session::new(p, &SessionConfig::default(), ready);
        s.on_bytes(b"not json\n\n  \n");
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1, "blank lines are skipped without replies");
        assert!(replies[0].contains("bad request"), "{}", replies[0]);
        assert!(!s.should_close(), "a malformed line is not fatal");
    }

    #[test]
    fn oversized_frame_is_fatal_after_the_error_drains() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let cfg = SessionConfig { max_frame_len: 16, ..SessionConfig::default() };
        let mut s = Session::new(p, &cfg, ready);
        s.on_bytes(&[b'x'; 64]);
        assert!(!s.wants_read());
        assert!(!s.should_close(), "error reply still queued");
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("frame exceeds"), "{}", replies[0]);
        assert!(s.should_close(), "close once the error reply drained");
        s.on_bytes(b"{\"op\":\"ping\"}\n");
        assert!(!s.has_output(), "a closed session ignores further input");
    }

    #[test]
    fn oversized_payload_announce_is_refused_and_fatal() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let cfg = SessionConfig { max_frame_len: 256, ..SessionConfig::default() };
        let mut s = Session::new(p, &cfg, ready);
        s.on_bytes(b"{\"op\":\"sample\",\"init_rows\":4,\"init_bytes\":100000}\n");
        assert!(!s.wants_read());
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("frame exceeds"), "{}", replies[0]);
        assert!(s.should_close(), "hostile announce cannot be resynced past");
        assert_eq!(s.pending_requests(), 0);
    }

    #[test]
    fn abort_mid_payload_resets_decoder_and_recycles_buffers() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let encode_pool = Arc::new(EncodePool::new());
        let cfg = SessionConfig::default();
        let mut s =
            Session::with_encode_pool(p.clone(), &cfg, ready.clone(), encode_pool.clone());
        // A ping reply queued but never written, then a disconnect
        // mid-payload: abort must recycle the reply buffer and clear
        // the half-armed counted mode.
        s.on_bytes(b"{\"op\":\"ping\"}\n{\"op\":\"sample\",\"init_rows\":2,\"init_bytes\":16}\n");
        s.on_bytes(b"\x01\x02\x03"); // 3 of 16 announced payload bytes
        assert!(s.has_output());
        s.abort();
        assert!(s.should_close());
        assert!(!s.has_output(), "undeliverable replies are dropped");
        assert_eq!(encode_pool.idle(), 1, "queued reply buffer returned to the pool");
        // A fresh session sharing the pool starts clean.
        let (ready2, _rx2) = ready_channel();
        let mut s2 = Session::with_encode_pool(p, &cfg, ready2, encode_pool);
        s2.on_bytes(b"{\"op\":\"ping\"}\n");
        let replies = drain(&mut s2);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("\"pong\":true"), "{}", replies[0]);
    }

    #[test]
    fn pooled_buffers_recycle_across_replies() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let encode_pool = Arc::new(EncodePool::new());
        let mut s = Session::with_encode_pool(
            p,
            &SessionConfig::default(),
            ready,
            encode_pool.clone(),
        );
        for _ in 0..5 {
            s.on_bytes(b"{\"op\":\"ping\"}\n");
            let replies = drain(&mut s);
            assert_eq!(replies.len(), 1);
        }
        assert_eq!(encode_pool.idle(), 1, "one buffer serves all sequential replies");
    }

    #[test]
    fn full_write_queue_parks_read_interest_until_drained() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let cfg = SessionConfig { write_queue_cap: 8, ..SessionConfig::default() };
        let mut s = Session::new(p, &cfg, ready);
        s.on_bytes(b"{\"op\":\"ping\"}\n");
        assert!(s.has_output());
        assert!(!s.wants_read(), "queue over cap must park reads");
        let n = s.out_slice().len();
        s.consume_out(n);
        assert!(s.wants_read(), "drained queue resumes reads");
    }

    #[test]
    fn vectored_gather_spans_segments() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let mut s = Session::new(p, &SessionConfig::default(), ready);
        s.on_bytes(b"{\"op\":\"ping\"}\n{\"op\":\"ping\"}\n{\"op\":\"ping\"}\n");
        let mut slices: [&[u8]; 8] = [&[]; 8];
        let n = s.out_vectored(&mut slices);
        assert_eq!(n, 3, "one segment per reply frame");
        let total: usize = slices[..n].iter().map(|sl| sl.len()).sum();
        // Partially consume into the second segment; the gather must
        // resume from the exact offset.
        let cut = slices[0].len() + 2;
        s.consume_out(cut);
        let mut slices2: [&[u8]; 8] = [&[]; 8];
        let n2 = s.out_vectored(&mut slices2);
        assert_eq!(n2, 2);
        let total2: usize = slices2[..n2].iter().map(|sl| sl.len()).sum();
        assert_eq!(total2, total - cut);
        s.consume_out(total2);
        assert!(!s.has_output());
    }

    #[test]
    fn abort_cancels_pending_and_releases_tags() {
        let p = pool();
        let (ready, rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(
            b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":4,\"seed\":2,\"tag\":77}\n",
        );
        assert_eq!(s.pending_requests(), 1);
        s.abort();
        assert_eq!(s.pending_requests(), 0);
        assert!(s.should_close());
        // The notify still fires when the cancelled request retires;
        // the token no longer resolves, which must be harmless.
        if let Ok(token) = rx.recv_timeout(Duration::from_secs(10)) {
            s.on_complete(token);
        }
        assert!(!p.cancel_tag(77), "aborted session must release its tag");
    }
}
