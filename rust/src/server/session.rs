//! Per-connection protocol session: a transport-agnostic state machine
//! between raw bytes and the worker pool.
//!
//! The gateway's event loops own sockets and readiness; they delegate
//! everything protocol-shaped to a [`Session`]: feed it whatever bytes
//! the socket had ([`Session::on_bytes`]), drain its outgoing byte
//! queue when the socket is writable ([`Session::out_slice`] /
//! [`Session::consume_out`]), and poke it when a submitted request
//! completes ([`Session::on_complete`]). The session never blocks and
//! never touches a socket, so it unit-tests without any I/O and would
//! ride any future transport (TLS, Unix sockets) unchanged.
//!
//! Control ops (`ping`, `stats`, `cancel`, ...) answer immediately.
//! `sample` ops are submitted with a [`CompletionNotify`] that calls
//! the session's ready callback with a per-request token; the owning
//! loop routes that token back into [`Session::on_complete`], which
//! polls the ticket (guaranteed ready — the notify fires after the
//! result lands) and enqueues the reply. Several samples may be in
//! flight on one connection at once; replies are written in completion
//! order, which pipelining clients must match by their own bookkeeping
//! (the stock [`super::client::Client`] runs one request at a time and
//! never observes reordering).
//!
//! Backpressure: the outgoing queue is bounded by
//! [`SessionConfig::write_queue_cap`]. While it is over the cap,
//! [`Session::wants_read`] turns false and the owner deregisters read
//! interest — a peer that stops draining replies stops being read,
//! instead of growing an unbounded buffer server-side.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::{CancelHandle, CompletionNotify};
use crate::json::Json;
use crate::pool::{PoolTicket, WorkerPool};

use super::codec::{encode_frame, FrameDecoder, MAX_FRAME_LEN};
use super::{dispatch_async, err_json, sample_reply, Dispatched};

/// Per-session protocol limits (shared by every connection of one
/// gateway).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Cap on one unterminated request line; a peer exceeding it gets
    /// one error reply and the connection closes (codec robustness
    /// contract — the connection cannot resync past an unframed blob).
    pub max_frame_len: usize,
    /// Outgoing-queue size above which the session parks read interest.
    pub write_queue_cap: usize,
    /// Server-level convergence default inherited by non-strict
    /// requests that did not set their own (see [`super::dispatch`]).
    pub default_conv_threshold: f64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            max_frame_len: MAX_FRAME_LEN,
            write_queue_cap: 256 * 1024,
            default_conv_threshold: 0.0,
        }
    }
}

/// Callback into the owning event loop: "the request with this token
/// finished; call [`Session::on_complete`] with it". Fired on the
/// shard's loop thread, so implementations must only enqueue-and-wake.
pub type ReadyFn = Arc<dyn Fn(u64) + Send + Sync>;

struct PendingRequest {
    ticket: PoolTicket,
    return_samples: bool,
    tag: Option<u64>,
    handle: CancelHandle,
}

/// Outgoing byte queue with amortized-O(1) front consumption (same
/// compaction discipline as [`FrameDecoder`]).
struct OutBuf {
    buf: Vec<u8>,
    start: usize,
}

const OUT_COMPACT_THRESHOLD: usize = 16 * 1024;

impl OutBuf {
    fn new() -> OutBuf {
        OutBuf { buf: Vec::new(), start: 0 }
    }

    fn len(&self) -> usize {
        self.buf.len() - self.start
    }

    fn slice(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    fn consume(&mut self, n: usize) {
        self.start += n;
        debug_assert!(self.start <= self.buf.len());
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start >= OUT_COMPACT_THRESHOLD {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }
}

/// One connection's protocol state. See the module docs for the
/// ownership contract with the event loop.
pub struct Session {
    pool: Arc<WorkerPool>,
    decoder: FrameDecoder,
    out: OutBuf,
    pending: HashMap<u64, PendingRequest>,
    next_token: u64,
    write_queue_cap: usize,
    default_conv_threshold: f64,
    on_ready: ReadyFn,
    /// Set on a codec error: the reply queue drains, then the owner
    /// closes the socket ([`Session::should_close`]).
    closed: bool,
}

impl Session {
    pub fn new(pool: Arc<WorkerPool>, config: &SessionConfig, on_ready: ReadyFn) -> Session {
        Session {
            pool,
            decoder: FrameDecoder::with_cap(config.max_frame_len),
            out: OutBuf::new(),
            pending: HashMap::new(),
            next_token: 0,
            write_queue_cap: config.write_queue_cap.max(1),
            default_conv_threshold: config.default_conv_threshold,
            on_ready,
            closed: false,
        }
    }

    /// Feed freshly read bytes; dispatches every complete frame.
    pub fn on_bytes(&mut self, bytes: &[u8]) {
        if self.closed {
            return;
        }
        self.decoder.push(bytes);
        loop {
            match self.decoder.next_frame() {
                Ok(Some(frame)) => {
                    // Blank lines are keepalive noise on the blocking
                    // path too; skip without a reply.
                    if frame.trim().is_empty() {
                        continue;
                    }
                    self.dispatch_frame(&frame);
                }
                Ok(None) => break,
                Err(e) => {
                    self.enqueue(&err_json(&format!("bad request: {e}")));
                    self.closed = true;
                    break;
                }
            }
        }
    }

    fn dispatch_frame(&mut self, frame: &str) {
        let token = self.next_token;
        self.next_token += 1;
        let on_ready = self.on_ready.clone();
        let notify: CompletionNotify = Arc::new(move || on_ready(token));
        match dispatch_async(frame, &self.pool, self.default_conv_threshold, Some(notify)) {
            Dispatched::Immediate(json) => self.enqueue(&json),
            Dispatched::Pending { ticket, return_samples, tag, handle } => {
                // The notify may already have fired (completion raced
                // the insert); that is fine — the wake is queued behind
                // this call on the owning loop, and `on_complete` finds
                // the entry once we insert it here.
                self.pending.insert(token, PendingRequest { ticket, return_samples, tag, handle });
            }
        }
    }

    /// Route a completion token back into the session: polls the
    /// ticket and enqueues the reply. Spurious or duplicate tokens are
    /// ignored (the entry stays pending / is already gone).
    pub fn on_complete(&mut self, token: u64) {
        let Some(p) = self.pending.remove(&token) else { return };
        match p.ticket.try_result() {
            None => {
                // Spurious wake: result not landed yet; keep waiting.
                self.pending.insert(token, p);
            }
            Some(out) => {
                // Identity-checked: a tag re-used by a newer request in
                // the meantime is not evicted.
                if let Some(tag) = p.tag {
                    self.pool.deregister_tag(tag, &p.handle);
                }
                self.enqueue(&sample_reply(out, p.return_samples));
            }
        }
    }

    fn enqueue(&mut self, reply: &Json) {
        encode_frame(&reply.to_string(), &mut self.out.buf);
    }

    /// False while the write queue is over cap (or the session is
    /// closing): the owner should park read interest.
    pub fn wants_read(&self) -> bool {
        !self.closed && self.out.len() < self.write_queue_cap
    }

    pub fn has_output(&self) -> bool {
        self.out.len() > 0
    }

    pub fn out_slice(&self) -> &[u8] {
        self.out.slice()
    }

    /// Mark `n` outgoing bytes as written to the socket.
    pub fn consume_out(&mut self, n: usize) {
        self.out.consume(n);
    }

    /// True once a fatal protocol error's reply has fully drained.
    pub fn should_close(&self) -> bool {
        self.closed && self.out.len() == 0
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.len()
    }

    /// Drop all in-flight state on disconnect: cancel pending tickets
    /// (their replies are undeliverable; freeing pool capacity early
    /// beats computing into the void) and release their tags.
    pub fn abort(&mut self) {
        for (_, p) in self.pending.drain() {
            if let Some(tag) = p.tag {
                self.pool.deregister_tag(tag, &p.handle);
            }
            p.ticket.cancel();
        }
        self.closed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{MockBank, ModelBank};
    use crate::pool::{PoolConfig, WorkerPool};
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::schedule::VpSchedule;
    use std::sync::mpsc;
    use std::time::Duration;

    fn pool() -> Arc<WorkerPool> {
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> =
            Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
        Arc::new(WorkerPool::start(bank, PoolConfig::default()))
    }

    fn drain(s: &mut Session) -> Vec<String> {
        let text = String::from_utf8(s.out_slice().to_vec()).unwrap();
        let n = s.out_slice().len();
        s.consume_out(n);
        text.lines().map(|l| l.to_string()).collect()
    }

    fn ready_channel() -> (ReadyFn, mpsc::Receiver<u64>) {
        let (tx, rx) = mpsc::channel();
        (Arc::new(move |token| drop(tx.send(token))), rx)
    }

    #[test]
    fn control_ops_answer_immediately_across_split_reads() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(b"{\"op\":\"pi");
        assert!(!s.has_output(), "partial frame must not dispatch");
        s.on_bytes(b"ng\"}\n{\"op\":\"stats\"}\n");
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 2);
        assert!(replies[0].contains("\"pong\":true"), "{}", replies[0]);
        assert!(replies[1].contains("\"shards\":1"), "{}", replies[1]);
        assert!(s.wants_read());
        assert!(!s.should_close());
    }

    #[test]
    fn sample_completes_via_ready_token_and_try_result() {
        let p = pool();
        let (ready, rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":4,\"seed\":1}\n");
        assert_eq!(s.pending_requests(), 1);
        assert!(!s.has_output(), "sample reply must not be written before completion");
        let token = rx.recv_timeout(Duration::from_secs(10)).expect("completion notify");
        s.on_complete(token);
        assert_eq!(s.pending_requests(), 0);
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("\"ok\":true"), "{}", replies[0]);
        assert!(replies[0].contains("\"rows\":4"), "{}", replies[0]);
        // A duplicate wake for a retired token is a no-op.
        s.on_complete(token);
        assert!(!s.has_output());
    }

    #[test]
    fn bad_request_line_gets_error_reply() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let mut s = Session::new(p, &SessionConfig::default(), ready);
        s.on_bytes(b"not json\n\n  \n");
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1, "blank lines are skipped without replies");
        assert!(replies[0].contains("bad request"), "{}", replies[0]);
        assert!(!s.should_close(), "a malformed line is not fatal");
    }

    #[test]
    fn oversized_frame_is_fatal_after_the_error_drains() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let cfg = SessionConfig { max_frame_len: 16, ..SessionConfig::default() };
        let mut s = Session::new(p, &cfg, ready);
        s.on_bytes(&[b'x'; 64]);
        assert!(!s.wants_read());
        assert!(!s.should_close(), "error reply still queued");
        let replies = drain(&mut s);
        assert_eq!(replies.len(), 1);
        assert!(replies[0].contains("frame exceeds"), "{}", replies[0]);
        assert!(s.should_close(), "close once the error reply drained");
        s.on_bytes(b"{\"op\":\"ping\"}\n");
        assert!(!s.has_output(), "a closed session ignores further input");
    }

    #[test]
    fn full_write_queue_parks_read_interest_until_drained() {
        let p = pool();
        let (ready, _rx) = ready_channel();
        let cfg = SessionConfig { write_queue_cap: 8, ..SessionConfig::default() };
        let mut s = Session::new(p, &cfg, ready);
        s.on_bytes(b"{\"op\":\"ping\"}\n");
        assert!(s.has_output());
        assert!(!s.wants_read(), "queue over cap must park reads");
        let n = s.out_slice().len();
        s.consume_out(n);
        assert!(s.wants_read(), "drained queue resumes reads");
    }

    #[test]
    fn abort_cancels_pending_and_releases_tags() {
        let p = pool();
        let (ready, rx) = ready_channel();
        let mut s = Session::new(p.clone(), &SessionConfig::default(), ready);
        s.on_bytes(
            b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":4,\"seed\":2,\"tag\":77}\n",
        );
        assert_eq!(s.pending_requests(), 1);
        s.abort();
        assert_eq!(s.pending_requests(), 0);
        assert!(s.should_close());
        // The notify still fires when the cancelled request retires;
        // the token no longer resolves, which must be harmless.
        if let Ok(token) = rx.recv_timeout(Duration::from_secs(10)) {
            s.on_complete(token);
        }
        assert!(!p.cancel_tag(77), "aborted session must release its tag");
    }
}
