//! Readiness transport for the gateway: a minimal safe wrapper over
//! Linux `epoll(7)`, declared straight against the C ABI.
//!
//! The crate's zero-dependency discipline (DESIGN.md §1) rules out mio
//! and tokio, and `std` exposes no readiness API — so the gateway owns
//! the three syscalls it needs (`epoll_create1`, `epoll_ctl`,
//! `epoll_wait`) plus `close`, and nothing else. Sockets stay ordinary
//! `std::net` types in nonblocking mode; only readiness *registration*
//! goes through [`Epoll`].
//!
//! Layout note: the kernel's `struct epoll_event` is packed on x86-64
//! (12 bytes — a plain `#[repr(C)]` struct would pad `data` to an
//! 8-byte boundary and the kernel would scribble events across the
//! wrong offsets), and naturally aligned elsewhere. The `cfg_attr`
//! mirrors exactly what glibc's header does. Fields of a packed struct
//! must be copied out, never borrowed.
//!
//! [`Waker`] is the cross-thread wake primitive: one end of a
//! `UnixStream::pair` registered with the loop's epoll; any thread
//! wakes the loop by writing a byte to the other end (a full pipe
//! means a wake is already pending — dropping the byte is correct).

use std::io::{self, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;

// Interest / readiness bits (uapi/linux/eventpoll.h).
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLET: u32 = 1 << 31;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: i32 = 0o2000000;

#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token routed back on readiness (we store the
    /// connection id, never a pointer).
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

/// `struct iovec` (uapi/linux/uio.h): one gather entry for `writev`.
#[repr(C)]
#[derive(Clone, Copy)]
struct IoVec {
    base: *const u8,
    len: usize,
}

/// Gather-write entry cap per `writev` call. The kernel allows 1024
/// (`UIO_MAXIOV`); a reply burst rarely exceeds a handful of segments,
/// so a small fixed array keeps the gather allocation-free.
pub const MAX_IOVECS: usize = 16;

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn writev(fd: i32, iov: *const IoVec, iovcnt: i32) -> isize;
}

/// Vectored write: submit up to [`MAX_IOVECS`] buffers in one syscall
/// (header + zero-copy payload + pipelined next frame). Returns the
/// bytes written, which may cover only a prefix of the slices — the
/// caller consumes its queue by count, exactly as with `write`.
/// `EAGAIN` surfaces as `WouldBlock`, like `TcpStream::write`.
pub fn writev_fd(fd: RawFd, slices: &[&[u8]]) -> io::Result<usize> {
    debug_assert!(!slices.is_empty() && slices.len() <= MAX_IOVECS);
    let mut iov = [IoVec { base: std::ptr::null(), len: 0 }; MAX_IOVECS];
    let n = slices.len().min(MAX_IOVECS);
    for (entry, s) in iov.iter_mut().zip(slices.iter()) {
        entry.base = s.as_ptr();
        entry.len = s.len();
    }
    // SAFETY: the iovec array points at `n` live slices whose borrows
    // outlast this call; the kernel only reads them.
    let rc = unsafe { writev(fd, iov.as_ptr(), n as i32) };
    if rc < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(rc as usize)
    }
}

/// An owned epoll instance. One per event-loop thread; not `Sync` by
/// design (registration from other threads goes through the loop's
/// inbox + [`Waker`], never a shared epoll handle).
pub struct Epoll {
    epfd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    /// Register `fd` with the given interest; `token` comes back in
    /// every event for it.
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Replace `fd`'s interest set (used for EPOLLOUT arming and
    /// read-interest backpressure parking).
    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        // The event pointer is ignored for DEL but must be non-null on
        // pre-2.6.9 kernels; pass a dummy for uniformity.
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, retrying on EINTR. `timeout_ms < 0` blocks
    /// indefinitely; `0` polls. Returns how many `events` entries were
    /// filled.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let rc = unsafe {
                epoll_wait(self.epfd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
            };
            if rc < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err);
            }
            return Ok(rc as usize);
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

/// Cross-thread wake-up for one event loop: register [`Waker::fd`]
/// (level-triggered `EPOLLIN`) and call [`Waker::wake`] from anywhere.
pub struct Waker {
    tx: UnixStream,
    rx: UnixStream,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Waker { tx, rx })
    }

    /// The readable end, for epoll registration.
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Nudge the loop. A full pipe (`WouldBlock`) means wakes are
    /// already pending, so dropping this byte loses nothing.
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }

    /// Swallow all pending wake bytes (call on every waker event, then
    /// drain the inbox).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.rx).read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(_) => break, // WouldBlock: drained
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_event_matches_kernel_layout() {
        // Packed on x86-64 (4 + 8), padded to alignment elsewhere.
        #[cfg(target_arch = "x86_64")]
        assert_eq!(std::mem::size_of::<EpollEvent>(), 12);
        assert!(std::mem::size_of::<EpollEvent>() >= 12);
    }

    #[test]
    fn waker_wakes_and_drains_level_triggered() {
        let ep = Epoll::new().unwrap();
        let waker = Waker::new().unwrap();
        ep.add(waker.fd(), EPOLLIN, 7).unwrap();

        let mut events = [EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "no wake yet");

        waker.wake();
        waker.wake(); // coalesces; still one readable fd
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        // Copy packed fields to locals before use.
        let (got_events, got_token) = (events[0].events, events[0].data);
        assert_ne!(got_events & EPOLLIN, 0);
        assert_eq!(got_token, 7);

        waker.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "drained waker is quiet");
    }

    #[test]
    fn writev_gathers_multiple_slices_in_one_call() {
        let (a, b) = UnixStream::pair().unwrap();
        let n = writev_fd(a.as_raw_fd(), &[b"hel", b"lo ", b"world"]).unwrap();
        assert_eq!(n, 11);
        let mut buf = [0u8; 16];
        let got = (&b).read(&mut buf).unwrap();
        assert_eq!(&buf[..got], b"hello world");
    }

    #[test]
    fn writev_on_a_full_pipe_is_would_block() {
        let (a, _b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let chunk = [0u8; 64 * 1024];
        let err = loop {
            match writev_fd(a.as_raw_fd(), &[&chunk, &chunk]) {
                Ok(_) => continue,
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind(), io::ErrorKind::WouldBlock);
    }

    #[test]
    fn modify_and_delete_change_the_interest_set() {
        let ep = Epoll::new().unwrap();
        let waker = Waker::new().unwrap();
        ep.add(waker.fd(), EPOLLIN, 1).unwrap();
        waker.wake();

        // Interest parked: a readable fd with empty interest reports
        // nothing (this is the backpressure mechanism).
        ep.modify(waker.fd(), 0, 1).unwrap();
        let mut events = [EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        // Re-armed: the still-pending byte reports immediately
        // (level-triggered).
        ep.modify(waker.fd(), EPOLLIN, 2).unwrap();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        let token = events[0].data;
        assert_eq!(token, 2);

        ep.delete(waker.fd()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "deleted fd is gone");
        assert!(ep.add(waker.fd(), EPOLLIN, 3).is_ok(), "fd can re-register after delete");
    }
}
