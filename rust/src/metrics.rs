//! Generation-quality metrics.
//!
//! The paper scores samplers with FID (Fréchet Inception Distance) over
//! 50k samples. Inception-V3 does not exist here and the data is low-dim
//! synthetic, so we compute the *Fréchet distance directly in data space*
//! — the identical formula FID uses on feature moments:
//!
//! ```text
//!     d^2 = ||mu1 - mu2||^2 + tr(C1 + C2 - 2 (C1^{1/2} C2 C1^{1/2})^{1/2})
//!
//! ```
//! plus two auxiliary views (sliced W2, mode coverage) used by the
//! qualitative figures. EXPERIMENTS.md reports the Fréchet numbers as the
//! FID column of every reproduced table.

use crate::linalg::{matmul, sqrtm_psd, symmetrize, trace};
use crate::tensor::Tensor;

/// First two moments of a sample set (f64 for metric stability).
#[derive(Clone, Debug)]
pub struct Moments {
    pub mean: Vec<f64>,
    /// Row-major d x d covariance.
    pub cov: Vec<f64>,
    pub dim: usize,
}

impl Moments {
    pub fn from_tensor(x: &Tensor) -> Moments {
        Moments { mean: x.col_means(), cov: x.covariance(), dim: x.cols() }
    }

    pub fn new(mean: Vec<f64>, cov: Vec<f64>) -> Moments {
        let dim = mean.len();
        assert_eq!(cov.len(), dim * dim, "covariance shape mismatch");
        Moments { mean, cov, dim }
    }
}

/// Squared Fréchet distance between two Gaussians (the FID formula).
pub fn frechet_distance(a: &Moments, b: &Moments) -> f64 {
    assert_eq!(a.dim, b.dim, "moment dimension mismatch");
    let n = a.dim;

    let mean_term: f64 = a
        .mean
        .iter()
        .zip(&b.mean)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();

    // tr(C1 + C2 - 2 sqrt(sqrt(C1) C2 sqrt(C1)))
    let s1 = sqrtm_psd(&symmetrize(&a.cov, n), n);
    let inner = matmul(&matmul(&s1, &symmetrize(&b.cov, n), n), &s1, n);
    let cross = sqrtm_psd(&symmetrize(&inner, n), n);
    let tr = trace(&a.cov, n) + trace(&b.cov, n) - 2.0 * trace(&cross, n);

    // The analytic value is >= 0; clamp tiny negative numerical residue.
    (mean_term + tr).max(0.0)
}

/// Fréchet distance between a generated tensor and reference moments.
pub fn fid(gen: &Tensor, reference: &Moments) -> f64 {
    frechet_distance(&Moments::from_tensor(gen), reference)
}

/// Sliced 2-Wasserstein distance: average 1-D W2 over `n_proj` random
/// projections. Cheap, captures shape mismatch the moment-based Fréchet
/// misses (e.g. a Gaussian vs a ring with equal moments).
pub fn sliced_w2(a: &Tensor, b: &Tensor, n_proj: usize, seed: u64) -> f64 {
    assert_eq!(a.cols(), b.cols());
    let d = a.cols();
    let mut rng = crate::rng::Rng::new(seed);
    let mut total = 0.0f64;
    for _ in 0..n_proj {
        // Random unit direction.
        let mut dir = vec![0.0f64; d];
        let mut norm = 0.0;
        for v in dir.iter_mut() {
            *v = rng.normal();
            norm += *v * *v;
        }
        let norm = norm.sqrt().max(1e-12);
        dir.iter_mut().for_each(|v| *v /= norm);

        let mut pa = project(a, &dir);
        let mut pb = project(b, &dir);
        pa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        // 1-D W2^2 between equal-size empirical measures = mean squared
        // difference of order statistics (resample the longer by index
        // scaling when sizes differ).
        let n = pa.len().min(pb.len());
        let mut acc = 0.0;
        for i in 0..n {
            let qa = pa[i * pa.len() / n.max(1)];
            let qb = pb[i * pb.len() / n.max(1)];
            acc += (qa - qb) * (qa - qb);
        }
        total += acc / n.max(1) as f64;
    }
    (total / n_proj as f64).sqrt()
}

fn project(x: &Tensor, dir: &[f64]) -> Vec<f64> {
    (0..x.rows())
        .map(|r| {
            x.row(r)
                .iter()
                .zip(dir)
                .map(|(&v, &d)| v as f64 * d)
                .sum::<f64>()
        })
        .collect()
}

/// Fraction of reference modes hit by at least one generated sample within
/// `radius` (mode-coverage view used in the qualitative analysis).
pub fn mode_coverage(gen: &Tensor, modes: &[Vec<f64>], radius: f64) -> f64 {
    if modes.is_empty() {
        return 1.0;
    }
    let mut hit = vec![false; modes.len()];
    for r in 0..gen.rows() {
        let row = gen.row(r);
        for (m, center) in modes.iter().enumerate() {
            if hit[m] {
                continue;
            }
            let d2: f64 = row
                .iter()
                .zip(center)
                .map(|(&v, &c)| {
                    let d = v as f64 - c;
                    d * d
                })
                .sum();
            if d2.sqrt() <= radius {
                hit[m] = true;
            }
        }
    }
    hit.iter().filter(|&&h| h).count() as f64 / modes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn frechet_zero_for_identical() {
        let m = Moments::new(vec![1.0, 2.0], vec![2.0, 0.3, 0.3, 1.0]);
        assert!(frechet_distance(&m, &m) < 1e-9);
    }

    #[test]
    fn frechet_mean_shift_only() {
        // Equal covariance, mean shift d: distance = ||d||^2.
        let c = vec![1.0, 0.0, 0.0, 1.0];
        let a = Moments::new(vec![0.0, 0.0], c.clone());
        let b = Moments::new(vec![3.0, 4.0], c);
        assert!((frechet_distance(&a, &b) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn frechet_isotropic_scale() {
        // N(0, I) vs N(0, 4I) in 2-D: tr(1+4-2*2) per axis = 1 per axis.
        let a = Moments::new(vec![0.0, 0.0], vec![1.0, 0.0, 0.0, 1.0]);
        let b = Moments::new(vec![0.0, 0.0], vec![4.0, 0.0, 0.0, 4.0]);
        assert!((frechet_distance(&a, &b) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn frechet_diagonal_closed_form() {
        // For diagonal covariances A = diag(a_i), B = diag(b_i):
        //   d^2 = ||mu1 - mu2||^2 + sum_i (sqrt(a_i) - sqrt(b_i))^2.
        // Here: mean term (1-3)^2 + (2-5)^2 = 13; covariance term
        // (1-3)^2 + (2-4)^2 = 8; total 21.
        let a = Moments::new(vec![1.0, 2.0], vec![1.0, 0.0, 0.0, 4.0]);
        let b = Moments::new(vec![3.0, 5.0], vec![9.0, 0.0, 0.0, 16.0]);
        assert!((frechet_distance(&a, &b) - 21.0).abs() < 1e-8);
    }

    #[test]
    fn frechet_symmetric() {
        let a = Moments::new(vec![0.0, 1.0], vec![1.5, 0.2, 0.2, 0.7]);
        let b = Moments::new(vec![0.5, 0.0], vec![0.9, -0.1, -0.1, 2.0]);
        let d1 = frechet_distance(&a, &b);
        let d2 = frechet_distance(&b, &a);
        assert!((d1 - d2).abs() < 1e-9);
        assert!(d1 > 0.0);
    }

    #[test]
    fn fid_of_matched_samples_is_small() {
        let mut rng = Rng::new(0);
        let x = rng.normal_tensor(20_000, 2);
        let reference = Moments::new(vec![0.0, 0.0], vec![1.0, 0.0, 0.0, 1.0]);
        let d = fid(&x, &reference);
        assert!(d < 0.01, "fid {d}");
    }

    #[test]
    fn fid_detects_mismatch() {
        let mut rng = Rng::new(0);
        let mut x = rng.normal_tensor(5_000, 2);
        x.scale(3.0);
        let reference = Moments::new(vec![0.0, 0.0], vec![1.0, 0.0, 0.0, 1.0]);
        assert!(fid(&x, &reference) > 1.0);
    }

    #[test]
    fn sliced_w2_zero_for_same_samples() {
        let mut rng = Rng::new(1);
        let x = rng.normal_tensor(2_000, 2);
        assert!(sliced_w2(&x, &x, 16, 7) < 1e-9);
    }

    #[test]
    fn sliced_w2_orders_distances() {
        let mut rng = Rng::new(2);
        let x = rng.normal_tensor(4_000, 2);
        let mut y_near = rng.normal_tensor(4_000, 2);
        y_near.scale(1.1);
        let mut y_far = rng.normal_tensor(4_000, 2);
        y_far.scale(3.0);
        let d_near = sliced_w2(&x, &y_near, 24, 7);
        let d_far = sliced_w2(&x, &y_far, 24, 7);
        assert!(d_near < d_far);
    }

    #[test]
    fn coverage_full_and_partial() {
        let gen = Tensor::from_vec(vec![0.0, 0.0, 2.0, 0.0], 2, 2);
        let modes = vec![vec![0.0, 0.0], vec![2.0, 0.0], vec![-2.0, 0.0]];
        let c = mode_coverage(&gen, &modes, 0.5);
        assert!((c - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mode_coverage(&gen, &[], 0.5), 1.0);
    }
}
